"""Quickstart: the paper in 40 lines.

Solve congestion-aware joint partition placement + routing on the IoT-edge-
cloud scenario and compare all four methods (paper Fig. 2, IoT column).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import compare_all, iot, stage_traffic

problem = iot()  # 17 nodes: 1 cloud, 4 edge servers, 12 IoT devices
results = compare_all(problem)

print("Normalized objective (lower is better; ALT is the paper's method):")
worst = max(r.J for r in results.values())
for name, r in results.items():
    bar = "#" * int(40 * r.J / worst)
    print(f"  {name:12s} J={r.J:12.2f}  ({r.J / worst:6.3f})  {bar}")

alt = results["ALT"]
hosts = np.asarray(alt.state.hosts())
names = (
    ["cloud"] + [f"edge{i}" for i in range(1, 5)] + [f"iot{i}" for i in range(5, 17)]
)
print("\nALT placement (partition1 -> partition2) per application:")
for a in range(min(8, hosts.shape[0])):
    src = int(problem.apps.src[a])
    print(
        f"  app{a}: source={names[src]:6s}  p1@{names[hosts[a, 0]]:6s} "
        f"p2@{names[hosts[a, 1]]:6s}"
    )
print("  ... (first 8 of", hosts.shape[0], "apps)")

t = stage_traffic(problem, alt.state)
# Bytes-on-wire per stage: L_k * sum_links f^{a,k}_{ij}.
f = t[..., :, None] * alt.state.phi  # [A, K, V, V]
wire = np.asarray(
    (problem.apps.L[:, :, None, None] * f).sum(axis=(0, 2, 3))
)
print(
    f"\nBytes-on-wire per stage (size x link crossings): raw={wire[0]:.1f} "
    f"features={wire[1]:.1f} outputs={wire[2]:.1f}"
)
print("(raw stage stays near the source — the first partition compresses")
print(" at the edge before the long haul: the paper's intended structure.)")
