"""End-to-end partitioned DNN serving over a multi-hop edge network.

The full stack in one script — BOTH planes:

  control plane: repro.core decides where the two partitions of each model
                 run and how stage 0/1/2 traffic is routed (congestion-aware
                 ALT), fed by real architecture profiles from repro.partition;
  data plane:    the chosen placement is EXECUTED — partition 1 of a real
                 (reduced) model runs at its host, the stage-1 activation is
                 "shipped" along the computed route, partition 2 produces
                 logits at its host; outputs are validated against the
                 monolithic model.

Also demonstrates the paper-native STRAGGLER MITIGATION: degrade a node's
compute rate and watch ALT move partitions off it and re-route.

    PYTHONPATH=src python examples/edge_serving.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core import CostModel, Network, Problem, solve_alt, stage_traffic
from repro.core.structs import BIG
from repro.models import init_params, logits_fn
from repro.partition import apps_from_profiles, profile_arch, run_partition, split_params

# ---------------------------------------------------------------------------
# 1. an 8-node edge network: 4 devices, 3 edge servers, 1 regional cloud
# ---------------------------------------------------------------------------
N = 8
names = ["dev0", "dev1", "dev2", "dev3", "edge0", "edge1", "edge2", "cloud"]
links = [
    (0, 4), (1, 4), (2, 5), (3, 5),          # device uplinks (weak)
    (4, 5), (5, 6), (4, 6),                  # edge ring
    (6, 7),                                  # edge -> cloud
]
adj = np.zeros((N, N), np.float32)
mu = np.full((N, N), BIG, np.float32)
for u, v in links:
    for i, j in ((u, v), (v, u)):
        adj[i, j] = 1.0
        mu[i, j] = {(0, 4): 40e6, (1, 4): 40e6, (2, 5): 40e6, (3, 5): 40e6}.get(
            (u, v), 400e6
        )  # devices: 40 MB/s uplinks; backbone: 400 MB/s
nu = np.array([30e9, 30e9, 30e9, 30e9, 300e9, 300e9, 300e9, 2000e9], np.float32)
net = Network(adj=jnp.asarray(adj), mu=jnp.asarray(mu), nu=jnp.asarray(nu))

# ---------------------------------------------------------------------------
# 2. applications: real architecture profiles (seq 256 requests)
# ---------------------------------------------------------------------------
ARCHS = ["qwen1.5-0.5b", "gemma-2b", "mamba2-370m", "hymba-1.5b"]
from repro.configs import get_config
from repro.partition.profile import ArchProfile

profiles = [profile_arch(get_config(a), seq_len=128) for a in ARCHS]
# Token-LM profiles have L1 >> L0 (activations dwarf token ids): ALT will
# follow COMPUTE for those. Add a perception pipeline in the paper's regime
# (raw video in, small features out: L0 >> L1) — ALT should SPLIT it:
# partition 1 compresses at the edge, partition 2 classifies upstream.
profiles.append(ArchProfile(
    arch="perception-cnn", splits=(8,), n_layers_total=32, seq_len=1,
    L_bytes=(2e6, 1.5e5, 1e4),
    w_flops=(3e9, 60e9),
))
ARCHS = ARCHS + ["perception-cnn"]
src = np.array([0, 1, 2, 3, 0])  # one service per device + video on dev0
lam = np.array([0.6, 0.4, 0.5, 0.4, 8.0])
apps = apps_from_profiles(profiles, src, src, lam)
problem = Problem(net=net, apps=apps, cost=CostModel())

res = solve_alt(problem)
hosts = np.asarray(res.state.hosts())
print("=== control plane: congestion-aware placement (ALT) ===")
for a, arch in enumerate(ARCHS):
    ratio = profiles[a].compression_ratio()
    regime = "compresses (paper regime)" if ratio < 1 else "activation>input (LM)"
    print(
        f"  {arch:14s} from {names[src[a]]}: partition1 @ {names[hosts[a, 0]]:5s} "
        f"partition2 @ {names[hosts[a, 1]]:5s}  (L1/L0 {ratio:8.2f}: {regime})"
    )
print(f"  total expected cost J = {res.J:.3f}")

# ---------------------------------------------------------------------------
# 3. data plane: execute app 0's split exactly as placed
# ---------------------------------------------------------------------------
arch = ARCHS[0]
cfg = reduced_config(arch)  # reduced weights; same partition structure
params = init_params(cfg, jax.random.PRNGKey(0))
k = profile_arch(cfg, seq_len=64).split_layer
p1, p2 = split_params(cfg, params, k)

batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0, cfg.vocab)}
act = run_partition(cfg, p1, batch, part=1, k=k)          # runs at hosts[0,0]
print(
    f"\n=== data plane ({arch}, split at layer {k}) ===\n"
    f"  stage-1 activation shipped {names[hosts[0,0]]} -> {names[hosts[0,1]]}: "
    f"{act.size * act.dtype.itemsize / 1e3:.1f} kB"
)
logits = run_partition(cfg, p2, act, part=2, k=k)          # runs at hosts[0,1]
want = logits_fn(cfg, params, batch)
err = float(jnp.max(jnp.abs(logits.astype(jnp.float32) - want.astype(jnp.float32))))
print(f"  partitioned output == monolithic output: max err {err:.2e}")
assert err < 1e-2

# ---------------------------------------------------------------------------
# 4. straggler mitigation: degrade the busiest host, re-optimize
# ---------------------------------------------------------------------------
counts = np.bincount(hosts.flatten(), minlength=N)
hot = int(np.argmax(counts))
nu2 = nu.copy()
nu2[hot] /= 20.0  # the node slows down 20x (straggler / contention)
problem2 = Problem(
    net=Network(adj=net.adj, mu=net.mu, nu=jnp.asarray(nu2)), apps=apps,
    cost=CostModel(),
)
res2 = solve_alt(problem2)
hosts2 = np.asarray(res2.state.hosts())
moved = int((hosts2 != hosts).sum())
print(f"\n=== straggler mitigation ===")
print(f"  degraded {names[hot]} 20x -> ALT moved {moved} partition placements")
for a, arch_name in enumerate(ARCHS):
    if (hosts2[a] != hosts[a]).any():
        print(
            f"    {arch_name:14s} p1 {names[hosts[a,0]]}->{names[hosts2[a,0]]}  "
            f"p2 {names[hosts[a,1]]}->{names[hosts2[a,1]]}"
        )
stale_J = float(jax.block_until_ready(
    __import__("repro.core.flow", fromlist=["objective"]).objective(problem2, res.state)[0]
))
print(
    f"  cost if routing had stayed stale: {stale_J:.3f}  "
    f"vs re-optimized: {res2.J:.3f}  ({stale_J / res2.J:.1f}x better)"
)
assert res2.J < stale_J
print("\nOK")
