"""End-to-end driver: train a ~100M-parameter qwen-family model.

Full production path — sharded train step, checkpointing, resume, data
pipeline — at a CPU-runnable scale. The default --steps 300 is the "few
hundred steps" recipe; --smoke runs a 20-step version for CI.

    PYTHONPATH=src python examples/train_100m.py [--smoke]
"""
import argparse
import dataclasses
import sys

import jax

from repro.configs import get_config
from repro.launch import train as train_mod
from repro.models.config import ModelConfig

# ~112M params: qwen-style dense stack, 12L x d768 x ff2112, 32k vocab.
CONFIG_100M = ModelConfig(
    name="qwen-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    vocab=32_000,
    n_heads=12,
    n_kv_heads=4,
    head_dim=64,
    d_ff=2112,
    mlp_act="swiglu",
    qkv_bias=True,
    tie_embeddings=True,
    remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    print(f"model: {CONFIG_100M.name}  params={CONFIG_100M.n_params()/1e6:.1f}M")

    # Reuse the production trainer by registering the config ad hoc.
    import repro.configs.registry as reg

    reg._MODULES = dict(reg._MODULES)
    mod = type(sys)("qwen_100m_cfg")
    mod.CONFIG = CONFIG_100M
    sys.modules["repro.configs._qwen_100m"] = mod
    reg._MODULES["qwen-100m"] = "repro.configs._qwen_100m"

    steps = args.steps or (20 if args.smoke else 300)
    batch, seq = (8, 128) if args.smoke else (8, 256)
    return train_mod.main(
        [
            "--arch", "qwen-100m",
            "--steps", str(steps),
            "--global-batch", str(batch),
            "--seq-len", str(seq),
            "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "100",
            "--log-every", "10" if not args.smoke else "2",
            "--lr", "6e-4",
        ]
    )


if __name__ == "__main__":
    raise SystemExit(main())
