"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracles."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional_deps import given, settings, st

from repro.kernels.minplus.kernel import minplus_matmul_pallas
from repro.kernels.minplus.ref import apsp_ref, minplus_matmul_ref
from repro.kernels.minplus.ops import apsp, apsp_with_nexthop
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


# ---------------------------------------------------------------------------
# minplus
# ---------------------------------------------------------------------------
MINPLUS_SHAPES = [
    (8, 8, 8),
    (17, 17, 17),
    (64, 128, 96),
    (128, 128, 128),
    (200, 170, 130),
    (256, 256, 256),
]


@pytest.mark.parametrize("shape", MINPLUS_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_minplus_matches_ref(shape, dtype):
    m, k, n = shape
    rng = np.random.RandomState(hash(shape) % 2**31)
    a = rng.uniform(0, 10, (m, k)).astype(np.float32)
    b = rng.uniform(0, 10, (k, n)).astype(np.float32)
    a[rng.rand(m, k) < 0.2] = 1e18  # unreachable entries
    a_j, b_j = jnp.asarray(a, dtype), jnp.asarray(b, dtype)
    got = minplus_matmul_pallas(a_j, b_j, interpret=True)
    want = minplus_matmul_ref(a_j.astype(jnp.float32), b_j.astype(jnp.float32))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_minplus_block_sizes():
    rng = np.random.RandomState(0)
    a = rng.uniform(0, 5, (96, 96)).astype(np.float32)
    b = rng.uniform(0, 5, (96, 96)).astype(np.float32)
    want = minplus_matmul_ref(jnp.asarray(a), jnp.asarray(b))
    for block in (32, 64, 128, 256):
        got = minplus_matmul_pallas(
            jnp.asarray(a), jnp.asarray(b), block=block, interpret=True
        )
        np.testing.assert_allclose(got, want, rtol=1e-6)


def test_apsp_matches_networkx():
    import networkx as nx

    rng = np.random.RandomState(3)
    g = nx.connected_watts_strogatz_graph(40, 4, 0.3, seed=1)
    n = 40
    W = np.full((n, n), 1e18, np.float32)
    for u, v in g.edges():
        w = rng.uniform(0.5, 5.0)
        W[u, v] = w
        W[v, u] = w
    dist = np.asarray(apsp(jnp.asarray(W)))
    gg = nx.DiGraph()
    for u in range(n):
        for v in range(n):
            if W[u, v] < 1e17:
                gg.add_edge(u, v, weight=float(W[u, v]))
    for u, dd in nx.all_pairs_dijkstra_path_length(gg):
        for v, d in dd.items():
            assert abs(dist[u, v] - d) < 1e-3 * (1 + d)


def test_apsp_pallas_matches_ref_path():
    rng = np.random.RandomState(5)
    n = 50
    W = np.full((n, n), 1e18, np.float32)
    for _ in range(200):
        u, v = rng.randint(0, n, 2)
        if u != v:
            W[u, v] = rng.uniform(0.1, 4.0)
    got = apsp(jnp.asarray(W), use_pallas=True, interpret=True)
    want = apsp(jnp.asarray(W))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_nexthop_descends():
    """Following next-hops strictly decreases distance-to-target."""
    import networkx as nx

    g = nx.connected_watts_strogatz_graph(25, 4, 0.2, seed=2)
    n = 25
    rng = np.random.RandomState(7)
    W = np.full((n, n), 1e18, np.float32)
    for u, v in g.edges():
        w = rng.uniform(0.5, 3.0)
        W[u, v] = w
        W[v, u] = w
    dist, nh = apsp_with_nexthop(jnp.asarray(W))
    dist, nh = np.asarray(dist), np.asarray(nh)
    for target in range(0, n, 5):
        for i in range(n):
            if i == target:
                continue
            j = nh[i, target]
            assert dist[j, target] < dist[i, target]


@given(st.integers(5, 60), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_apsp_triangle_inequality(n, seed):
    rng = np.random.RandomState(seed)
    W = rng.uniform(0.1, 5.0, (n, n)).astype(np.float32)
    W[rng.rand(n, n) < 0.5] = 1e18
    d = np.asarray(apsp(jnp.asarray(W)))
    # d[i,j] <= d[i,k] + d[k,j] for all triples (vectorized check).
    via = (d[:, :, None] + d[None, :, :]).min(axis=1)
    assert (d <= via + 1e-3).all()


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
ATTN_CASES = [
    # (B, H, Kv, Sq, Sk, D, causal, window)
    (1, 4, 4, 128, 128, 64, True, None),     # MHA causal
    (2, 8, 2, 256, 256, 64, True, None),     # GQA 4:1
    (1, 8, 1, 128, 128, 128, True, None),    # MQA
    (1, 4, 4, 128, 128, 64, False, None),    # bidirectional (encoder)
    (1, 8, 2, 256, 256, 64, True, 128),      # sliding window
    (2, 4, 2, 100, 100, 64, True, None),     # non-multiple seq (padding)
    (1, 4, 2, 64, 192, 64, True, None),      # Sq != Sk with q_offset
    (1, 2, 2, 128, 128, 256, True, None),    # gemma-style d=256
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_matches_ref(case):
    b, h, kv, sq, sk, d, causal, window = case
    rng = np.random.RandomState(abs(hash(case)) % 2**31)
    q = jnp.asarray(rng.randn(b, h, sq, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, kv, sk, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, kv, sk, d), jnp.float32)
    q_offset = sk - sq if sq != sk else 0
    got = flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_offset=q_offset, interpret=True
    )
    want = attention_ref(q, k, v, causal=causal, window=window, q_offset=q_offset)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_dtypes(dtype):
    rng = np.random.RandomState(11)
    q = jnp.asarray(rng.randn(1, 4, 128, 64), dtype)
    k = jnp.asarray(rng.randn(1, 2, 128, 64), dtype)
    v = jnp.asarray(rng.randn(1, 2, 128, 64), dtype)
    got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    assert got.dtype == dtype
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=2e-2, atol=2e-2
    )


def test_flash_block_boundaries():
    """Non-128 block sizes and seqs crossing block boundaries."""
    rng = np.random.RandomState(13)
    q = jnp.asarray(rng.randn(1, 2, 200, 64), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 200, 64), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 200, 64), jnp.float32)
    want = attention_ref(q, k, v, causal=True)
    for bq, bk in ((64, 64), (128, 64), (64, 128)):
        got = flash_attention_pallas(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flash_fully_masked_rows_are_zero():
    """Rows before the window see no keys and must output exactly 0."""
    rng = np.random.RandomState(17)
    q = jnp.asarray(rng.randn(1, 2, 8, 64), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 8, 64), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 8, 64), jnp.float32)
    # q_offset far beyond kv length + tiny window => nothing visible for the
    # earliest rows is impossible here; instead use causal with offset -1:
    # query positions all < 0 relative to keys -> fully masked.
    got = flash_attention_pallas(
        q, k, v, causal=True, q_offset=-100, interpret=True
    )
    np.testing.assert_allclose(got, jnp.zeros_like(got), atol=1e-6)
