"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracles."""
import dataclasses
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional_deps import given, settings, st

from repro.kernels.minplus.kernel import (
    minplus_matmul_argmin_pallas,
    minplus_matmul_pallas,
)
from repro.kernels.minplus.ref import apsp_ref, minplus_matmul_blocked, minplus_matmul_ref
from repro.kernels.minplus.ops import _nexthop_blocked, apsp, apsp_with_nexthop
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref

# CI kernels-smoke knob: set REPRO_BIG_KERNEL_V (e.g. 1536) to run the
# interpret-mode parity sweeps at a V past the single-tile VMEM cap.
BIGV = int(os.environ.get("REPRO_BIG_KERNEL_V", "0"))
bigv_only = pytest.mark.skipif(
    BIGV < 1, reason="set REPRO_BIG_KERNEL_V to run the big-V parity sweeps"
)


# ---------------------------------------------------------------------------
# minplus
# ---------------------------------------------------------------------------
MINPLUS_SHAPES = [
    (8, 8, 8),
    (17, 17, 17),
    (64, 128, 96),
    (128, 128, 128),
    (200, 170, 130),
    (256, 256, 256),
]


@pytest.mark.parametrize("shape", MINPLUS_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_minplus_matches_ref(shape, dtype):
    m, k, n = shape
    rng = np.random.RandomState(hash(shape) % 2**31)
    a = rng.uniform(0, 10, (m, k)).astype(np.float32)
    b = rng.uniform(0, 10, (k, n)).astype(np.float32)
    a[rng.rand(m, k) < 0.2] = 1e18  # unreachable entries
    a_j, b_j = jnp.asarray(a, dtype), jnp.asarray(b, dtype)
    got = minplus_matmul_pallas(a_j, b_j, interpret=True)
    want = minplus_matmul_ref(a_j.astype(jnp.float32), b_j.astype(jnp.float32))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_minplus_block_sizes():
    rng = np.random.RandomState(0)
    a = rng.uniform(0, 5, (96, 96)).astype(np.float32)
    b = rng.uniform(0, 5, (96, 96)).astype(np.float32)
    want = minplus_matmul_ref(jnp.asarray(a), jnp.asarray(b))
    for block in (32, 64, 128, 256):
        got = minplus_matmul_pallas(
            jnp.asarray(a), jnp.asarray(b), block=block, interpret=True
        )
        np.testing.assert_allclose(got, want, rtol=1e-6)


def test_apsp_matches_networkx():
    import networkx as nx

    rng = np.random.RandomState(3)
    g = nx.connected_watts_strogatz_graph(40, 4, 0.3, seed=1)
    n = 40
    W = np.full((n, n), 1e18, np.float32)
    for u, v in g.edges():
        w = rng.uniform(0.5, 5.0)
        W[u, v] = w
        W[v, u] = w
    dist = np.asarray(apsp(jnp.asarray(W)))
    gg = nx.DiGraph()
    for u in range(n):
        for v in range(n):
            if W[u, v] < 1e17:
                gg.add_edge(u, v, weight=float(W[u, v]))
    for u, dd in nx.all_pairs_dijkstra_path_length(gg):
        for v, d in dd.items():
            assert abs(dist[u, v] - d) < 1e-3 * (1 + d)


def test_apsp_pallas_matches_ref_path():
    rng = np.random.RandomState(5)
    n = 50
    W = np.full((n, n), 1e18, np.float32)
    for _ in range(200):
        u, v = rng.randint(0, n, 2)
        if u != v:
            W[u, v] = rng.uniform(0.1, 4.0)
    got = apsp(jnp.asarray(W), use_pallas=True, interpret=True)
    want = apsp(jnp.asarray(W))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_nexthop_descends():
    """Following next-hops strictly decreases distance-to-target."""
    import networkx as nx

    g = nx.connected_watts_strogatz_graph(25, 4, 0.2, seed=2)
    n = 25
    rng = np.random.RandomState(7)
    W = np.full((n, n), 1e18, np.float32)
    for u, v in g.edges():
        w = rng.uniform(0.5, 3.0)
        W[u, v] = w
        W[v, u] = w
    dist, nh = apsp_with_nexthop(jnp.asarray(W))
    dist, nh = np.asarray(dist), np.asarray(nh)
    for target in range(0, n, 5):
        for i in range(n):
            if i == target:
                continue
            j = nh[i, target]
            assert dist[j, target] < dist[i, target]


@given(st.integers(5, 60), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_apsp_triangle_inequality(n, seed):
    rng = np.random.RandomState(seed)
    W = rng.uniform(0.1, 5.0, (n, n)).astype(np.float32)
    W[rng.rand(n, n) < 0.5] = 1e18
    d = np.asarray(apsp(jnp.asarray(W)))
    # d[i,j] <= d[i,k] + d[k,j] for all triples (vectorized check).
    via = (d[:, :, None] + d[None, :, :]).min(axis=1)
    assert (d <= via + 1e-3).all()


# ---------------------------------------------------------------------------
# blocked (k-chunked) tropical matmul — the O(V^2)-memory default path
# ---------------------------------------------------------------------------
@given(
    st.integers(1, 40),
    st.integers(2, 48),
    st.integers(1, 40),
    st.integers(0, 10_000),
    st.sampled_from([0.0, 0.3, 0.9]),
)
@settings(max_examples=25, deadline=None)
def test_blocked_matches_ref_bitwise(m, k, n, seed, density):
    """Streaming the K reduction in chunks must be BITWISE the oracle:
    min over the same candidate multiset, padding contributes only
    BIG+BIG candidates that +inf-initialized accumulators never keep."""
    rng = np.random.RandomState(seed)
    a = rng.uniform(0, 10, (m, k)).astype(np.float32)
    b = rng.uniform(0, 10, (k, n)).astype(np.float32)
    a[rng.rand(m, k) < density] = 1e18
    b[rng.rand(k, n) < density] = 1e18
    # block_k=8 forces real chunking (and ragged padding) at every size.
    got = minplus_matmul_blocked(jnp.asarray(a), jnp.asarray(b), block_k=8)
    want = minplus_matmul_ref(jnp.asarray(a), jnp.asarray(b))
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_blocked_all_big_rows_cols_and_diagonal():
    """Degenerate rows (all non-edge), columns, and a reflexive zero
    diagonal — the exact shapes APSP squaring feeds the matmul."""
    v = 24
    rng = np.random.RandomState(1)
    w = rng.uniform(0.1, 5.0, (v, v)).astype(np.float32)
    w[rng.rand(v, v) < 0.4] = 1e18
    w[3, :] = 1e18  # isolated source
    w[:, 7] = 1e18  # unreachable sink
    np.fill_diagonal(w, 0.0)
    for bk in (8, 16, v):  # v: degenerate single chunk (oracle passthrough)
        got = minplus_matmul_blocked(jnp.asarray(w), jnp.asarray(w), block_k=bk)
        want = minplus_matmul_ref(jnp.asarray(w), jnp.asarray(w))
        assert np.array_equal(np.asarray(got), np.asarray(want)), bk


def test_apsp_squaring_matches_floyd_warshall():
    """The n_iter/early-exit squaring closure agrees with the FW default
    (bitwise: integer weights make every path sum exact in fp32)."""
    rng = np.random.RandomState(2)
    n = 48
    W = np.full((n, n), 1e18, np.float32)
    for _ in range(200):
        u, v = rng.randint(0, n, 2)
        if u != v:
            W[u, v] = float(rng.randint(1, 8))
    d_fw = np.asarray(apsp(jnp.asarray(W)))
    d_sq = np.asarray(apsp(jnp.asarray(W), n_iter=math.ceil(math.log2(n))))
    d_ne = np.asarray(apsp(jnp.asarray(W), n_iter=8, early_exit=False))
    assert np.array_equal(d_fw, d_sq)
    assert np.array_equal(d_fw, d_ne)


# ---------------------------------------------------------------------------
# fused min+argmin next-hop: kernel and blocked fallback vs the full tensor
# ---------------------------------------------------------------------------
def _random_weights(n, n_edges, seed, integer=False):
    rng = np.random.RandomState(seed)
    W = np.full((n, n), 1e18, np.float32)
    for _ in range(n_edges):
        u, v = rng.randint(0, n, 2)
        if u != v:
            W[u, v] = float(rng.randint(1, 5)) if integer else rng.uniform(0.1, 4.0)
    return W


def test_fused_argmin_matches_two_step():
    """The fused kernel == materialize [V,V,V], min + first-min argmin."""
    n = 72
    W = _random_weights(n, 400, seed=9)
    dist = np.asarray(apsp(jnp.asarray(W)))
    val, nh = minplus_matmul_argmin_pallas(
        jnp.asarray(W), jnp.asarray(dist), interpret=True
    )
    cand = W[:, :, None] + dist[None, :, :]
    np.testing.assert_allclose(np.asarray(val), cand.min(axis=1), rtol=1e-6)
    assert np.array_equal(np.asarray(nh), cand.argmin(axis=1))


def test_fused_argmin_tie_break_first_min():
    """Integer weights force exact ties; the strict-< carry must keep the
    FIRST minimizing k, like jnp.argmin on the full candidate tensor."""
    n = 40
    W = _random_weights(n, 300, seed=11, integer=True)
    dist = np.asarray(apsp(jnp.asarray(W)))
    cand = W[:, :, None] + dist[None, :, :]
    want = cand.argmin(axis=1)
    _, nh_pl = minplus_matmul_argmin_pallas(
        jnp.asarray(W), jnp.asarray(dist), interpret=True
    )
    nh_bl = _nexthop_blocked(jnp.asarray(W), jnp.asarray(dist))
    assert np.array_equal(np.asarray(nh_pl), want)
    assert np.array_equal(np.asarray(nh_bl), want)


def test_apsp_with_nexthop_pallas_matches_fallback():
    """End-to-end parity of the two apsp_with_nexthop paths. Integer
    weights keep both distance strategies exact, so the next-hop tables
    (same first-min tie-break) agree bitwise."""
    n = 60
    W = _random_weights(n, 500, seed=13, integer=True)
    d_bl, nh_bl = apsp_with_nexthop(jnp.asarray(W))
    d_pl, nh_pl = apsp_with_nexthop(jnp.asarray(W), use_pallas=True, interpret=True)
    assert np.array_equal(np.asarray(d_bl), np.asarray(d_pl))
    assert np.array_equal(np.asarray(nh_bl), np.asarray(nh_pl))


# ---------------------------------------------------------------------------
# incremental hop-bound cache: warm re-closure == cold solve, bitwise
# ---------------------------------------------------------------------------
@given(st.integers(8, 24), st.integers(0, 10_000), st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_hop_bound_cache_warm_matches_cold(n, seed, n_events):
    """Arbitrary chaos event sequences (node down, link remove, link add):
    after every event the warm-started closure must be bitwise identical
    to a from-scratch solve (1/BIG hop weights are exact fp32 integers)."""
    from repro.core import hop_bound_cache, random_connected

    p = random_connected(n, max(2, n // 3), seed=seed)
    net = p.net
    cache = hop_bound_cache(net)
    assert cache.sweeps == -1  # cold solve
    rng = np.random.RandomState(seed + 1)
    adj = np.asarray(net.adj).copy()
    for _ in range(n_events):
        ev = rng.randint(3)
        i, j = rng.randint(n, size=2)
        if ev == 0:  # node churn: every incident link drops
            adj[i, :] = 0.0
            adj[:, i] = 0.0
        elif ev == 1 and i != j:  # symmetric link removal
            adj[i, j] = adj[j, i] = 0.0
        elif i != j:  # symmetric link addition
            adj[i, j] = adj[j, i] = 1.0
        net = dataclasses.replace(net, adj=jnp.asarray(adj))
        cache = hop_bound_cache(net, cache)
        cold = hop_bound_cache(net)
        assert np.array_equal(cache.adj, cold.adj)
        assert np.array_equal(cache.dist, cold.dist)
        assert cache.hop_bound == cold.hop_bound
    # an unchanged adjacency short-circuits: no sweeps, same answer
    again = hop_bound_cache(net, cache)
    assert again.sweeps == 0
    assert np.array_equal(again.dist, cache.dist)


def test_hop_bound_cache_pallas_path_matches():
    """The warm re-closure through the Pallas matmul (interpret) agrees
    with the jnp path bitwise."""
    from repro.core import hop_bound_cache, random_connected

    p = random_connected(16, 5, seed=3)
    c0 = hop_bound_cache(p.net)
    adj = np.asarray(p.net.adj).copy()
    adj[0, :] = 0.0
    adj[:, 0] = 0.0
    net = dataclasses.replace(p.net, adj=jnp.asarray(adj))
    warm_jnp = hop_bound_cache(net, c0)
    warm_pl = hop_bound_cache(net, c0, use_pallas=True, interpret=True)
    assert np.array_equal(warm_jnp.dist, warm_pl.dist)
    assert warm_jnp.hop_bound == warm_pl.hop_bound


# ---------------------------------------------------------------------------
# big-V interpret-mode parity (CI kernels smoke: REPRO_BIG_KERNEL_V=1536)
# ---------------------------------------------------------------------------
@bigv_only
def test_bigv_minplus_pallas_matches_blocked():
    v = BIGV
    rng = np.random.RandomState(0)
    w = rng.uniform(0.1, 5.0, (v, v)).astype(np.float32)
    w[rng.rand(v, v) < 0.6] = 1e18
    np.fill_diagonal(w, 0.0)
    got = minplus_matmul_pallas(jnp.asarray(w), jnp.asarray(w), interpret=True)
    # the blocked path is the bitwise-oracle reference at sizes where the
    # [V, V, V] broadcast oracle cannot be materialized
    want = minplus_matmul_blocked(jnp.asarray(w), jnp.asarray(w))
    assert np.array_equal(np.asarray(got), np.asarray(want))


@bigv_only
def test_bigv_fused_argmin_values_match_blocked():
    v = BIGV
    rng = np.random.RandomState(1)
    w = rng.uniform(0.1, 5.0, (v, v)).astype(np.float32)
    w[rng.rand(v, v) < 0.6] = 1e18
    np.fill_diagonal(w, 0.0)
    a, b = jnp.asarray(w), jnp.asarray(w)
    val, nh = minplus_matmul_argmin_pallas(a, b, interpret=True)
    want = minplus_matmul_blocked(a, b)
    assert np.array_equal(np.asarray(val), np.asarray(want))
    # gather parity: the claimed argmin must reproduce the min value
    idx = np.asarray(nh)
    picked = np.take_along_axis(w, idx, axis=1) + np.take_along_axis(
        w, idx, axis=0
    )  # w[i, k] + w[k, j] at k = idx[i, j]
    np.testing.assert_array_equal(picked, np.asarray(want))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
ATTN_CASES = [
    # (B, H, Kv, Sq, Sk, D, causal, window)
    (1, 4, 4, 128, 128, 64, True, None),     # MHA causal
    (2, 8, 2, 256, 256, 64, True, None),     # GQA 4:1
    (1, 8, 1, 128, 128, 128, True, None),    # MQA
    (1, 4, 4, 128, 128, 64, False, None),    # bidirectional (encoder)
    (1, 8, 2, 256, 256, 64, True, 128),      # sliding window
    (2, 4, 2, 100, 100, 64, True, None),     # non-multiple seq (padding)
    (1, 4, 2, 64, 192, 64, True, None),      # Sq != Sk with q_offset
    (1, 2, 2, 128, 128, 256, True, None),    # gemma-style d=256
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_matches_ref(case):
    b, h, kv, sq, sk, d, causal, window = case
    rng = np.random.RandomState(abs(hash(case)) % 2**31)
    q = jnp.asarray(rng.randn(b, h, sq, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, kv, sk, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, kv, sk, d), jnp.float32)
    q_offset = sk - sq if sq != sk else 0
    got = flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_offset=q_offset, interpret=True
    )
    want = attention_ref(q, k, v, causal=causal, window=window, q_offset=q_offset)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_dtypes(dtype):
    rng = np.random.RandomState(11)
    q = jnp.asarray(rng.randn(1, 4, 128, 64), dtype)
    k = jnp.asarray(rng.randn(1, 2, 128, 64), dtype)
    v = jnp.asarray(rng.randn(1, 2, 128, 64), dtype)
    got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    assert got.dtype == dtype
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=2e-2, atol=2e-2
    )


def test_flash_block_boundaries():
    """Non-128 block sizes and seqs crossing block boundaries."""
    rng = np.random.RandomState(13)
    q = jnp.asarray(rng.randn(1, 2, 200, 64), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 200, 64), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 200, 64), jnp.float32)
    want = attention_ref(q, k, v, causal=True)
    for bq, bk in ((64, 64), (128, 64), (64, 128)):
        got = flash_attention_pallas(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flash_fully_masked_rows_are_zero():
    """Rows before the window see no keys and must output exactly 0."""
    rng = np.random.RandomState(17)
    q = jnp.asarray(rng.randn(1, 2, 8, 64), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 8, 64), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 8, 64), jnp.float32)
    # q_offset far beyond kv length + tiny window => nothing visible for the
    # earliest rows is impossible here; instead use causal with offset -1:
    # query positions all < 0 relative to keys -> fully masked.
    got = flash_attention_pallas(
        q, k, v, causal=True, q_offset=-100, interpret=True
    )
    np.testing.assert_allclose(got, jnp.zeros_like(got), atol=1e-6)
