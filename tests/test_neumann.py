"""Tests for the nilpotent-propagation solver path (kernels/neumann + the
core solver switch): the nilpotency contract (Neumann == LU on loop-free
forwarding states, including padded phantom rows), kernel/oracle agreement,
differentiability through custom_linear_solve, and hop-bound plumbing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional_deps import given, settings, st

from repro.core import (
    SCENARIOS,
    forwarding_sweep,
    infer_hop_bound,
    objective,
    random_connected,
    stage_traffic,
    structured_init,
    with_hop_bound,
)
from repro.core.marginals import cost_to_go
from repro.fleet import pad_problem, stack_problems, unify_hop_bound
from repro.kernels.neumann import (
    effective_hops,
    lu_solve_ref,
    neumann_solve,
    neumann_solve_ref,
)
from repro.kernels.neumann.kernel import neumann_solve_pallas

jax.config.update("jax_enable_x64", False)


def _nilpotent_batch(rng, n_batch, v, density=0.3):
    """Strictly-upper-triangular (provably nilpotent) random operators."""
    m = np.triu(rng.uniform(0.0, 1.0, (n_batch, v, v)).astype(np.float32), 1)
    m *= rng.rand(n_batch, v, v) < density
    return jnp.asarray(m)


# ---------------------------------------------------------------------------
# Subsystem: oracle / XLA / Pallas agreement on nilpotent operators
# ---------------------------------------------------------------------------
class TestNeumannSubsystem:
    def test_all_paths_match_lu(self):
        rng = np.random.RandomState(0)
        m = _nilpotent_batch(rng, 5, 23)
        b = jnp.asarray(rng.uniform(0.0, 2.0, (5, 23)).astype(np.float32))
        want = lu_solve_ref(m, b)
        scale = float(jnp.max(jnp.abs(want)))
        for got in (
            neumann_solve_ref(m, b, hops=24),
            neumann_solve(m, b, hops=24),
            neumann_solve_pallas(m, b, hops=24, interpret=True),
        ):
            np.testing.assert_allclose(
                np.asarray(got) / scale, np.asarray(want) / scale, atol=1e-5
            )

    def test_early_exit_matches_full_hops(self):
        """The residual early-exit must not change the converged answer."""
        rng = np.random.RandomState(1)
        m = _nilpotent_batch(rng, 3, 17)
        b = jnp.asarray(rng.uniform(0.0, 2.0, (3, 17)).astype(np.float32))
        # Generous cap: early exit fires as soon as the series is summed.
        got = neumann_solve(m, b, hops=500)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(lu_solve_ref(m, b)), rtol=1e-4, atol=1e-5
        )

    def test_contractive_cycles_converge(self):
        """Transient blocking-rule cycles (gain < 1) still solve correctly —
        the geometric-tail regime the hop slack exists for."""
        rng = np.random.RandomState(2)
        v = 12
        m = np.array(_nilpotent_batch(rng, 1, v, density=0.5))[0]
        # Real phi rows are substochastic (sum <= 1, Eq. 2) — normalize,
        # then close a cycle with an improper-link-sized back edge.
        m /= np.maximum(m.sum(axis=1, keepdims=True), 1.0)
        m[v - 1, 0] = 0.4
        mj = jnp.asarray(m)[None]
        b = jnp.asarray(rng.uniform(0.0, 2.0, (1, v)).astype(np.float32))
        got = neumann_solve(mj, b, hops=effective_hops(v + 2, v))
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(lu_solve_ref(mj, b)), rtol=1e-4
        )

    def test_small_magnitude_element_not_truncated(self):
        """Convergence must be judged per batch element: a huge
        fast-converging element must not early-exit a tiny slow-converging
        one (regression: the residual check was batch-global)."""
        v = 24
        chain = np.zeros((v, v), np.float32)
        for i in range(v - 1):
            chain[i, i + 1] = 1.0  # full-length propagation chain
        m = jnp.asarray(np.stack([np.zeros((v, v), np.float32), chain.T]))
        b = np.zeros((2, v), np.float32)
        b[0, 0] = 1e6      # converges after one hop
        b[1, 0] = 1e-3     # needs all v-1 hops to reach the far end
        b = jnp.asarray(b)
        got = neumann_solve(m, b, hops=v + 1)
        want = lu_solve_ref(m, b)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
        assert float(got[1, v - 1]) == pytest.approx(1e-3, rel=1e-4)

    def test_grad_matches_lu(self):
        """custom_linear_solve routes cotangents through a transpose solve."""
        rng = np.random.RandomState(3)
        m = _nilpotent_batch(rng, 2, 11)
        b = jnp.asarray(rng.uniform(0.0, 2.0, (2, 11)).astype(np.float32))
        g_ne = jax.grad(lambda x: jnp.sum(neumann_solve(m, x, hops=12) ** 2))(b)
        g_lu = jax.grad(lambda x: jnp.sum(lu_solve_ref(m, x) ** 2))(b)
        np.testing.assert_allclose(np.asarray(g_ne), np.asarray(g_lu), rtol=1e-3)
        gm_ne = jax.grad(lambda x: jnp.sum(neumann_solve(x, b, hops=12)))(m)
        gm_lu = jax.grad(lambda x: jnp.sum(lu_solve_ref(x, b)))(m)
        np.testing.assert_allclose(
            np.asarray(gm_ne), np.asarray(gm_lu), rtol=1e-3, atol=1e-4
        )

    def test_vmap_fleet_axis(self):
        rng = np.random.RandomState(4)
        m = _nilpotent_batch(rng, 6, 9).reshape(2, 3, 9, 9)
        b = jnp.asarray(rng.uniform(0.0, 1.0, (2, 3, 9)).astype(np.float32))
        got = jax.vmap(lambda mm, bb: neumann_solve(mm, bb, hops=10))(m, b)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(lu_solve_ref(m, b)), rtol=1e-4, atol=1e-5
        )

    def test_pallas_lane_padding_inert(self):
        """V not a lane multiple: padded coordinates must stay exactly zero."""
        rng = np.random.RandomState(5)
        m = _nilpotent_batch(rng, 2, 37)
        b = jnp.asarray(rng.uniform(0.0, 1.0, (2, 37)).astype(np.float32))
        got = neumann_solve_pallas(m, b, hops=38, interpret=True)
        assert got.shape == (2, 37)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(lu_solve_ref(m, b)), rtol=1e-4, atol=1e-5
        )


# ---------------------------------------------------------------------------
# K-tiled kernel: V past the single-tile VMEM cap, mixed precision
# ---------------------------------------------------------------------------
def _substochastic_batch(rng, n_batch, v, rho=0.9):
    """Strictly-upper-triangular operators with row sums rho < 1 — nilpotent
    AND contractive, so truncated hops converge fast at any V."""
    m = rng.uniform(0.0, 1.0, (n_batch, v, v)).astype(np.float32)
    m *= np.triu(np.ones((v, v), np.float32), 1)
    m *= rho / np.maximum(m.sum(-1, keepdims=True), 1e-9)
    return jnp.asarray(m)


class TestKTiledKernel:
    def test_forced_tiling_matches_single_tile(self):
        """block_k below V forces the tiled kernel at a size where the
        single-tile kernel is also available: the two must agree."""
        rng = np.random.RandomState(21)
        v = 192
        m = _substochastic_batch(rng, 2, v)
        b = jnp.asarray(rng.uniform(0.0, 2.0, (2, v)).astype(np.float32))
        ref = neumann_solve_pallas(m, b, hops=24, interpret=True)
        for bk in (128, 256):
            tiled = neumann_solve_pallas(m, b, hops=24, interpret=True, block_k=bk)
            np.testing.assert_allclose(
                np.asarray(tiled), np.asarray(ref), rtol=1e-5, atol=1e-5
            )

    def test_tiled_lane_padding_inert(self):
        """Ragged V through the tiled path: padded coordinates stay zero
        and the valid region matches LU."""
        rng = np.random.RandomState(22)
        m = _nilpotent_batch(rng, 2, 150)
        b = jnp.asarray(rng.uniform(0.0, 1.0, (2, 150)).astype(np.float32))
        got = neumann_solve_pallas(m, b, hops=151, interpret=True, block_k=128)
        assert got.shape == (2, 150)
        want = lu_solve_ref(m, b)
        scale = float(jnp.max(jnp.abs(want)))
        np.testing.assert_allclose(
            np.asarray(got) / scale, np.asarray(want) / scale, atol=1e-5
        )

    def test_bf16_operands_bounded_error(self):
        """bf16 W streaming with fp32 accumulation: bounded relative error
        vs the fp32 path (bf16 has ~3 decimal digits; the accumulator
        keeps the series sum from drifting)."""
        rng = np.random.RandomState(23)
        v = 384
        m = _substochastic_batch(rng, 2, v)
        b = jnp.asarray(rng.uniform(0.0, 2.0, (2, v)).astype(np.float32))
        x32 = neumann_solve_pallas(m, b, hops=32, interpret=True, block_k=128)
        xbf = neumann_solve_pallas(
            m, b, hops=32, interpret=True, block_k=128,
            operand_dtype=jnp.bfloat16,
        )
        scale = float(jnp.max(jnp.abs(x32))) + 1e-30
        err = float(jnp.max(jnp.abs(xbf - x32))) / scale
        assert err < 2e-2, err
        assert err > 0.0  # bf16 genuinely engaged (not silently fp32)

    def test_bf16_preserves_exact_zeros(self):
        """The zero-padding inertness argument requires bf16 casts to keep
        exact zeros: decoupled coordinates must come out exactly 0.0."""
        rng = np.random.RandomState(24)
        v = 160
        m = np.array(_substochastic_batch(rng, 1, v))
        m[:, v // 2 :, :] = 0.0  # no coupling into the upper half...
        b = rng.uniform(0.5, 1.0, (1, v)).astype(np.float32)
        b[:, v // 2 :] = 0.0  # ...and no source there either
        got = neumann_solve_pallas(
            jnp.asarray(m), jnp.asarray(b), hops=16, interpret=True,
            block_k=128, operand_dtype=jnp.bfloat16,
        )
        assert float(jnp.max(jnp.abs(got[:, v // 2 :]))) == 0.0

    def test_auto_tiling_past_vmem_cap(self):
        """V > MAX_VMEM_V dispatches to the tiled kernel automatically and
        matches the XLA propagation loop."""
        from repro.kernels.neumann.kernel import MAX_VMEM_V
        from repro.kernels.neumann.ops import _propagate_xla

        rng = np.random.RandomState(25)
        v = MAX_VMEM_V + 128
        m = _substochastic_batch(rng, 1, v)
        b = jnp.asarray(rng.uniform(0.0, 2.0, (1, v)).astype(np.float32))
        got = neumann_solve_pallas(m, b, hops=40, interpret=True)
        want = _propagate_xla(m, b, 40, 1e-6)
        scale = float(jnp.max(jnp.abs(want))) + 1e-30
        err = float(jnp.max(jnp.abs(got - want))) / scale
        assert err < 1e-5, err

    def test_nilpotency_contract_past_vmem_cap(self):
        """The PR's acceptance bar: a provably nilpotent operator at
        V > MAX_VMEM_V solves through the K-tiled kernel to LU accuracy."""
        rng = np.random.RandomState(26)
        v = 1280
        m = rng.uniform(0.0, 1.0, (1, v, v)).astype(np.float32)
        m *= np.triu(np.ones((v, v), np.float32), 1)
        m *= rng.rand(1, v, v) < (4.0 / v)  # sparse: finite, reachable sum
        mj, bj = jnp.asarray(m), jnp.asarray(
            rng.uniform(0.0, 1.0, (1, v)).astype(np.float32)
        )
        got = neumann_solve_pallas(mj, bj, hops=64, interpret=True)
        want = lu_solve_ref(mj, bj)
        scale = float(jnp.max(jnp.abs(want))) + 1e-30
        err = float(jnp.max(jnp.abs(got - want))) / scale
        assert err < 1e-5, err

    def test_block_k_must_be_lane_multiple(self):
        rng = np.random.RandomState(27)
        m = _substochastic_batch(rng, 1, 64)
        b = jnp.asarray(rng.uniform(0.0, 1.0, (1, 64)).astype(np.float32))
        with pytest.raises(ValueError, match="multiple"):
            neumann_solve_pallas(m, b, hops=4, interpret=True, block_k=100)

    @pytest.mark.skipif(
        int(os.environ.get("REPRO_BIG_KERNEL_V", "0")) < 1,
        reason="set REPRO_BIG_KERNEL_V to run the big-V parity sweeps",
    )
    def test_bigv_tiled_matches_xla(self):
        """CI kernels smoke: the K-tiled kernel at REPRO_BIG_KERNEL_V
        (1536 in CI — past MAX_VMEM_V) vs the XLA propagation loop."""
        from repro.kernels.neumann.ops import _propagate_xla

        rng = np.random.RandomState(29)
        v = int(os.environ["REPRO_BIG_KERNEL_V"])
        m = _substochastic_batch(rng, 1, v)
        b = jnp.asarray(rng.uniform(0.0, 2.0, (1, v)).astype(np.float32))
        got = neumann_solve_pallas(m, b, hops=32, interpret=True)
        want = _propagate_xla(m, b, 32, 1e-6)
        scale = float(jnp.max(jnp.abs(want))) + 1e-30
        err = float(jnp.max(jnp.abs(got - want))) / scale
        assert err < 1e-5, err

    def test_tiled_through_neumann_solve_wrapper(self):
        """block_k/operand_dtype thread through the public wrapper (and its
        custom_linear_solve) without disturbing the solution."""
        rng = np.random.RandomState(28)
        v = 96
        m = _nilpotent_batch(rng, 2, v)
        b = jnp.asarray(rng.uniform(0.0, 1.0, (2, v)).astype(np.float32))
        got = neumann_solve(
            m, b, hops=v + 1, use_pallas=True, interpret=True, block_k=128
        )
        want = lu_solve_ref(m, b)
        scale = float(jnp.max(jnp.abs(want)))
        np.testing.assert_allclose(
            np.asarray(got) / scale, np.asarray(want) / scale, atol=1e-5
        )


# ---------------------------------------------------------------------------
# Hop-bound plumbing
# ---------------------------------------------------------------------------
class TestHopBound:
    def test_scenarios_carry_bound(self):
        for name, make in SCENARIOS.items():
            p = make()
            assert p.hop_bound is not None, name
            assert 3 <= p.hop_bound <= p.net.n_nodes + 2, (name, p.hop_bound)

    def test_infer_matches_known_diameter(self):
        p = SCENARIOS["mesh"]()  # 5x5 grid: diameter 8
        assert infer_hop_bound(p.net) == 10

    def test_with_hop_bound_is_idempotent(self):
        p = SCENARIOS["iot"]()
        assert with_hop_bound(p) is p

    def test_effective_hops_floor_and_slack(self):
        from repro.kernels.neumann import NEUMANN_SLACK

        # The nilpotency-index bound V + 1 floors the cap (refined multipath
        # paths may exceed the diameter); larger carried bounds win.
        assert effective_hops(None, 16) == 16 + 1 + NEUMANN_SLACK
        assert effective_hops(5, 16) == 16 + 1 + NEUMANN_SLACK
        assert effective_hops(40, 16) == 40 + NEUMANN_SLACK
        # The fused kernel's fixed loop skips the V + 1 floor (every hop
        # executes, so the floor would cost O(V^3) wasted matvecs).
        assert effective_hops(5, 16, fixed_loop=True) == 5 + NEUMANN_SLACK
        assert effective_hops(None, 16, fixed_loop=True) == 17 + NEUMANN_SLACK

    def test_padding_preserves_bound(self):
        p = SCENARIOS["iot"]()
        padded, _ = pad_problem(p, p.net.n_nodes + 9, p.apps.n_apps + 3)
        assert padded.hop_bound == p.hop_bound

    def test_stacking_unifies_bound(self):
        fleet = [SCENARIOS["iot"](), SCENARIOS["mesh"]()]
        hb = unify_hop_bound(fleet)
        assert hb == max(p.hop_bound for p in fleet)
        stacked, _ = stack_problems(fleet)
        assert stacked.hop_bound == hb


# ---------------------------------------------------------------------------
# The nilpotency contract on real forwarding states (the tentpole's parity
# guarantee): SP-tree init + blocking-rule-refined phi give Neumann == LU.
# ---------------------------------------------------------------------------
def _traffic_both(problem, state):
    t_ne = stage_traffic(problem, state, solver="neumann")
    t_lu = stage_traffic(problem, state, solver="lu")
    return np.asarray(t_ne), np.asarray(t_lu)


class TestNilpotencyContract:
    @given(st.integers(8, 18), st.integers(0, 10_000), st.integers(0, 4))
    @settings(max_examples=10, deadline=None)
    def test_traffic_and_cost_to_go_match_lu(self, n, seed, sweeps):
        """Random SP-tree phi, refined by `sweeps` blocking-rule sweeps:
        both fixed points agree with dense LU to rtol 1e-5."""
        p = random_connected(n, max(2, n // 3), seed=seed, load_scale=0.6)
        s = structured_init(p)
        for _ in range(sweeps):
            s = forwarding_sweep(p, s, alpha=0.5)
        t_ne, t_lu = _traffic_both(p, s)
        scale = np.max(np.abs(t_lu)) + 1e-30
        np.testing.assert_allclose(t_ne / scale, t_lu / scale, atol=1e-5)
        q_ne = np.asarray(cost_to_go(p, s, solver="neumann")[0])
        q_lu = np.asarray(cost_to_go(p, s, solver="lu")[0])
        qs = np.max(np.abs(q_lu)) + 1e-30
        np.testing.assert_allclose(q_ne / qs, q_lu / qs, atol=1e-5)

    @given(st.integers(0, 10_000))
    @settings(max_examples=6, deadline=None)
    def test_contract_holds_with_padded_phantom_rows(self, seed):
        """Padded phantom apps/nodes (zero mass, zero rate) must not perturb
        the propagation solve relative to LU."""
        p = random_connected(11, 5, seed=seed, load_scale=0.6)
        padded, _ = pad_problem(p, 16, 9)
        s = structured_init(padded)
        s = forwarding_sweep(padded, s, alpha=0.5)
        t_ne, t_lu = _traffic_both(padded, s)
        scale = np.max(np.abs(t_lu)) + 1e-30
        np.testing.assert_allclose(t_ne / scale, t_lu / scale, atol=1e-5)
        # phantom coordinates stay exactly zero on the propagation path
        a, v = p.apps.n_apps, p.net.n_nodes
        assert float(np.max(np.abs(t_ne[a:]))) == 0.0
        assert float(np.max(np.abs(t_ne[:, :, v:]))) == 0.0

    def test_objective_parity_on_paper_scenarios(self):
        for name, make in SCENARIOS.items():
            p = make()
            s = structured_init(p)
            for _ in range(3):
                s = forwarding_sweep(p, s, alpha=0.5)
            J_ne, _ = objective(p, s, solver="neumann")
            J_lu, _ = objective(p, s, solver="lu")
            np.testing.assert_allclose(
                float(J_ne), float(J_lu), rtol=1e-5, err_msg=name
            )


# ---------------------------------------------------------------------------
# Fleet chunking rides the same solver path
# ---------------------------------------------------------------------------
class TestFleetChunking:
    def test_chunked_matches_unchunked(self):
        from repro.fleet import sample_fleet, solve_fleet

        fleet = sample_fleet(5, seed=17)
        kw = dict(m_max=3, t_phi=3)
        full = solve_fleet(fleet, **kw)
        chunked = solve_fleet(fleet, chunk_size=2, **kw)
        np.testing.assert_allclose(chunked.J, full.J, rtol=1e-3)
        assert chunked.n_instances == len(fleet)
        assert chunked.history.shape == full.history.shape
        # per-instance reporting works across chunk boundaries
        rows = chunked.per_instance()
        assert len(rows) == len(fleet)
        for row, p, mask in zip(rows, fleet, chunked.node_mask):
            n_real = int(mask.sum())
            assert len(row["hosts"]) == p.apps.n_apps
            assert max(max(h) for h in row["hosts"]) < n_real

    def test_hosts_clamped_against_node_mask(self):
        from repro.fleet import solve_fleet

        p = random_connected(9, 4, seed=23)
        res = solve_fleet([p, SCENARIOS["iot"]()], m_max=2, t_phi=3)
        # Forge a padded-envelope host leak; per_instance must clamp + flag.
        res.hosts[0, 0, 0] = res.node_mask.shape[1] - 1
        rows = res.per_instance()
        n_real = int(res.node_mask[0].sum())
        assert rows[0]["padded_host_leaks"] == 1
        assert max(max(h) for h in rows[0]["hosts"]) < n_real
