"""Unit + property tests for the paper's core algorithm (repro.core)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional_deps import given, settings, st

from repro.core import (
    SCENARIOS,
    CostModel,
    Problem,
    State,
    forwarding_mass,
    forwarding_sweep,
    forwarding_update,
    iot,
    mesh,
    objective,
    placement_update,
    solve_alt,
    solve_colocated,
    solve_congunaware,
    solve_oneshot,
    stage_traffic,
    structured_init,
    total_absorbed,
)
from repro.core import costs as core_costs
from repro.core.flow import objective_with_injection
from repro.core.marginals import cost_to_go
from repro.core.structs import BIG_THRESHOLD

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# Cost functions
# ---------------------------------------------------------------------------
class TestCosts:
    def test_mm1_matches_exact_below_knee(self):
        cm = CostModel()
        F = jnp.linspace(0.0, 0.9, 50)
        got = core_costs.link_cost(F, jnp.ones_like(F), cm)
        want = F / (1.0 - F)
        np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_mm1_zero_at_zero(self):
        cm = CostModel()
        assert float(core_costs.link_cost(jnp.array(0.0), jnp.array(3.0), cm)) == 0.0

    def test_mm1_c1_continuous_at_knee(self):
        cm = CostModel()
        mu = 2.0
        knee = cm.rho_max * mu
        eps = 1e-4
        slope = float(core_costs.link_cost_prime(jnp.array(knee), jnp.array(mu), cm))
        lo = float(core_costs.link_cost(jnp.array(knee - eps), jnp.array(mu), cm))
        hi = float(core_costs.link_cost(jnp.array(knee + eps), jnp.array(mu), cm))
        # Jump must be explained by the (large) local slope => C0 continuity.
        assert abs(hi - lo) <= 2.5 * slope * eps
        lo = float(core_costs.link_cost_prime(jnp.array(knee - eps), jnp.array(mu), cm))
        hi = float(core_costs.link_cost_prime(jnp.array(knee + eps), jnp.array(mu), cm))
        # Derivative jump is second-order small => C1 continuity.
        assert abs(hi - lo) / lo < 1e-2

    def test_prime_matches_autodiff(self):
        cm = CostModel()
        mu = jnp.array(5.0)
        for f in [0.5, 3.0, 4.7, 6.0, 20.0]:  # includes beyond-capacity points
            g = jax.grad(lambda x: core_costs.link_cost(x, mu, cm))(jnp.array(f))
            p = core_costs.link_cost_prime(jnp.array(f), mu, cm)
            np.testing.assert_allclose(g, p, rtol=1e-4)

    @given(
        st.floats(0.1, 50.0),
        st.floats(0.0, 3.0),
        st.floats(0.0, 3.0),
    )
    @settings(max_examples=50, deadline=None)
    def test_mm1_increasing_convex(self, mu, r1, r2):
        """D increasing and midpoint-convex on arbitrary load pairs."""
        cm = CostModel()
        f1, f2 = sorted((r1 * mu, r2 * mu))
        mu_ = jnp.array(mu)
        d1 = float(core_costs.link_cost(jnp.array(f1), mu_, cm))
        d2 = float(core_costs.link_cost(jnp.array(f2), mu_, cm))
        assert d2 >= d1 - 1e-6
        mid = float(core_costs.link_cost(jnp.array((f1 + f2) / 2), mu_, cm))
        assert mid <= (d1 + d2) / 2 + 1e-4 * (1 + abs(d1) + abs(d2))


# ---------------------------------------------------------------------------
# Flow / conservation invariants (Eqs. 2-6)
# ---------------------------------------------------------------------------
def _mass_violation(problem, state):
    n = problem.net.n_nodes
    mass = forwarding_mass(state, problem.apps, n)
    row = jnp.sum(state.phi, axis=-1)
    return float(jnp.max(jnp.abs(row - mass)))


@pytest.mark.parametrize("name", list(SCENARIOS))
class TestFlowInvariants:
    def test_init_feasible(self, name):
        p = SCENARIOS[name]()
        s = structured_init(p)
        assert _mass_violation(p, s) < 1e-5
        assert float(jnp.min(s.phi)) >= 0.0
        # x is one-hot per (a, p)
        np.testing.assert_allclose(jnp.sum(s.x, axis=-1), 1.0, atol=1e-6)

    def test_conservation_after_sweeps(self, name):
        p = SCENARIOS[name]()
        s = structured_init(p)
        for _ in range(5):
            s = forwarding_sweep(p, s, alpha=0.5)
        assert _mass_violation(p, s) < 1e-4
        absorbed = total_absorbed(p, s)
        np.testing.assert_allclose(absorbed, p.apps.lam, rtol=1e-4)

    def test_conservation_after_placement(self, name):
        p = SCENARIOS[name]()
        s = structured_init(p)
        s = forwarding_update(p, s, t_phi=3)
        s = placement_update(p, s)
        absorbed = total_absorbed(p, s)
        np.testing.assert_allclose(absorbed, p.apps.lam, rtol=1e-4)

    def test_stage_traffic_nonnegative(self, name):
        p = SCENARIOS[name]()
        s = structured_init(p)
        t = stage_traffic(p, s)
        assert float(jnp.min(t)) >= -1e-6

    def test_phi_only_on_edges(self, name):
        p = SCENARIOS[name]()
        s = structured_init(p)
        s = forwarding_update(p, s, t_phi=4)
        off_edge = jnp.where(p.net.adj[None, None] > 0, 0.0, s.phi)
        assert float(jnp.max(jnp.abs(off_edge))) == 0.0


# ---------------------------------------------------------------------------
# Marginals: Gallager's identity  q = dJ/d(injection)
# ---------------------------------------------------------------------------
class TestMarginals:
    @pytest.mark.parametrize("stage", [0, 1, 2])
    def test_cost_to_go_is_gradient(self, stage):
        p = mesh()
        s = structured_init(p)
        s = forwarding_update(p, s, t_phi=4)
        q, dp, kappa, t, F, G = cost_to_go(p, s)
        a = 3
        g = jax.grad(
            lambda inj: objective_with_injection(p, s, a, stage, inj)
        )(jnp.zeros(p.net.n_nodes))
        np.testing.assert_allclose(np.asarray(g), np.asarray(q[a, stage]), rtol=2e-3, atol=1e-4)

    def test_delta_min_always_proper(self):
        """The argmin out-link must survive the blocking rule (q_j* < q_i)."""
        p = iot()
        s = structured_init(p)
        s = forwarding_update(p, s, t_phi=3)
        from repro.core.marginals import link_marginals

        delta, aux = link_marginals(p, s)
        q = aux["q"]
        jstar = jnp.argmin(delta, axis=-1)
        q_star = jnp.take_along_axis(q, jstar.reshape(q.shape[0], 3, -1), axis=-1)
        n = p.net.n_nodes
        mass = forwarding_mass(s, p.apps, n)
        # Wherever a node must forward (mass > 0), q at its argmin link is
        # strictly below its own cost-to-go.
        viol = (q_star.reshape(q.shape) >= q) & (mass > 1e-6)
        assert not bool(jnp.any(viol))


# ---------------------------------------------------------------------------
# Forwarding update behaviour
# ---------------------------------------------------------------------------
class TestForwarding:
    def test_forwarding_reduces_comm_cost(self):
        p = iot(load_scale=0.7)
        s = structured_init(p)
        _, aux0 = objective(p, s)
        for _ in range(15):
            s = forwarding_sweep(p, s, alpha=0.5)
        _, aux1 = objective(p, s)
        # Placement fixed -> computation cost unchanged, communication falls.
        np.testing.assert_allclose(aux0["J_comp"], aux1["J_comp"], rtol=1e-4)
        assert float(aux1["J_comm"]) <= float(aux0["J_comm"]) * 1.0 + 1e-6

    def test_solver_stays_wellposed_many_sweeps(self):
        p = smallworld_problem = SCENARIOS["smallworld"]()
        s = structured_init(p)
        for _ in range(25):
            s = forwarding_sweep(p, s, alpha=0.7)
            t = stage_traffic(p, s)
            assert bool(jnp.all(jnp.isfinite(t)))


# ---------------------------------------------------------------------------
# End-to-end: the paper's headline comparisons (Fig. 2 ordering)
# ---------------------------------------------------------------------------
@pytest.mark.slow
class TestPaperClaims:
    def test_alt_beats_all_baselines_everywhere(self):
        for name, make in SCENARIOS.items():
            p = make()
            alt = solve_alt(p)
            for other in (solve_oneshot(p), solve_congunaware(p), solve_colocated(p)):
                assert alt.J <= other.J * 1.001, (name, other.name, alt.J, other.J)

    def test_alt_improves_on_init(self):
        for name, make in SCENARIOS.items():
            p = make()
            r = solve_alt(p)
            assert r.J <= r.history[0] * 1.0 + 1e-6, name

    def test_split_flexibility_matters_most_in_iot(self):
        """CoLocated/ALT ratio is far larger on the hierarchical IoT net."""
        ratios = {}
        for name in ("iot", "geant"):
            p = SCENARIOS[name]()
            ratios[name] = solve_colocated(p).J / solve_alt(p).J
        assert ratios["iot"] > ratios["geant"]

    def test_load_widens_absolute_gap(self):
        gaps = []
        for f in (0.5, 1.0):
            p = iot(load_scale=f)
            gap = solve_congunaware(p).J - solve_alt(p).J
            gaps.append(gap)
        assert gaps[1] > gaps[0] > 0


# ---------------------------------------------------------------------------
# Eta tradeoff plumbing (Fig. 5)
# ---------------------------------------------------------------------------
class TestEtaWeighting:
    def test_weighted_objective_composition(self):
        p = iot(cost=CostModel(w_comm=0.3, w_comp=0.7))
        s = structured_init(p)
        J, aux = objective(p, s)
        np.testing.assert_allclose(
            float(J), 0.3 * float(aux["J_comm"]) + 0.7 * float(aux["J_comp"]), rtol=1e-6
        )

    def test_extreme_eta_shifts_solution(self):
        comm_heavy = solve_alt(iot(cost=CostModel(w_comm=0.95, w_comp=0.05)))
        comp_heavy = solve_alt(iot(cost=CostModel(w_comm=0.05, w_comp=0.95)))
        # Optimizing mostly-communication should yield lower comm than the
        # mostly-computation solution, and vice versa.
        assert comm_heavy.J_comm < comp_heavy.J_comm
        assert comp_heavy.J_comp < comm_heavy.J_comp


# ---------------------------------------------------------------------------
# Randomized-network property tests (hypothesis)
# ---------------------------------------------------------------------------
class TestRandomNetworks:
    @given(st.integers(8, 20), st.integers(0, 10_000), st.floats(0.2, 0.9))
    @settings(max_examples=10, deadline=None)
    def test_invariants_hold_on_random_graphs(self, n, seed, alpha):
        from repro.core import random_connected

        p = random_connected(n, max(2, n // 3), seed=seed, load_scale=0.5)
        s = structured_init(p)
        for _ in range(3):
            s = forwarding_sweep(p, s, alpha=float(alpha))
        # conservation + feasibility + finiteness, any graph, any alpha
        absorbed = total_absorbed(p, s)
        np.testing.assert_allclose(
            np.asarray(absorbed), np.asarray(p.apps.lam), rtol=1e-3
        )
        assert float(jnp.min(s.phi)) >= 0.0
        J, _ = objective(p, s)
        assert np.isfinite(float(J))

    @pytest.mark.parametrize("seed", [11, 42, 1234])
    def test_alt_improves_on_random_networks(self, seed):
        from repro.core import random_connected

        p = random_connected(14, 6, seed=seed)
        r = solve_alt(p, m_max=8, t_phi=5)
        assert r.J <= r.history[0] * 1.0 + 1e-6
        assert np.isfinite(r.J)

    @given(st.integers(0, 500))
    @settings(max_examples=8, deadline=None)
    def test_placement_preserves_feasibility(self, seed):
        from repro.core import random_connected

        p = random_connected(12, 5, seed=seed, load_scale=0.7)
        s = structured_init(p)
        s = forwarding_update(p, s, t_phi=2)
        s2 = placement_update(p, s)
        # one-hot placement, consistent absorption, conserved flow
        np.testing.assert_allclose(np.asarray(jnp.sum(s2.x, axis=-1)), 1.0, atol=1e-6)
        absorbed = total_absorbed(p, s2)
        np.testing.assert_allclose(
            np.asarray(absorbed), np.asarray(p.apps.lam), rtol=1e-3
        )
