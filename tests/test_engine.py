"""Shared round-engine tests (core/engine.py).

The engine replaced two hand-rolled copies of Algorithm 1's outer loop (the
host-synced Python loop in core/alt.py and the fixed-length lax.scan in
fleet/solve.py) with one jitted while_loop. These tests pin:

  * parity — the while_loop path reproduces the pre-refactor Python loop's
    history / iters / J on all four paper topologies, for ALT and CoLocated,
    at rtol 1e-5 (the reference loop below IS the deleted solve_alt body);
  * early exit — the while_loop executes fewer trips than m_max once every
    instance has stalled, sequentially (B=1) and batched;
  * freeze masking — once an instance freezes, extra trips driven by
    still-live instances leave its results bit-identical;
  * the acceptance scenario — a converged B=12 fleet at the default
    tol/patience exits before its m_max budget.
"""
import jax
import numpy as np
import pytest

from repro.core import (
    SCENARIOS,
    forwarding_update,
    iot,
    placement_update,
    round_eval,
    solve_alt,
    solve_colocated,
    structured_init,
)
from repro.core.engine import engine_solve, engine_solve_single, stack_single
from repro.fleet import sample_fleet, solve_fleet, stack_problems

KW = dict(m_max=8, t_phi=5, alpha=0.5, tol=1e-3, patience=3)


def _reference_alt(problem, *, m_max, t_phi, alpha, tol, patience, colocate=False):
    """The pre-refactor `solve_alt` body, verbatim: a host-synced Python loop
    with a float(J) device->host round-trip every round. Kept here (and only
    here) as the parity oracle for the engine's while_loop."""
    state = structured_init(problem, colocate=colocate)
    J, aux = round_eval(problem, state)
    best_J, best_aux = float(J), aux
    history = [float(J)]
    iters = 0
    stall = 0
    for m in range(m_max):
        state = placement_update(problem, state, aux["ctg"], colocate=colocate)
        state = forwarding_update(problem, state, t_phi=t_phi, alpha=alpha)
        J, aux = round_eval(problem, state)
        jf = float(J)
        history.append(jf)
        iters = m + 1
        if jf < best_J * (1.0 - tol):
            stall = 0
        else:
            stall += 1
        if jf < best_J:
            best_J, best_aux = jf, aux
        if stall >= patience:
            break
    return {
        "J": best_J,
        "J_comm": float(best_aux["J_comm"]),
        "J_comp": float(best_aux["J_comp"]),
        "history": history,
        "iters": iters,
    }


# ---------------------------------------------------------------------------
# Parity: while_loop engine == pre-refactor Python loop
# ---------------------------------------------------------------------------
class TestParity:
    @pytest.mark.parametrize("name", list(SCENARIOS))
    def test_alt_matches_python_loop(self, name):
        p = SCENARIOS[name]()
        ref = _reference_alt(p, **KW)
        got = solve_alt(p, **KW)
        np.testing.assert_allclose(got.J, ref["J"], rtol=1e-5)
        np.testing.assert_allclose(got.J_comm, ref["J_comm"], rtol=1e-5)
        np.testing.assert_allclose(got.J_comp, ref["J_comp"], rtol=1e-5)
        assert got.iters == ref["iters"]
        np.testing.assert_allclose(got.history, ref["history"], rtol=1e-5)

    @pytest.mark.parametrize("name", list(SCENARIOS))
    def test_colocated_matches_python_loop(self, name):
        p = SCENARIOS[name]()
        ref = _reference_alt(p, colocate=True, **KW)
        got = solve_colocated(p, **KW)
        np.testing.assert_allclose(got.J, ref["J"], rtol=1e-5)
        assert got.iters == ref["iters"]
        np.testing.assert_allclose(got.history, ref["history"], rtol=1e-5)

    def test_single_is_engine_at_b1(self):
        """stack_single -> engine_solve == engine_solve_single, bitwise."""
        p = iot()
        kw = dict(colocate=False, track_best=True, **KW)
        batched = engine_solve(stack_single(p), **kw)
        single = engine_solve_single(p, **kw)
        np.testing.assert_array_equal(
            np.asarray(batched["J"][0]), np.asarray(single["J"])
        )
        np.testing.assert_array_equal(
            np.asarray(batched["history"][0]), np.asarray(single["history"])
        )


# ---------------------------------------------------------------------------
# Early exit: the while_loop stops before m_max once everything stalled
# ---------------------------------------------------------------------------
class TestEarlyExit:
    def test_sequential_early_exit(self):
        p = iot()
        out = engine_solve_single(
            p, m_max=30, t_phi=5, alpha=0.5, tol=1e-3, patience=3,
        )
        rounds = int(out["rounds"])
        assert rounds < 30
        assert rounds == int(out["iters"])
        # history past the exit point stays NaN (preallocated buffer)
        hist = np.asarray(out["history"])
        assert np.all(np.isnan(hist[rounds + 1 :]))
        assert not np.any(np.isnan(hist[: rounds + 1]))

    def test_batched_early_exit_tracks_slowest_instance(self):
        from repro.core import random_connected

        fleet = [iot(), random_connected(14, 6, seed=11)]
        stacked, _ = stack_problems(fleet)
        out = engine_solve(
            stacked, m_max=25, t_phi=5, alpha=0.5, tol=1e-3, patience=3,
        )
        iters = np.asarray(out["iters"])
        assert int(out["rounds"]) == int(iters.max()) < 25

    def test_converged_b12_fleet_exits_before_m_max(self):
        """Acceptance criterion: a converged B=12 fleet at the DEFAULT
        tol/patience executes fewer outer rounds than m_max."""
        fleet = sample_fleet(12, seed=7)
        res = solve_fleet(fleet, m_max=30, t_phi=5)  # default tol/patience
        assert res.n_instances == 12
        assert res.rounds < 30, (
            f"engine must exit early on a converged fleet (rounds={res.rounds})"
        )
        assert np.all(res.iters < 30)
        assert res.rounds == int(res.iters.max())


# ---------------------------------------------------------------------------
# Freeze masking: frozen instances are bit-identical under extra trips
# ---------------------------------------------------------------------------
class TestFreezeMasking:
    def test_frozen_instance_bits_survive_extra_rounds(self):
        """Solve [fast, slow] vs [fast, fast]: same compiled program (same
        shapes/statics), but the second run exits as soon as `fast` stalls
        while the first keeps looping for `slow`. Lane 0 must come out
        bit-identical — the extra trips only ever touch live lanes."""
        from repro.core import random_connected

        fast = random_connected(12, 5, seed=3, load_scale=0.4)
        slow = random_connected(12, 5, seed=4, load_scale=1.1)
        kw = dict(m_max=20, t_phi=5, alpha=0.5, tol=1e-3, patience=2)

        mixed = engine_solve(stack_problems([fast, slow])[0], **kw)
        alone = engine_solve(stack_problems([fast, fast])[0], **kw)
        # The premise: lane 0 froze while lane 1 kept the loop alive.
        assert int(mixed["iters"][0]) < int(mixed["rounds"])
        assert int(mixed["rounds"]) > int(alone["rounds"])

        for key in ("J", "J_comm", "J_comp", "iters"):
            np.testing.assert_array_equal(
                np.asarray(mixed[key][0]), np.asarray(alone[key][0])
            )
        np.testing.assert_array_equal(
            np.asarray(mixed["hosts"][0]), np.asarray(alone["hosts"][0])
        )
        np.testing.assert_array_equal(
            np.asarray(mixed["history"][0]), np.asarray(alone["history"][0])
        )
        frozen_state = jax.tree_util.tree_map(lambda x: x[0], mixed["state"])
        alone_state = jax.tree_util.tree_map(lambda x: x[0], alone["state"])
        np.testing.assert_array_equal(
            np.asarray(frozen_state.phi), np.asarray(alone_state.phi)
        )
        np.testing.assert_array_equal(
            np.asarray(frozen_state.x), np.asarray(alone_state.x)
        )
