"""Model zoo tests: per-arch smoke (reduced configs), decode-path consistency,
SSD and MoE oracles, and analytic-vs-actual parameter counts."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional_deps import given, settings, st

from repro.configs import ARCHS, get_config, reduced_config
from repro.models import (
    SHAPES,
    decode_step,
    init_caches,
    init_params,
    logits_fn,
    loss_fn,
    param_specs,
    prefill,
    shape_applicable,
)
from repro.models import layers as L
from repro.models import ssm as S

RNG = jax.random.PRNGKey(0)


def _batch_for(cfg, b, s, key=jax.random.PRNGKey(1)):
    if cfg.family == "encdec":
        return {
            "feats": jax.random.normal(key, (b, s, cfg.frontend_dim)),
            "dec_tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        }
    if cfg.frontend != "none":
        return {
            "feats": jax.random.normal(key, (b, s, cfg.frontend_dim)),
            "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
        }
    return {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}


def _no_drop(cfg):
    if cfg.family == "moe":
        return dataclasses.replace(
            cfg, capacity_factor=float(cfg.n_experts) / cfg.top_k + 1.0
        )
    return cfg


# ---------------------------------------------------------------------------
# required per-arch smoke tests (reduced config, one forward/train step)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = reduced_config(arch)
    params = init_params(cfg, RNG)
    b, s = 2, 64
    batch = _batch_for(cfg, b, s)
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss)), arch
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), arch
    logits = logits_fn(cfg, params, batch)
    assert logits.shape == (b, s, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = reduced_config(arch)
    params = init_params(cfg, RNG)
    b, kv_len = 2, 64
    caches = init_caches(cfg, b, kv_len)
    if cfg.family == "encdec":
        # cross-attn caches must be populated; use a short prefill instead.
        batch = _batch_for(cfg, b, 8)
        caches, _ = prefill(cfg, params, batch, kv_len)
    token = jnp.ones((b, 1), jnp.int32)
    logits, new_caches = decode_step(cfg, params, caches, token, jnp.int32(8))
    assert logits.shape == (b, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree_util.tree_structure(new_caches) == jax.tree_util.tree_structure(
        caches
    )


# ---------------------------------------------------------------------------
# decode == full-forward consistency (incl. ring-buffer wraparound)
# ---------------------------------------------------------------------------
CONSISTENCY_ARCHS = [
    "qwen1.5-0.5b",          # dense, full attention
    "gemma-2b",              # MQA, scaled embeddings
    "command-r-plus-104b",   # parallel block, tied embeddings
    "mamba2-370m",           # pure SSM
    "hymba-1.5b",            # hybrid + sliding window
    "mixtral-8x22b",         # MoE + sliding window
    "qwen2-moe-a2.7b",       # MoE + shared expert
    "seamless-m4t-medium",   # encoder-decoder
]


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_prefill_then_decode_matches_full_forward(arch):
    cfg = _no_drop(reduced_config(arch))
    params = init_params(cfg, RNG)
    b, t_pre, n_dec, total = 1, 17, 6, 64
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (b, t_pre + n_dec), 0, cfg.vocab)
    if cfg.family == "encdec":
        feats = jax.random.normal(key, (b, 24, cfg.frontend_dim))
        batch_pre = {"feats": feats, "dec_tokens": toks[:, :t_pre]}
        batch_full = {"feats": feats, "dec_tokens": toks}
    else:
        batch_pre = {"tokens": toks[:, :t_pre]}
        batch_full = {"tokens": toks}
    # bf16 compute: one ulp at logit magnitude ~1 is 2^-7 ~ 8e-3.
    tol = dict(rtol=2e-3, atol=1e-2)
    caches, logits_pre = prefill(cfg, params, batch_pre, total)
    full = logits_fn(cfg, params, batch_full)
    np.testing.assert_allclose(logits_pre[:, 0], full[:, t_pre - 1], **tol)
    # Autoregressive decode with the true tokens; every step must match.
    for i in range(n_dec - 1):
        pos = t_pre + i
        logits_dec, caches = decode_step(
            cfg, params, caches, toks[:, pos : pos + 1], jnp.int32(pos)
        )
        np.testing.assert_allclose(logits_dec[:, 0], full[:, pos], **tol)


def test_decode_past_ring_buffer_wrap():
    """SWA arch decoded past the window: ring slots are overwritten and the
    result still matches the windowed full forward."""
    cfg = reduced_config("hymba-1.5b")  # window reduced to 32
    params = init_params(cfg, RNG)
    b, t_pre, total = 1, 30, 48
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, total), 0, cfg.vocab)
    caches, _ = prefill(cfg, params, {"tokens": toks[:, :t_pre]}, total)
    full = logits_fn(cfg, params, {"tokens": toks})
    for pos in range(t_pre, total - 1):  # crosses slot 32 wraparound
        logits_dec, caches = decode_step(
            cfg, params, caches, toks[:, pos : pos + 1], jnp.int32(pos)
        )
        np.testing.assert_allclose(
            logits_dec[:, 0], full[:, pos], rtol=3e-3, atol=3e-3
        )


# ---------------------------------------------------------------------------
# SSD oracle: chunked dual form == naive sequential recurrence
# ---------------------------------------------------------------------------
def _ssd_sequential(xs, dt, A, B, C):
    b, l, h, p = xs.shape
    n = B.shape[-1]
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(l):
        dA = jnp.exp(dt[:, t] * A[None, :])  # [b, h]
        state = state * dA[:, :, None, None] + jnp.einsum(
            "bn,bh,bhp->bhpn", B[:, t], dt[:, t], xs[:, t]
        )
        ys.append(jnp.einsum("bn,bhpn->bhp", C[:, t], state))
    return jnp.stack(ys, axis=1), state


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_chunked_matches_sequential(chunk):
    rng = np.random.RandomState(chunk)
    b, l, h, p, n = 2, 24, 3, 4, 8
    xs = jnp.asarray(rng.randn(b, l, h, p), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, (b, l, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 4.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.randn(b, l, n), jnp.float32)
    C = jnp.asarray(rng.randn(b, l, n), jnp.float32)
    y_chunk, s_chunk = S.ssd_chunked(xs, dt, A, B, C, chunk)
    y_seq, s_seq = _ssd_sequential(xs, dt, A, B, C)
    np.testing.assert_allclose(y_chunk, y_seq, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s_chunk, s_seq, rtol=1e-4, atol=1e-4)


@given(
    st.integers(1, 3),
    st.integers(5, 40),
    st.integers(1, 4),
    st.sampled_from([2, 4, 8]),
    st.integers(0, 1000),
)
@settings(max_examples=15, deadline=None)
def test_ssd_chunked_property(b, l, h, chunk, seed):
    rng = np.random.RandomState(seed)
    p, n = 4, 4
    xs = jnp.asarray(rng.randn(b, l, h, p), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, l, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.2, 3.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.randn(b, l, n), jnp.float32)
    C = jnp.asarray(rng.randn(b, l, n), jnp.float32)
    y_chunk, _ = S.ssd_chunked(xs, dt, A, B, C, chunk)
    y_seq, _ = _ssd_sequential(xs, dt, A, B, C)
    np.testing.assert_allclose(y_chunk, y_seq, rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# MoE oracle: sort-based dispatch == naive per-token loop (no drops)
# ---------------------------------------------------------------------------
def test_moe_matches_naive_loop():
    cfg = _no_drop(reduced_config("mixtral-8x22b"))
    key = jax.random.PRNGKey(5)
    p = L.init_moe(key, cfg)
    b, s = 2, 16
    x = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    got = L.moe_apply(p, x.astype(jnp.bfloat16), cfg).astype(jnp.float32)

    # Naive: every token through its top-k experts.
    xf = x.reshape(-1, cfg.d_model)
    logits = (xf @ np.asarray(p["router"], np.float32)).astype(np.float32)
    out = np.zeros_like(np.asarray(xf))
    for t in range(xf.shape[0]):
        idx = np.argsort(-logits[t])[: cfg.top_k]
        w = jax.nn.softmax(jnp.asarray(logits[t][idx]))
        for e_i, e in enumerate(idx):
            wi = np.asarray(p["wi_e"][e], np.float32)
            wg = np.asarray(p["wg_e"][e], np.float32)
            wo = np.asarray(p["wo_e"][e], np.float32)
            h = (np.asarray(jax.nn.silu(jnp.asarray(xf[t] @ wg))) * (xf[t] @ wi)) @ wo
            out[t] += float(w[e_i]) * h
    np.testing.assert_allclose(got.reshape(-1, cfg.d_model), out, rtol=0.1, atol=0.05)


def test_moe_capacity_drops_are_bounded():
    """With tiny capacity the op must still be finite and shaped correctly."""
    cfg = dataclasses.replace(reduced_config("qwen2-moe-a2.7b"), capacity_factor=0.5)
    p = L.init_moe(jax.random.PRNGKey(6), cfg)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 32, cfg.d_model), jnp.bfloat16)
    y = L.moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))


# ---------------------------------------------------------------------------
# RoPE property: scores depend only on relative position
# ---------------------------------------------------------------------------
def test_rope_relative_property():
    rng = np.random.RandomState(9)
    q = jnp.asarray(rng.randn(1, 1, 1, 64), jnp.float32)
    k = jnp.asarray(rng.randn(1, 1, 1, 64), jnp.float32)
    def score(pq, pk):
        qr = L.rope(q, jnp.array([pq]), 10_000.0)
        kr = L.rope(k, jnp.array([pk]), 10_000.0)
        return float(jnp.sum(qr * kr))
    np.testing.assert_allclose(score(5, 3), score(105, 103), rtol=1e-4)
    np.testing.assert_allclose(score(17, 0), score(1017, 1000), rtol=1e-4)


# ---------------------------------------------------------------------------
# analytic parameter count == actual tree (full configs, eval_shape only)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_analytic(arch):
    cfg = get_config(arch)
    specs = param_specs(cfg)
    actual = sum(
        int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(specs)
    )
    analytic = cfg.n_params()
    # Analytic formula ignores norm scales / tiny vectors: within 0.5%.
    assert abs(actual - analytic) / analytic < 5e-3, (arch, actual, analytic)


def test_full_config_sizes_sane():
    """Spot-check the headline parameter counts (the names say the size)."""
    expect = {
        "command-r-plus-104b": (95e9, 115e9),
        "mixtral-8x22b": (130e9, 150e9),  # total (not active) params
        "gemma-2b": (2.0e9, 3.3e9),
        "qwen1.5-0.5b": (0.3e9, 0.7e9),
        "mamba2-370m": (0.3e9, 0.5e9),
        "hymba-1.5b": (1.2e9, 2.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo < n < hi, (arch, n)


def test_moe_grouped_dispatch_matches_global():
    """moe_groups=G == moe_groups=1 when capacity is no-drop (Perf iter 1)."""
    base = _no_drop(reduced_config("mixtral-8x22b"))
    p = L.init_moe(jax.random.PRNGKey(8), base)
    x = jax.random.normal(jax.random.PRNGKey(9), (4, 16, base.d_model), jnp.bfloat16)
    y1 = L.moe_apply(p, x, dataclasses.replace(base, moe_groups=1))
    y4 = L.moe_apply(p, x, dataclasses.replace(base, moe_groups=4))
    np.testing.assert_allclose(
        y1.astype(jnp.float32), y4.astype(jnp.float32), rtol=2e-2, atol=2e-2
    )
