"""Stage-generic core tests (ISSUE 5, DESIGN.md section 13).

The refactor made the partition count P (stages K = P + 1) per-`Problem`
data instead of a structural constant: `lax.scan` stage chains in
flow/marginals, a partition scan inside the placement sweep, a Viterbi-style
DP init, and phantom-stage padding for mixed-P fleets. What is pinned here:

  * P = 2 parity — the stage-generic primitives and the full `solve_alt` /
    `solve_fleet` reproduce the PRE-refactor implementation on all four
    paper topologies at rtol 1e-5. The oracle below is the deleted
    unrolled-t0/t1/t2 + q2->q1->q0 + pair-scan-init + explicit-h1/h2 code,
    kept verbatim (the test_engine.py oracle pattern);
  * phantom-stage inertness — the DESIGN.md section 9 contract extended to
    the stage axis: padding a P = 2 instance to a larger K is *bitwise*
    inert on J, real-stage traffic, and placements (hypothesis property);
  * P = 3 end-to-end — an IoT-tree scenario through `solve_fleet` with
    conservation and monotone best-iterate J, and a mixed-P fleet solved as
    one compiled padded batch;
  * K-sweep smoke — P = 1..4 x all four methods (the CI job that keeps
    stage-genericity from regressing to a P = 2 fast path).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional_deps import given, settings, st

from repro.core import (
    SCENARIOS,
    State,
    forwarding_mass,
    iot,
    placement_update,
    solve_alt,
    solve_colocated,
    stage_traffic,
    structured_init,
    total_absorbed,
)
from repro.core import costs as _costs
from repro.core.flow import stage_solve
from repro.core.marginals import cost_to_go
from repro.core.structs import BIG, BIG_THRESHOLD, app_live_mask, one_hot
from repro.kernels.minplus import apsp_with_nexthop
from repro.fleet import (
    METHODS,
    pad_problem_parts,
    sample_fleet,
    solve_fleet,
    solve_sequential,
)
from repro.fleet.generator import erdos_renyi, iot_hierarchy

KW = dict(m_max=6, t_phi=5, alpha=0.5, tol=1e-3, patience=3)


# ===========================================================================
# PRE-REFACTOR ORACLE — the deleted P = 2 implementation, kept verbatim.
# Unrolled stage chains, explicit h1/h2 sweep, joint pair-scan init. Only
# trivial renames (old_ prefixes) and the removal of jit decorators differ
# from the deleted source; every arithmetic expression is untouched.
# ===========================================================================
def old_forwarding_mass(state, apps, n):
    dst_oh = one_hot(apps.dst, n)  # [A, V]
    m0 = 1.0 - state.x[:, 0, :]
    m1 = 1.0 - state.x[:, 1, :]
    m2 = 1.0 - dst_oh
    return jnp.stack([m0, m1, m2], axis=1) * app_live_mask(apps)[:, None, None]


def old_stage_traffic(problem, state, *, solver="neumann"):
    n = problem.net.n_nodes
    apps = problem.apps
    src_oh = one_hot(apps.src, n)  # [A, V]
    solve = functools.partial(
        stage_solve, problem=problem, transpose=True, solver=solver
    )
    b0 = apps.lam[:, None] * src_oh
    t0 = solve(state.phi[:, 0], b0)
    b1 = state.x[:, 0, :] * t0
    t1 = solve(state.phi[:, 1], b1)
    b2 = state.x[:, 1, :] * t1
    t2 = solve(state.phi[:, 2], b2)
    return jnp.stack([t0, t1, t2], axis=1)


def old_loads(problem, state, t):
    apps = problem.apps
    f = t[..., :, None] * state.phi  # [A, K, V, V]
    F = jnp.einsum("ak,akij->ij", apps.L, f)
    G = jnp.einsum("ap,apv,apv->v", apps.w, state.x, t[:, :2, :])
    return F, G


def old_objective_from_loads(problem, F, G):
    net, cm = problem.net, problem.cost
    D = _costs.link_cost(F, net.mu, cm) * net.adj
    C = _costs.comp_cost(G, net.nu, cm)
    j_comm = jnp.sum(D)
    j_comp = jnp.sum(C)
    J = cm.w_comm * j_comm + cm.w_comp * j_comp
    return J, j_comm, j_comp


def old_cost_to_go(problem, state, *, solver="neumann"):
    t = old_stage_traffic(problem, state, solver=solver)
    F, G = old_loads(problem, state, t)
    cm = problem.cost
    dp = cm.w_comm * _costs.link_cost_prime(F, problem.net.mu, cm)
    dp = jnp.where(problem.net.adj > 0, dp, BIG)
    dp_edges = jnp.where(problem.net.adj > 0, dp, 0.0)
    cp = cm.w_comp * _costs.comp_cost_prime(G, problem.net.nu, cm)
    kappa = problem.apps.w[:, :, None] * cp[None, None, :]  # [A, P, V]
    L = problem.apps.L  # [A, 3]
    solve = functools.partial(
        stage_solve, problem=problem, transpose=False, solver=solver
    )

    def link_term(phi_k, Lk):
        return Lk * jnp.sum(phi_k * dp_edges[None, :, :], axis=-1)

    c2 = link_term(state.phi[:, 2], L[:, 2][:, None])
    q2 = solve(state.phi[:, 2], c2)
    c1 = link_term(state.phi[:, 1], L[:, 1][:, None])
    c1 = c1 + state.x[:, 1, :] * (kappa[:, 1, :] + q2)
    q1 = solve(state.phi[:, 1], c1)
    c0 = link_term(state.phi[:, 0], L[:, 0][:, None])
    c0 = c0 + state.x[:, 0, :] * (kappa[:, 0, :] + q1)
    q0 = solve(state.phi[:, 0], c0)

    q = jnp.stack([q0, q1, q2], axis=1)  # [A, K, V]
    return q, dp, kappa, t, F, G


def old_round_eval(problem, state, *, solver="neumann"):
    ctg = old_cost_to_go(problem, state, solver=solver)
    J, j_comm, j_comp = old_objective_from_loads(problem, ctg[4], ctg[5])
    return J, {"J": J, "J_comm": j_comm, "J_comp": j_comp, "ctg": ctg}


def old_link_marginals(problem, state, *, solver="neumann"):
    q, dp, kappa, t, F, G = old_cost_to_go(problem, state, solver=solver)
    L = problem.apps.L
    delta = L[:, :, None, None] * dp[None, None, :, :] + q[:, :, None, :]
    delta = jnp.where(problem.net.adj[None, None] > 0, delta, BIG)
    return delta, q


_PRUNE = 1e-9


def old_forwarding_sweep(problem, state, alpha=0.5, *, solver="neumann", mass=None):
    n = problem.net.n_nodes
    delta, q = old_link_marginals(problem, state, solver=solver)
    if mass is None:
        mass = old_forwarding_mass(state, problem.apps, n)
    delta_min = jnp.min(delta, axis=-1, keepdims=True)
    jstar = jnp.argmin(delta, axis=-1)
    jstar_oh = jax.nn.one_hot(jstar, n, dtype=state.phi.dtype)
    edge = delta < BIG_THRESHOLD
    gap = jnp.where(edge, delta - delta_min, 0.0)
    rel = gap / (jnp.abs(delta_min) + gap + 1e-12)
    rate = alpha * rel
    q_i = q[..., :, None]
    q_j = q[..., None, :]
    improper = ~(q_j < q_i)
    rate = jnp.where(improper, alpha, rate)
    phi = state.phi * (1.0 - rate)
    phi = jnp.where(phi < _PRUNE, 0.0, phi)
    phi = phi * (1.0 - jstar_oh)
    others = jnp.sum(phi, axis=-1)
    phi = phi + jstar_oh * jnp.maximum(mass - others, 0.0)[..., None]
    return State(x=state.x, phi=phi)


@functools.partial(jax.jit, static_argnames=("t_phi", "alpha"))
def old_forwarding_update(problem, state, *, t_phi=8, alpha=0.5):
    mass = old_forwarding_mass(state, problem.apps, problem.net.n_nodes)

    def body(_, s):
        return old_forwarding_sweep(problem, s, alpha=alpha, mass=mass)

    return jax.lax.fori_loop(0, t_phi, body, state)


def _old_sp_tree_phi(nexthop_to, target, mass, n):
    nh = nexthop_to[:, target]
    rows = jax.nn.one_hot(nh, n, dtype=jnp.float32)
    return rows * mass[:, None]


def old_repair_phi(problem, old, new, nexthop):
    n = problem.net.n_nodes
    apps = problem.apps
    old_hosts = old.hosts()
    new_hosts = new.hosts()

    def per_app(phi_a, oh, nh, dst):
        h1, h2 = nh[0], nh[1]
        m0 = 1.0 - jax.nn.one_hot(h1, n, dtype=jnp.float32)
        tree0 = _old_sp_tree_phi(nexthop, h1, m0, n)
        m1 = 1.0 - jax.nn.one_hot(h2, n, dtype=jnp.float32)
        tree1 = _old_sp_tree_phi(nexthop, h2, m1, n)
        changed1 = oh[0] != nh[0]
        changed2 = oh[1] != nh[1]
        phi0 = jnp.where(changed1, tree0, phi_a[0])
        phi1 = jnp.where(changed2, tree1, phi_a[1])
        return jnp.stack([phi0, phi1, phi_a[2]], axis=0)

    phi = jax.vmap(per_app)(new.phi, old_hosts, new_hosts, apps.dst)
    phi = phi * app_live_mask(apps)[:, None, None, None]
    return State(x=new.x, phi=phi)


@functools.partial(jax.jit, static_argnames=("colocate", "move_margin"))
def old_placement_update(problem, state, ctg=None, *, colocate=False, move_margin=0.02):
    n = problem.net.n_nodes
    apps = problem.apps
    if ctg is None:
        ctg = old_cost_to_go(problem, state)
    q, dp, kappa, t, F, G = ctg
    dist, nexthop = apsp_with_nexthop(dp)

    hosts = state.hosts()  # [A, 2]
    L = apps.L
    cm = problem.cost
    nu = problem.net.nu

    def cprime(Gv):
        return cm.w_comp * _costs.comp_cost_prime(Gv, nu, cm)

    dist_from_src = dist[apps.src, :]  # [A, V]
    dist_to_dst = dist[:, apps.dst].T  # [A, V]

    def body(Gv, inputs):
        (a_src_d, a_dst_d, h1_old, h2_old, lam_a, L_a, w_a) = inputs
        load1 = w_a[0] * lam_a
        load2 = w_a[1] * lam_a
        Gv = Gv - load1 * jax.nn.one_hot(h1_old, n) - load2 * jax.nn.one_hot(h2_old, n)

        def pick(S, h_old):
            cand = jnp.argmin(S).astype(jnp.int32)
            better = S[cand] < (1.0 - move_margin) * S[h_old]
            return jnp.where(better, cand, h_old).astype(jnp.int32)

        if colocate:
            S = (
                L_a[0] * a_src_d
                + (w_a[0] + w_a[1]) * cprime(Gv)
                + L_a[2] * a_dst_d
            )
            h1 = pick(S, h1_old)
            h2 = h1
            Gv = Gv + (load1 + load2) * jax.nn.one_hot(h1, n)
        else:
            S1 = L_a[0] * a_src_d + w_a[0] * cprime(Gv) + L_a[1] * dist[:, h2_old]
            h1 = pick(S1, h1_old)
            Gv = Gv + load1 * jax.nn.one_hot(h1, n)
            S2 = L_a[1] * dist[h1, :] + w_a[1] * cprime(Gv) + L_a[2] * a_dst_d
            h2 = pick(S2, h2_old)
            Gv = Gv + load2 * jax.nn.one_hot(h2, n)
        return Gv, (h1, h2)

    _, (h1, h2) = jax.lax.scan(
        body,
        G,
        (dist_from_src, dist_to_dst, hosts[:, 0], hosts[:, 1], apps.lam, L, apps.w),
    )

    x_new = jnp.stack([one_hot(h1, n), one_hot(h2, n)], axis=1)
    new_state = State(x=x_new, phi=state.phi)
    return old_repair_phi(problem, state, new_state, nexthop)


@functools.partial(jax.jit, static_argnames=("colocate",))
def old_structured_init(problem, *, colocate=False):
    n = problem.net.n_nodes
    apps = problem.apps

    dp0 = problem.cost.w_comm * _costs.link_cost_prime(
        jnp.zeros_like(problem.net.mu), problem.net.mu, problem.cost
    )
    dp0 = jnp.where(problem.net.adj > 0, dp0, BIG)
    dist, nexthop = apsp_with_nexthop(dp0)

    cp0 = problem.cost.w_comp * _costs.comp_cost_prime(
        jnp.zeros_like(problem.net.nu), problem.net.nu, problem.cost
    )
    kappa0 = apps.w[:, :, None] * cp0[None, None, :]  # [A, 2, V]

    L = apps.L
    dist_from_src = dist[apps.src, :]
    dist_to_dst = dist[:, apps.dst].T

    if colocate:
        S = (
            L[:, 0][:, None] * dist_from_src
            + kappa0[:, 0, :]
            + kappa0[:, 1, :]
            + L[:, 2][:, None] * dist_to_dst
        )
        h1 = jnp.argmin(S, axis=-1).astype(jnp.int32)
        h2 = h1
    else:
        S_pair = (
            L[:, 0][:, None, None] * dist_from_src[:, :, None]
            + kappa0[:, 0, :, None]
            + L[:, 1][:, None, None] * dist[None, :, :]
            + kappa0[:, 1, None, :]
            + L[:, 2][:, None, None] * dist_to_dst[:, None, :]
        )
        flat = jnp.argmin(S_pair.reshape(S_pair.shape[0], -1), axis=-1)
        h1 = (flat // n).astype(jnp.int32)
        h2 = (flat % n).astype(jnp.int32)

    x = jnp.stack([one_hot(h1, n), one_hot(h2, n)], axis=1)

    def per_app(h1a, h2a, dsta):
        m0 = 1.0 - jax.nn.one_hot(h1a, n, dtype=jnp.float32)
        m1 = 1.0 - jax.nn.one_hot(h2a, n, dtype=jnp.float32)
        m2 = 1.0 - jax.nn.one_hot(dsta, n, dtype=jnp.float32)
        return jnp.stack(
            [
                _old_sp_tree_phi(nexthop, h1a, m0, n),
                _old_sp_tree_phi(nexthop, h2a, m1, n),
                _old_sp_tree_phi(nexthop, dsta, m2, n),
            ],
            axis=0,
        )

    phi = jax.vmap(per_app)(h1, h2, apps.dst)
    phi = phi * app_live_mask(apps)[:, None, None, None]
    return State(x=x, phi=phi)


def oracle_alt(problem, *, m_max, t_phi, alpha, tol, patience, colocate=False):
    """The pre-refactor Algorithm-1 loop over the pre-refactor primitives:
    the end-to-end parity oracle for the stage-generic stack."""
    state = old_structured_init(problem, colocate=colocate)
    J, aux = old_round_eval(problem, state)
    best_J, best_aux = float(J), aux
    history = [float(J)]
    iters = 0
    stall = 0
    for m in range(m_max):
        state = old_placement_update(problem, state, aux["ctg"], colocate=colocate)
        state = old_forwarding_update(problem, state, t_phi=t_phi, alpha=alpha)
        J, aux = old_round_eval(problem, state)
        jf = float(J)
        history.append(jf)
        iters = m + 1
        if jf < best_J * (1.0 - tol):
            stall = 0
        else:
            stall += 1
        if jf < best_J:
            best_J, best_aux = jf, aux
        if stall >= patience:
            break
    return {
        "J": best_J,
        "J_comm": float(best_aux["J_comm"]),
        "J_comp": float(best_aux["J_comp"]),
        "history": history,
        "iters": iters,
    }


# ---------------------------------------------------------------------------
# P = 2 parity: stage-generic primitives == pre-refactor unrolled code
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", list(SCENARIOS))
class TestPrimitiveParity:
    def test_structured_init_bitwise(self, name):
        p = SCENARIOS[name]()
        s_new = structured_init(p)
        s_old = old_structured_init(p)
        np.testing.assert_array_equal(np.asarray(s_new.x), np.asarray(s_old.x))
        np.testing.assert_array_equal(np.asarray(s_new.phi), np.asarray(s_old.phi))

    def test_traffic_and_cost_to_go(self, name):
        p = SCENARIOS[name]()
        s = structured_init(p)
        t_new = stage_traffic(p, s)
        t_old = old_stage_traffic(p, s)
        np.testing.assert_allclose(
            np.asarray(t_new), np.asarray(t_old), rtol=1e-6, atol=1e-6
        )
        # q tolerates jit-vs-eager fusion differences (the oracle chain is
        # unjitted); the refactor's own budget is the 1e-5 parity bar.
        q_new = cost_to_go(p, s)[0]
        q_old = old_cost_to_go(p, s)[0]
        np.testing.assert_allclose(
            np.asarray(q_new), np.asarray(q_old), rtol=1e-5, atol=1e-5
        )

    def test_forwarding_mass(self, name):
        p = SCENARIOS[name]()
        s = structured_init(p)
        np.testing.assert_array_equal(
            np.asarray(forwarding_mass(s, p.apps, p.net.n_nodes)),
            np.asarray(old_forwarding_mass(s, p.apps, p.net.n_nodes)),
        )

    def test_placement_sweep_hosts(self, name):
        p = SCENARIOS[name]()
        s = structured_init(p)
        s = old_forwarding_update(p, s, t_phi=4)
        s_new = placement_update(p, s)
        s_old = old_placement_update(p, s)
        np.testing.assert_array_equal(
            np.asarray(s_new.hosts()), np.asarray(s_old.hosts())
        )
        np.testing.assert_allclose(
            np.asarray(s_new.phi), np.asarray(s_old.phi), rtol=1e-6, atol=1e-6
        )


# ---------------------------------------------------------------------------
# P = 2 parity: solve_alt / solve_fleet == the pre-refactor oracle loop
# ---------------------------------------------------------------------------
class TestEndToEndParity:
    @pytest.mark.parametrize("name", list(SCENARIOS))
    def test_solve_alt_matches_oracle(self, name):
        p = SCENARIOS[name]()
        ref = oracle_alt(p, **KW)
        got = solve_alt(p, **KW)
        np.testing.assert_allclose(got.J, ref["J"], rtol=1e-5)
        np.testing.assert_allclose(got.J_comm, ref["J_comm"], rtol=1e-5)
        np.testing.assert_allclose(got.J_comp, ref["J_comp"], rtol=1e-5)
        assert got.iters == ref["iters"]
        np.testing.assert_allclose(got.history, ref["history"], rtol=1e-5)

    @pytest.mark.parametrize("name", ["iot", "geant"])
    def test_solve_colocated_matches_oracle(self, name):
        p = SCENARIOS[name]()
        ref = oracle_alt(p, colocate=True, **KW)
        got = solve_colocated(p, **KW)
        np.testing.assert_allclose(got.J, ref["J"], rtol=1e-5)
        assert got.iters == ref["iters"]

    def test_solve_fleet_matches_oracle(self, name=None):
        """One padded batch over all four topologies vs the per-instance
        pre-refactor loop: the (V, A) padding must not cost the rtol-1e-5
        budget either."""
        fleet = [make() for make in SCENARIOS.values()]
        res = solve_fleet(fleet, **KW)
        for b, p in enumerate(fleet):
            ref = oracle_alt(p, **KW)
            np.testing.assert_allclose(res.J[b], ref["J"], rtol=1e-5)
            assert int(res.iters[b]) == ref["iters"]


# ---------------------------------------------------------------------------
# Phantom-stage inertness (DESIGN.md section 9 extended to the stage axis)
# ---------------------------------------------------------------------------
class TestPhantomStageInertness:
    def _assert_inert(self, p, k_env):
        """Padding a problem to K = k_env stages is bitwise-inert."""
        pp = pad_problem_parts(p, k_env - 1)
        assert pp.apps.n_stages == k_env

        s0 = structured_init(p)
        s1 = structured_init(pp)
        n_parts = p.apps.n_parts
        # placements of the real partitions: bitwise
        np.testing.assert_array_equal(
            np.asarray(s1.hosts())[:, :n_parts], np.asarray(s0.hosts())
        )
        # real-stage traffic bitwise, phantom stages exactly zero
        k_real = p.apps.n_stages
        t0, t1 = np.asarray(stage_traffic(p, s0)), np.asarray(stage_traffic(pp, s1))
        np.testing.assert_array_equal(t1[:, :k_real], t0)
        assert float(np.abs(t1[:, k_real:]).max(initial=0.0)) == 0.0

        r0 = solve_alt(p, m_max=4, t_phi=3)
        r1 = solve_alt(pp, m_max=4, t_phi=3)
        assert r0.J == r1.J  # bitwise
        assert r0.iters == r1.iters
        np.testing.assert_array_equal(r0.history, r1.history)
        np.testing.assert_array_equal(
            np.asarray(r1.state.hosts())[:, :n_parts],
            np.asarray(r0.state.hosts()),
        )
        # conservation still holds on the padded chain
        ab = total_absorbed(pp, r1.state)
        np.testing.assert_allclose(
            np.asarray(ab), np.asarray(pp.apps.lam), rtol=1e-3
        )

    def test_paper_iot_padded_to_k5(self):
        """The ISSUE acceptance anchor: P=2 padded to K=5."""
        self._assert_inert(iot(), 5)

    @given(
        seed=st.integers(0, 10_000),
        k_env=st.integers(4, 6),
        base_parts=st.integers(1, 3),
    )
    @settings(max_examples=6, deadline=None)
    def test_property_padding_bitwise_inert(self, seed, k_env, base_parts):
        p = erdos_renyi(12, 5, seed=seed, n_parts=base_parts)
        self._assert_inert(p, max(k_env, base_parts + 2))


# ---------------------------------------------------------------------------
# P = 3 end-to-end + mixed-P fleets (acceptance criteria)
# ---------------------------------------------------------------------------
class TestDeepSplits:
    def test_p3_iot_tree_end_to_end(self):
        """A P = 3 IoT-tree scenario through solve_fleet(shard=True):
        conservation + monotone best-iterate J. On a single-device run the
        mesh plan falls back explicitly (reason='single-device'); the
        multidevice CI job runs this same path truly sharded."""
        fleet = [iot_hierarchy(seed=s, n_apps=6, n_parts=3) for s in range(4)]
        assert all(p.apps.n_parts == 3 for p in fleet)
        res = solve_fleet(fleet, method="ALT", m_max=6, t_phi=4, shard=True)
        assert res.shard.requested
        assert np.all(np.isfinite(res.J))
        # monotone best-iterate: the returned J never exceeds any history row
        hist = res.history
        assert np.all(res.J <= np.nanmin(hist, axis=1) * (1 + 1e-6))
        # conservation on the final state of each instance, re-solved at B=1
        for p in fleet:
            r = solve_alt(p, m_max=6, t_phi=4)
            ab = total_absorbed(p, r.state)
            np.testing.assert_allclose(
                np.asarray(ab), np.asarray(p.apps.lam), rtol=1e-3
            )

    def test_mixed_p_fleet_single_padded_batch(self):
        """P in {1, 2, 3} solves as ONE compiled padded batch and matches the
        per-instance sequential path."""
        fleet = sample_fleet(6, seed=11, partitions=(1, 2, 3))
        assert sorted({p.apps.n_parts for p in fleet}) == [1, 2, 3]
        res = solve_fleet(fleet, m_max=4, t_phi=4)
        # one batch: everything padded to the max split depth's envelope
        assert res.hosts.shape[-1] == 3
        seq = solve_sequential(fleet, m_max=4, t_phi=4)
        for b, r in enumerate(seq):
            np.testing.assert_allclose(res.J[b], r.J, rtol=1e-3)
        rows = res.per_instance()
        assert [r["partitions"] for r in rows] == [1, 2, 3, 1, 2, 3]
        for row, p in zip(rows, fleet):
            assert all(len(h) == p.apps.n_parts for h in row["hosts"])

    def test_per_app_heterogeneous_parts(self):
        """`Apps.parts` is per-app: one problem may mix split depths."""
        import dataclasses

        p = iot(n_parts=3)
        parts = np.full(p.apps.n_apps, 3, np.int32)
        parts[::2] = 2  # every other app splits only twice
        apps = dataclasses.replace(p.apps, parts=jnp.asarray(parts))
        p = dataclasses.replace(p, apps=apps)
        s = structured_init(p)
        ab = total_absorbed(p, s)
        np.testing.assert_allclose(
            np.asarray(ab), np.asarray(p.apps.lam), rtol=1e-3
        )
        r = solve_alt(p, m_max=3, t_phi=3)
        assert np.isfinite(r.J)
        ab = total_absorbed(p, r.state)
        np.testing.assert_allclose(
            np.asarray(ab), np.asarray(p.apps.lam), rtol=1e-3
        )


# ---------------------------------------------------------------------------
# K-sweep smoke: P = 1..4 x all four methods (the CI regression gate)
# ---------------------------------------------------------------------------
class TestKSweep:
    @pytest.mark.parametrize("n_parts", [1, 2, 3, 4])
    def test_all_methods_all_depths(self, n_parts):
        fleet = [
            iot_hierarchy(seed=0, n_edge=3, devices_per_edge=2, n_apps=4,
                          n_parts=n_parts),
            erdos_renyi(10, 4, seed=1, n_parts=n_parts),
        ]
        for method in METHODS:
            res = solve_fleet(fleet, method=method, m_max=2, t_phi=3)
            assert np.all(np.isfinite(res.J)), (method, n_parts)
            assert np.all(res.J > 0), (method, n_parts)
        # ALT at B=1 keeps conservation at every depth
        r = solve_alt(fleet[0], m_max=2, t_phi=3)
        ab = total_absorbed(fleet[0], r.state)
        np.testing.assert_allclose(
            np.asarray(ab), np.asarray(fleet[0].apps.lam), rtol=1e-3
        )

    def test_mixed_depth_smoke(self):
        fleet = sample_fleet(4, seed=2, partitions=(1, 2, 3, 4))
        res = solve_fleet(fleet, m_max=2, t_phi=3)
        assert np.all(np.isfinite(res.J))
        assert res.hosts.shape[-1] == 4
