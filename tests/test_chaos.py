"""Fault-injection suite: chaos traces, repair semantics, warm-start parity,
input validation, and the epoch controller (DESIGN.md section 15).

The load-bearing contracts:
  * failure inertness — after ANY event sequence, no live partition is
    hosted on a masked-out node (hypothesis property), and repair leaves
    no phi mass flowing INTO dead nodes;
  * empty-trace stability — repairing with an all-live mask is bitwise
    identity on the State;
  * warm-start parity — a frozen warm lane returns exactly its init-state
    evaluation; an active warm re-solve from the cold optimum matches the
    cold objective at rtol 1e-5 on all four paper topologies;
  * the controller never ends an epoch without a servable placement.
"""
from __future__ import annotations

import dataclasses
import functools
import json

import numpy as np
import pytest

from repro.chaos import (
    NODE_DOWN,
    InstanceHealth,
    apply_health,
    generate_trace,
    repair_fleet,
)
from repro.core.scenarios import SCENARIOS
from repro.core.structs import BIG
from repro.fleet import (
    EmptyFleetError,
    NU_PAD,
    iot_hierarchy,
    pad_batch_to_multiple,
    sample_fleet,
    solve_fleet,
)

from _optional_deps import given, settings, st


def _small_fleet(n=3, seed=11):
    return sample_fleet(n, families=["iot_hierarchy"], seed=seed)


SOLVE_KW = dict(m_max=3, t_phi=3, round_to=8)


@functools.lru_cache(maxsize=1)
def _property_fixture():
    """One solved fleet shared by every hypothesis example (the property
    varies the EVENT sequence, not the solve)."""
    fleet = _small_fleet(3, seed=77)
    state = solve_fleet(fleet, keep_state=True, **SOLVE_KW).state
    return fleet, state


# ---------------------------------------------------------------------------
# Trace generation
# ---------------------------------------------------------------------------
def test_trace_deterministic_and_counted():
    fleet = _small_fleet()
    t1 = generate_trace(fleet, 12, seed=5, node_failures=3,
                        link_degradations=2, flash_crowds=1)
    t2 = generate_trace(fleet, 12, seed=5, node_failures=3,
                        link_degradations=2, flash_crowds=1)
    assert t1.events == t2.events
    c = t1.counts()
    assert c["node_down"] == 3
    assert c["link_degrade"] == 2
    assert c["flash_crowd"] == 1
    # recoveries never outnumber their faults
    assert c["node_up"] <= c["node_down"]
    assert c["link_restore"] <= c["link_degrade"]


def test_trace_never_kills_endpoints_or_disconnects():
    fleet = _small_fleet(4, seed=2)
    trace = generate_trace(fleet, 20, seed=9, node_failures=6,
                           link_degradations=3, flash_crowds=1)
    from repro.chaos.events import _connected_without, _protected_nodes

    protected = [_protected_nodes(p) for p in fleet]
    for _, fired, healths in trace.timeline():
        for ev in fired:
            if ev.kind == NODE_DOWN:
                assert ev.node not in protected[ev.instance]
        for i, h in enumerate(healths):
            if h.down:
                adj = np.asarray(fleet[i].net.adj)
                assert _connected_without(adj, h.down)


def test_apply_health_uses_pad_encoding():
    p = iot_hierarchy(seed=1, n_edge=3, devices_per_edge=2, n_apps=4)
    dead = next(
        v for v in range(p.net.n_nodes)
        if v not in set(map(int, np.asarray(p.apps.src)))
        | set(map(int, np.asarray(p.apps.dst)))
    )
    h = InstanceHealth(down=frozenset({dead}), rate_scale=2.0)
    q, live = apply_health(p, h)
    assert live[dead] == 0.0 and live.sum() == p.net.n_nodes - 1
    adj = np.asarray(q.net.adj)
    mu = np.asarray(q.net.mu)
    nu = np.asarray(q.net.nu)
    assert (adj[dead, :] == 0).all() and (adj[:, dead] == 0).all()
    assert (mu[dead, :] == BIG).all() and (mu[:, dead] == BIG).all()
    assert nu[dead] == np.float32(NU_PAD)
    np.testing.assert_allclose(
        np.asarray(q.apps.lam), np.asarray(p.apps.lam) * 2.0, rtol=1e-6
    )
    # pristine health is a structural no-op (same object, same program)
    q2, live2 = apply_health(p, InstanceHealth())
    assert q2 is p and live2.all()
    # perturbation never changes shapes or static metadata
    assert q.hop_bound == p.hop_bound
    assert q.net.adj.shape == p.net.adj.shape


def test_link_degrade_scales_both_directions():
    p = iot_hierarchy(seed=1, n_edge=3, devices_per_edge=2, n_apps=4)
    adj = np.asarray(p.net.adj)
    u, v = map(int, np.argwhere(np.triu((adj > 0) | (adj.T > 0), 1))[0])
    h = InstanceHealth(link_scale=(((u, v), 0.5),))
    q, live = apply_health(p, h)
    assert live.all()
    mu0, mu1 = np.asarray(p.net.mu), np.asarray(q.net.mu)
    for a, b in ((u, v), (v, u)):
        if adj[a, b] > 0:
            np.testing.assert_allclose(mu1[a, b], mu0[a, b] * 0.5, rtol=1e-6)


# ---------------------------------------------------------------------------
# Repair semantics
# ---------------------------------------------------------------------------
def test_repair_identity_on_empty_trace():
    fleet, state = _property_fixture()
    masks = [np.ones(p.net.n_nodes, np.float32) for p in fleet]
    rep = repair_fleet(fleet, state, masks, round_to=8)
    assert (np.asarray(rep.x) == np.asarray(state.x)).all()
    assert (np.asarray(rep.phi) == np.asarray(state.phi)).all()


def _assert_no_dead_hosting(fleet, state, masks):
    hosts = np.asarray(state.hosts())
    for b, m in enumerate(masks):
        m = np.asarray(m)
        parts = np.asarray(fleet[b].apps.parts)
        for a in range(parts.size):
            hs = hosts[b, a, : int(parts[a])]
            assert (hs < m.size).all(), f"instance {b} app {a}: host on pad"
            assert (m[hs] > 0).all(), (
                f"instance {b} app {a}: live partition on dead node "
                f"(hosts {hs}, dead {np.flatnonzero(m == 0)})"
            )


def test_repair_evicts_and_cleans_phi():
    fleet = _small_fleet(3, seed=21)
    res = solve_fleet(fleet, keep_state=True, **SOLVE_KW)
    trace = generate_trace(fleet, 14, seed=3, node_failures=4,
                           link_degradations=2, flash_crowds=1)
    checked = 0
    for _, fired, healths in trace.timeline():
        if not fired:
            continue
        pairs = [apply_health(p, h) for p, h in zip(fleet, healths)]
        probs = [q for q, _ in pairs]
        masks = [m for _, m in pairs]
        rep = repair_fleet(probs, res.state, masks, round_to=8)
        _assert_no_dead_hosting(fleet, rep, masks)
        # No phi mass flows INTO a dead node after repair: forced stages are
        # rebuilt as shortest-path trees on the adj-gated metric, where any
        # hop into a dead node costs BIG.
        phi = np.asarray(rep.phi)
        for b, m in enumerate(masks):
            dead = np.flatnonzero(np.asarray(m) == 0)
            if dead.size:
                checked += 1
                assert phi[b][..., dead].sum() == 0.0
    assert checked > 0, "trace produced no dead-node epochs to check"


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**16),
    n_fail=st.integers(min_value=0, max_value=5),
    n_deg=st.integers(min_value=0, max_value=3),
    n_crowd=st.integers(min_value=0, max_value=1),
)
def test_property_no_partition_on_masked_node(seed, n_fail, n_deg, n_crowd):
    """After ANY generated event sequence, repair leaves no live partition
    on a masked-out node, and the perturbed problems stay finite."""
    fleet, state = _property_fixture()
    trace = generate_trace(
        fleet, 10, seed=seed, node_failures=n_fail,
        link_degradations=n_deg, flash_crowds=n_crowd,
    )
    for _, fired, healths in trace.timeline():
        if not fired:
            continue
        pairs = [apply_health(p, h) for p, h in zip(fleet, healths)]
        probs = [q for q, _ in pairs]
        masks = [m for _, m in pairs]
        for q in probs:
            assert np.isfinite(np.asarray(q.net.mu)).all()
            assert np.isfinite(np.asarray(q.net.nu)).all()
            assert np.isfinite(np.asarray(q.apps.lam)).all()
        rep = repair_fleet(probs, state, masks, round_to=8)
        _assert_no_dead_hosting(fleet, rep, masks)


# ---------------------------------------------------------------------------
# Warm start
# ---------------------------------------------------------------------------
def test_warm_start_frozen_lane_returns_init_eval():
    fleet, _ = _property_fixture()
    cold = solve_fleet(fleet, keep_state=True, **SOLVE_KW)
    warm = solve_fleet(
        fleet, warm_start=cold.state,
        warm_active=np.zeros(len(fleet), bool), keep_state=True, **SOLVE_KW
    )
    # All lanes frozen: zero engine trips, state bitwise-carried, J is the
    # evaluation of the warm state itself.
    assert warm.rounds == 0
    assert (warm.iters == 0).all()
    assert (np.asarray(warm.state.x) == np.asarray(cold.state.x)).all()
    assert (np.asarray(warm.state.phi) == np.asarray(cold.state.phi)).all()
    np.testing.assert_allclose(warm.J, cold.J, rtol=1e-5)


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_warm_start_parity_all_topologies(name):
    """Warm re-solving FROM the cold optimum must keep the objective within
    rtol 1e-5 of the cold solve on every paper topology — the warm path may
    only hold or improve J (best-iterate tracking), never lose it."""
    p = SCENARIOS[name]()
    cold = solve_fleet([p], m_max=6, t_phi=4, keep_state=True)
    frozen = solve_fleet(
        [p], m_max=6, t_phi=4, warm_start=cold.state,
        warm_active=np.array([False]),
    )
    np.testing.assert_allclose(frozen.J, cold.J, rtol=1e-5)
    active = solve_fleet(
        [p], m_max=6, t_phi=4, warm_start=cold.state,
        warm_active=np.array([True]),
    )
    assert np.isfinite(active.J).all()
    assert active.J[0] <= cold.J[0] * (1.0 + 1e-5)


def test_warm_start_shape_mismatch_raises():
    fleet, state = _property_fixture()
    with pytest.raises(ValueError, match="envelope"):
        solve_fleet(fleet[:2], warm_start=state, **SOLVE_KW)


def test_warm_start_guards():
    fleet, state = _property_fixture()
    with pytest.raises(ValueError, match="warm_active requires"):
        solve_fleet(fleet, warm_active=np.ones(3, bool), **SOLVE_KW)
    with pytest.raises(ValueError, match="CongUnaware"):
        solve_fleet(fleet, method="CongUnaware", warm_start=state, **SOLVE_KW)
    with pytest.raises(ValueError, match="single-chunk"):
        solve_fleet(fleet, warm_start=state, chunk_size=2, **SOLVE_KW)


# ---------------------------------------------------------------------------
# solve_fleet input validation + pad edge cases
# ---------------------------------------------------------------------------
def test_validation_rejects_nonfinite_and_dead():
    fleet = _small_fleet()
    lam = np.asarray(fleet[1].apps.lam).astype(np.float32).copy()
    lam[0] = np.nan
    bad = dataclasses.replace(
        fleet[1], apps=dataclasses.replace(fleet[1].apps, lam=lam)
    )
    with pytest.raises(ValueError, match="instance 1.*lam"):
        solve_fleet([fleet[0], bad], **SOLVE_KW)

    all_dead = dataclasses.replace(
        fleet[0],
        net=dataclasses.replace(
            fleet[0].net,
            nu=np.full(fleet[0].net.n_nodes, NU_PAD, np.float32),
        ),
    )
    with pytest.raises(ValueError, match="instance 0.*stage 0.*live-host"):
        solve_fleet([all_dead], **SOLVE_KW)

    nu = np.asarray(fleet[0].net.nu).astype(np.float32).copy()
    nu[int(np.asarray(fleet[0].apps.src)[0])] = NU_PAD
    dead_src = dataclasses.replace(
        fleet[0], net=dataclasses.replace(fleet[0].net, nu=nu)
    )
    with pytest.raises(ValueError, match="src node.*dead"):
        solve_fleet([dead_src], **SOLVE_KW)


def test_empty_fleet_typed_errors():
    with pytest.raises(EmptyFleetError):
        pad_batch_to_multiple([], 4)
    p = iot_hierarchy(seed=1, n_edge=3, devices_per_edge=2, n_apps=4)
    dead = dataclasses.replace(
        p,
        net=dataclasses.replace(
            p.net, nu=np.full(p.net.n_nodes, NU_PAD, np.float32)
        ),
    )
    with pytest.raises(EmptyFleetError, match="dead"):
        pad_batch_to_multiple([dead, dead], 4)
    with pytest.raises(EmptyFleetError):
        solve_fleet([], **SOLVE_KW)


# ---------------------------------------------------------------------------
# Controller
# ---------------------------------------------------------------------------
def test_controller_every_epoch_servable():
    from repro.launch.control import run_control

    fleet = _small_fleet(3, seed=31)
    ctl = run_control(
        fleet, epochs=6, seed=13, m_max=3, t_phi=3, round_to=8,
        trace_kwargs=dict(
            node_failures=2, link_degradations=1, flash_crowds=1
        ),
    )
    s = ctl.summary()
    assert s["epochs"] == 6
    assert s["feasible_fraction"] == 1.0
    assert s["nonfinite_epochs"] == 0
    # epoch 0 is the cold bootstrap; later epochs warm-start
    assert ctl.reports[0].mode == "cold"
    assert all(r.mode == "warm" for r in ctl.reports[1:])
    # event-free epochs freeze the whole batch: zero engine trips
    quiet = [r for r in ctl.reports[1:] if r.perturbed == 0]
    assert all(r.rounds == 0 for r in quiet)


def test_controller_cli_smoke(tmp_path):
    from repro.launch.control import main

    out = tmp_path / "control.json"
    events = tmp_path / "events.json"
    rc = main([
        "--instances", "2", "--epochs", "5", "--seed", "4",
        "--node-failures", "1", "--link-degradations", "1",
        "--flash-crowds", "0", "--m-max", "2", "--t-phi", "2",
        "--json-out", str(out), "--events-out", str(events),
        "--assert-feasible",
    ])
    assert rc == 0
    payload = json.loads(out.read_text())
    assert payload["summary"]["feasible_fraction"] == 1.0
    assert len(payload["epochs"]) == 5
    sched = json.loads(events.read_text())
    assert sched["counts"]["node_down"] == 1
