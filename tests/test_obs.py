"""Observability subsystem tests (ISSUE 6 / DESIGN.md section 14).

Three layers, three contracts:

  * on-device round traces (core/engine.py `EngineTrace` -> `FleetTrace`):
    the trace buffers obey the exact NaN-past-freeze contract of the J
    history, frozen lanes stay *bitwise*-inert to extra trips, tracing
    on/off never changes a solved bit, and sharded == unsharded traces;
  * host spans (obs/trace.py): nesting, disabled no-op, JSONL + Chrome
    serialization, and the `repro.obs.validate` schema checker both in the
    accepting and the rejecting direction;
  * metrics registry (obs/metrics.py): get-or-create semantics, type-reuse
    errors, histogram percentiles, snapshot shape.
"""
import json

import jax
import numpy as np
import pytest

from repro.core import random_connected
from repro.core.engine import engine_solve, engine_solve_single
from repro.fleet import sample_fleet, solve_fleet, stack_problems
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.roundtrace import FleetTrace
from repro.obs.validate import validate_events, validate_lines

N_DEV = len(jax.devices())
needs_mesh = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >= 2 devices; run under "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

KW = dict(m_max=8, t_phi=5, alpha=0.5, tol=1e-3, patience=3)


@pytest.fixture(autouse=True)
def _clean_global_obs_state():
    """Tests below enable the process-wide tracer/registry; leave none of
    that behind for other test modules."""
    yield
    obs_trace.TRACER.enabled = False
    obs_trace.TRACER.jsonl_path = None
    obs_trace.TRACER.chrome_path = None
    obs_trace.reset()
    obs_metrics.registry.reset()


# ---------------------------------------------------------------------------
# Layer 1: on-device round traces
# ---------------------------------------------------------------------------
class TestEngineTrace:
    def test_trace_nan_past_freeze_matches_history(self):
        """The trace buffers inherit the history's freeze mask exactly:
        NaN wherever the round was not applied, and `live` is that mask in
        arithmetic form."""
        fleet = sample_fleet(4, seed=11)
        res = solve_fleet(fleet, m_max=10, t_phi=4, patience=2)
        t = res.trace
        hist_nan = np.isnan(res.history)
        assert np.array_equal(np.isnan(t.J_comm), hist_nan)
        assert np.array_equal(np.isnan(t.J_comp), hist_nan)
        assert np.array_equal(np.isnan(t.moves), hist_nan)
        assert np.array_equal(t.live > 0, ~hist_nan)
        # live[b, m] == 1  <=>  m <= iters[b]
        for b in range(res.n_instances):
            applied = np.flatnonzero(t.live[b] > 0)
            assert applied[-1] == int(res.iters[b])
        # Column 0 is the structured init: applied to everyone, zero churn.
        assert np.all(t.live[:, 0] == 1.0)
        assert np.all(t.moves[:, 0] == 0.0)

    def test_trace_objective_split_consistent(self):
        """Per-round J_comm + J_comp == history J wherever applied, and
        best_round points at the history's minimum."""
        fleet = sample_fleet(4, seed=12)
        res = solve_fleet(fleet, m_max=10, t_phi=4, patience=2)
        t = res.trace
        applied = ~np.isnan(res.history)
        np.testing.assert_allclose(
            (t.J_comm + t.J_comp)[applied], res.history[applied], rtol=1e-5
        )
        for b in range(res.n_instances):
            m_best = int(t.best_round[b])
            hist = res.history[b][applied[b]]
            # track_best keeps the running min: the recorded round must hold
            # the minimal J seen (ties resolve to the earliest strict win).
            np.testing.assert_allclose(hist[m_best], hist.min(), rtol=1e-6)

    def test_frozen_lane_trace_bits_survive_extra_rounds(self):
        """[fast, slow] vs [fast, fast]: lane 0's trace entries must be
        bitwise-identical even though the mixed batch keeps looping for the
        slow lane (satellite 3's inertness requirement)."""
        fast = random_connected(12, 5, seed=3, load_scale=0.4)
        slow = random_connected(12, 5, seed=4, load_scale=1.1)
        kw = dict(m_max=20, t_phi=5, alpha=0.5, tol=1e-3, patience=2)

        mixed = engine_solve(stack_problems([fast, slow])[0], **kw)
        alone = engine_solve(stack_problems([fast, fast])[0], **kw)
        # Premise: lane 0 froze while lane 1 kept the loop alive.
        assert int(mixed["iters"][0]) < int(mixed["rounds"])
        assert int(mixed["rounds"]) > int(alone["rounds"])

        tm, ta = mixed["trace"], alone["trace"]
        for field in ("J_comm", "J_comp", "moves", "live", "best_round"):
            np.testing.assert_array_equal(
                np.asarray(getattr(tm, field)[0]),
                np.asarray(getattr(ta, field)[0]),
                err_msg=f"trace.{field} lane 0 not bitwise-inert",
            )

    def test_trace_off_is_bitwise_identical_and_none(self):
        """`trace=False` removes the buffers and changes nothing else."""
        fleet = sample_fleet(3, seed=13)
        kw = dict(m_max=6, t_phi=3, patience=2)
        on = solve_fleet(fleet, trace=True, **kw)
        off = solve_fleet(fleet, trace=False, **kw)
        assert off.trace is None and isinstance(on.trace, FleetTrace)
        assert np.array_equal(on.J, off.J)
        assert np.array_equal(on.history, off.history, equal_nan=True)
        assert np.array_equal(on.hosts, off.hosts)
        assert np.array_equal(on.iters, off.iters)
        assert on.rounds == off.rounds

    def test_congunaware_has_no_trace(self):
        res = solve_fleet(sample_fleet(2, seed=14), method="CongUnaware")
        assert res.trace is None
        assert res.m_max == 0

    def test_single_solve_squeezes_trace(self):
        out = engine_solve_single(random_connected(10, 4, seed=5), **KW)
        t = out["trace"]
        assert t.J_comm.ndim == 1 and t.best_round.ndim == 0

    def test_summary_carries_telemetry(self):
        res = solve_fleet(sample_fleet(3, seed=15), m_max=12, t_phi=4)
        s = res.summary()
        assert f"rounds={res.rounds}/12" in s
        assert "churn=" in s
        assert "shard[1dev" in s
        d = res.trace.to_dict()
        assert d["rounds"] == res.rounds
        assert len(d["churn_per_instance"]) == res.n_instances
        assert len(d["frozen_count_per_round"]) == res.rounds + 1

    def test_chunked_trace_gathers_all_instances(self):
        fleet = sample_fleet(5, seed=16)
        res = solve_fleet(fleet, m_max=4, t_phi=3, chunk_size=2)
        assert res.trace.n_instances == 5
        assert np.array_equal(np.isnan(res.trace.J_comm), np.isnan(res.history))

    @needs_mesh
    def test_sharded_trace_parity(self):
        """Sharded vs unsharded solve on a simulated mesh: identical live
        mask / best rounds / churn, allclose objective splits."""
        batch = 10 if N_DEV == 8 else N_DEV + 1  # force pad-and-trim
        fleet = sample_fleet(batch, seed=17)
        kw = dict(m_max=4, t_phi=3, patience=3)
        res_u = solve_fleet(fleet, **kw)
        res_s = solve_fleet(fleet, shard=True, **kw)
        tu, ts = res_u.trace, res_s.trace
        assert ts.n_instances == batch
        np.testing.assert_array_equal(ts.live, tu.live)
        np.testing.assert_array_equal(ts.best_round, tu.best_round)
        np.testing.assert_array_equal(ts.moves, tu.moves)
        np.testing.assert_allclose(ts.J_comm, tu.J_comm, rtol=1e-5)
        np.testing.assert_allclose(ts.J_comp, tu.J_comp, rtol=1e-5)


# ---------------------------------------------------------------------------
# Layer 2: host spans + validator
# ---------------------------------------------------------------------------
class TestTracer:
    def test_disabled_tracer_records_nothing(self):
        tr = obs_trace.Tracer()
        with tr.span("noop", a=1):
            pass
        assert tr.events() == []

    def test_nesting_and_parent_ids(self):
        tr = obs_trace.Tracer()
        tr.configure(enabled=True)
        with tr.span("root", kind="outer"):
            with tr.span("child"):
                pass
            with tr.span("child2"):
                pass
        events = {e.name: e for e in tr.events()}
        root, child, child2 = events["root"], events["child"], events["child2"]
        assert root.parent == -1 and root.depth == 0
        assert child.parent == root.id and child.depth == 1
        assert child2.parent == root.id and child2.depth == 1
        # Children are recorded before the parent closes.
        names = [e.name for e in tr.events()]
        assert names.index("child") < names.index("root")
        assert root.attrs == {"kind": "outer"}

    def test_jsonl_roundtrip_validates(self, tmp_path):
        tr = obs_trace.Tracer()
        tr.configure(enabled=True)
        with tr.span("outer", n=2):
            with tr.span("inner"):
                pass
        path = tmp_path / "t.jsonl"
        tr.write_jsonl(path)
        records, errors = validate_lines(path.read_text().splitlines())
        assert errors == []
        assert len(records) == 2

    def test_chrome_trace_format(self, tmp_path):
        tr = obs_trace.Tracer()
        tr.configure(enabled=True)
        with tr.span("phase"):
            pass
        path = tmp_path / "t.trace.json"
        tr.write_chrome_trace(path)
        payload = json.loads(path.read_text())
        (ev,) = payload["traceEvents"]
        assert ev["ph"] == "X" and ev["name"] == "phase"
        assert ev["dur"] >= 0 and ev["cat"] == "repro"

    def test_chrome_path_for(self):
        assert obs_trace.chrome_path_for("a/b.jsonl") == "a/b.trace.json"
        assert obs_trace.chrome_path_for("x") == "x.trace.json"


class TestValidator:
    def _event(self, **over):
        base = dict(
            id=0, parent=-1, name="e", ts=0.0, dur=1.0, tid=1, depth=0,
            attrs={},
        )
        base.update(over)
        return base

    def test_accepts_well_formed(self):
        assert validate_events([self._event()]) == []

    def test_missing_fields(self):
        errs = validate_events([{"name": "x"}])
        assert any("missing required fields" in e for e in errs)

    def test_rejects_negative_and_wrong_types(self):
        assert validate_events([self._event(ts=-1.0)])
        assert validate_events([self._event(dur="fast")])
        assert validate_events([self._event(name="")])
        assert validate_events([self._event(attrs=[1])])

    def test_rejects_orphan_parent(self):
        errs = validate_events(
            [self._event(id=5, parent=99, depth=1)]
        )
        assert any("parent id 99" in e for e in errs)

    def test_rejects_bad_depth_and_containment(self):
        parent = self._event(id=1, ts=0.0, dur=1.0)
        bad_depth = self._event(id=2, parent=1, depth=2, ts=0.1, dur=0.1)
        escapes = self._event(id=3, parent=1, depth=1, ts=0.5, dur=2.0)
        errs = validate_events([parent, bad_depth, escapes])
        assert any("depth" in e for e in errs)
        assert any("not contained" in e for e in errs)

    def test_rejects_invalid_json_line(self):
        records, errors = validate_lines(["{not json"])
        assert records == [] and any("invalid JSON" in e for e in errors)


# ---------------------------------------------------------------------------
# Layer 3: metrics registry
# ---------------------------------------------------------------------------
class TestMetrics:
    def test_counter_gauge_roundtrip(self):
        reg = obs_metrics.MetricsRegistry()
        reg.counter("a.events").inc()
        reg.counter("a.events").inc(2)
        reg.gauge("a.level").set(7)
        assert reg.snapshot() == {"a.events": 3, "a.level": 7}

    def test_type_reuse_raises(self):
        reg = obs_metrics.MetricsRegistry()
        reg.counter("x")
        with pytest.raises(TypeError):
            reg.gauge("x")

    def test_histogram_percentiles(self):
        reg = obs_metrics.MetricsRegistry()
        h = reg.histogram("lat")
        for v in range(1, 101):
            h.observe(float(v))
        snap = reg.snapshot()["lat"]
        assert snap["count"] == 100
        assert snap["p50"] == pytest.approx(50.5)
        assert snap["p95"] == pytest.approx(95.05)
        assert snap["min"] == 1.0 and snap["max"] == 100.0

    def test_empty_histogram(self):
        h = obs_metrics.Histogram()
        assert h.snapshot() == {"count": 0}
        with pytest.raises(ValueError):
            h.percentile(50)

    def test_reset(self):
        reg = obs_metrics.MetricsRegistry()
        reg.counter("c").inc()
        reg.reset()
        assert reg.snapshot() == {}

    def test_solve_fleet_populates_registry(self):
        obs_metrics.registry.reset()
        solve_fleet(sample_fleet(3, seed=18), m_max=4, t_phi=3, chunk_size=2)
        snap = obs_metrics.registry.snapshot()
        assert snap["fleet.chunks_executed"] == 2
        assert snap["fleet.m_max"] == 4
        assert 0.0 <= snap["fleet.pad_overhead_fraction"] < 1.0
        assert snap["fleet.rounds_executed"] <= 4
        # Both chunks share one (shape, kwargs) signature; whether it was
        # cold depends on what earlier tests compiled, but the counts must
        # cover both chunks.
        assert (
            snap.get("fleet.compile.cold", 0)
            + snap.get("fleet.compile.warm", 0) == 2
        )


# ---------------------------------------------------------------------------
# Launch CLI integration
# ---------------------------------------------------------------------------
class TestLaunchIntegration:
    def test_fleet_cli_emits_metrics_trace_and_valid_jsonl(
        self, tmp_path, capsys
    ):
        from repro.launch.fleet import main

        out_path = tmp_path / "spans.jsonl"
        rc = main(
            [
                "--instances", "2", "--m-max", "3", "--t-phi", "3",
                "--trace-out", str(out_path),
            ]
        )
        assert rc == 0
        payload = json.loads(capsys.readouterr().out)
        assert payload["trace"]["rounds"] == payload["rounds"]
        assert "fleet.rounds_executed" in payload["metrics"]
        assert len(payload["trace"]["churn_per_instance"]) == 2
        records, errors = validate_lines(
            out_path.read_text().splitlines()
        )
        assert errors == [] and len(records) >= 4
        names = {r["name"] for r in records}
        assert {"launch.fleet.solve", "solve_fleet.execute"} <= names
