"""Blocked placement sweep (ISSUE 10, DESIGN.md section 18).

The sweep refactor made `placement_update`'s app scan a blocked schedule:
per block of `block_apps` apps the score-row ingredients are precomputed
batched, while the decisions stay a serial, conflict-exact walk. What is
pinned here:

  * bitwise invariance — `blocked_placement_update` (the blocked code path
    forced at ANY block size, including 1) reproduces the verbatim
    pre-refactor sequential scan bit-for-bit on all four paper topologies,
    both chained and colocated, and on mixed-partition / stage-padded
    instances. The oracle below is the deleted `lax.scan` implementation,
    kept verbatim;
  * end-of-solve parity — `solve_alt` / `solve_colocated` land on the SAME
    solution for block_apps in {1, 4, 0}: J within rtol 1e-5 (the ISSUE
    bar; measured equal to the bit) and identical hosts/iteration counts;
  * decision certificates — every committed move in `blocked_sweep_cert`
    carries `S_new < (1 - move_margin) * S_old` under its decision context,
    and unmoved partitions score unchanged (hypothesis property over random
    connected instances + deterministic anchors);
  * lane_chunk — the engine's round-body layout knob is bitwise-inert
    unsharded, and `solve_fleet` rejects a nonzero lane_chunk combined with
    a committed mesh (the guard only fires when a mesh actually commits, so
    that test runs under the simulated 8-device CPU mesh like
    tests/test_sharded_fleet.py);
  * Apsp0Cache — `repair_fleet` with a cached zero-load APSP is bitwise the
    uncached path, and `refresh_apsp0` hits exactly when (adj, mu, cost)
    are value-identical.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _optional_deps import given, settings, st

from repro.chaos import refresh_apsp0, repair_fleet
from repro.core import (
    SCENARIOS,
    State,
    blocked_placement_update,
    blocked_sweep_cert,
    forwarding_update,
    placement_update,
    random_connected,
    solve_alt,
    solve_colocated,
    structured_init,
)
from repro.core.placement import repair_phi
from repro.core.marginals import cost_to_go
from repro.core.structs import one_hot
from repro.fleet import pad_problem_parts, sample_fleet, solve_fleet
from repro.kernels.minplus import apsp_with_nexthop

N_DEV = len(jax.devices())
needs_mesh = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >= 2 devices; run under "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

MOVE_MARGIN = 0.02
BLOCKS = (1, 4, 0)  # sequential-size, mid block, one block over all apps


# ===========================================================================
# PRE-REFACTOR ORACLE — the deleted sequential `lax.scan` placement sweep,
# kept verbatim. Only the removal of the jit decorator and the explicit
# imports differ from the deleted source; every arithmetic expression,
# scan order, and the hysteresis pick are untouched. (`cost_to_go`,
# `apsp_with_nexthop` and `repair_phi` are unchanged by the refactor, so
# calling the production versions is exactly the deleted code's behavior.)
# ===========================================================================
def oracle_placement_update(
    problem, state, ctg=None, *, colocate=False, move_margin=0.02,
    solver="neumann",
):
    n = problem.net.n_nodes
    apps = problem.apps
    n_parts = apps.n_parts
    if ctg is None:
        ctg = cost_to_go(problem, state, solver=solver)
    q, dp, kappa, t, F, G = ctg
    dist, nexthop = apsp_with_nexthop(dp)

    hosts = state.hosts()  # [A, P]
    cm = problem.cost
    nu = problem.net.nu
    p_idx = jnp.arange(n_parts)

    from repro.core import costs as _costs

    def cprime(Gv):
        return cm.w_comp * _costs.comp_cost_prime(Gv, nu, cm)

    def body(Gv, inputs):
        (src_a, dst_a, h_old, lam_a, L_a, w_a, parts_a) = inputs
        loads_a = w_a * lam_a  # [P]
        live = p_idx < parts_a  # [P]
        # Remove this app's own loads so kappa is the marginal of adding it
        # (sequentially, in partition order — phantom loads are exact zeros).
        def remove(g, pin):
            h_p, load_p = pin
            return g - load_p * jax.nn.one_hot(h_p, n), None

        Gv, _ = jax.lax.scan(remove, Gv, (h_old, loads_a))

        def pick(S, h_prev):
            cand = jnp.argmin(S).astype(jnp.int32)
            better = S[cand] < (1.0 - move_margin) * S[h_prev]
            return jnp.where(better, cand, h_prev).astype(jnp.int32)

        if colocate:
            w_tot = jnp.sum(jnp.where(live, w_a, 0.0))
            load_tot = jnp.sum(jnp.where(live, loads_a, 0.0))
            L_fin = L_a[parts_a]
            S = (
                L_a[0] * dist[src_a, :]
                + w_tot * cprime(Gv)
                + L_fin * dist[:, dst_a]
            )
            h = pick(S, h_old[0])
            h_new = jnp.where(live, h, h_old)
            Gv = Gv + load_tot * jax.nn.one_hot(h, n)
            return Gv, h_new

        down = jnp.where(
            p_idx + 1 < parts_a,
            jnp.concatenate([h_old[1:], dst_a[None]]),
            dst_a,
        )  # [P]

        def step(carry, pin):
            g, up = carry
            live_p, h_old_p, down_p, L_up, L_dn, w_p, load_p = pin
            S = L_up * dist[up, :] + w_p * cprime(g) + L_dn * dist[:, down_p]
            h = jnp.where(live_p, pick(S, h_old_p), h_old_p)
            g = g + jnp.where(live_p, load_p, 0.0) * jax.nn.one_hot(h, n)
            return (g, h), h

        (Gv, _), h_new = jax.lax.scan(
            step,
            (Gv, src_a),
            (live, h_old, down, L_a[:-1], L_a[1:], w_a, loads_a),
        )
        return Gv, h_new

    _, hosts_new = jax.lax.scan(
        body,
        G,
        (apps.src, apps.dst, hosts, apps.lam, apps.L, apps.w, apps.parts),
    )

    x_new = one_hot(hosts_new, n)  # [A, P, V]
    new_state = State(x=x_new, phi=state.phi)
    return repair_phi(problem, state, new_state, nexthop)


def _sweep_state(problem):
    """Mid-solve state with congested routing, like the ALT loop's rounds:
    init, then a few forwarding sweeps so the marginals are not zero-load."""
    return forwarding_update(problem, structured_init(problem), t_phi=4)


# ---------------------------------------------------------------------------
# Bitwise invariance: blocked algorithm == verbatim sequential oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", list(SCENARIOS))
class TestBlockedSweepBitwise:
    @pytest.mark.parametrize("bk", BLOCKS)
    def test_matches_oracle(self, name, bk):
        p = SCENARIOS[name]()
        s = _sweep_state(p)
        ref = oracle_placement_update(p, s)
        got = blocked_placement_update(p, s, block_apps=bk)
        np.testing.assert_array_equal(
            np.asarray(got.hosts()), np.asarray(ref.hosts())
        )
        # phi goes through the identical repair_phi; the oracle chain is
        # unjitted, so the routing tensors get the fusion-tolerance budget.
        np.testing.assert_allclose(
            np.asarray(got.phi), np.asarray(ref.phi), rtol=1e-6, atol=1e-6
        )

    @pytest.mark.parametrize("bk", BLOCKS)
    def test_matches_oracle_colocated(self, name, bk):
        p = SCENARIOS[name]()
        s = _sweep_state(p)
        ref = oracle_placement_update(p, s, colocate=True)
        got = blocked_placement_update(p, s, colocate=True, block_apps=bk)
        np.testing.assert_array_equal(
            np.asarray(got.hosts()), np.asarray(ref.hosts())
        )

    def test_production_dispatch_bitwise(self, name):
        """The jitted production entry at every block size returns the SAME
        BITS as its own block_apps=1 dispatch — full state, not just hosts
        (both sides jitted, so no fusion budget is needed or granted)."""
        p = SCENARIOS[name]()
        s = _sweep_state(p)
        base = placement_update(p, s)  # dispatches the sequential scan
        for bk in BLOCKS:
            got = blocked_placement_update(p, s, block_apps=bk)
            np.testing.assert_array_equal(np.asarray(got.x), np.asarray(base.x))
            np.testing.assert_array_equal(
                np.asarray(got.phi), np.asarray(base.phi)
            )


class TestBlockedSweepMixedPartitions:
    def test_stage_padded_instance_bitwise(self):
        """Phantom partitions (DESIGN.md section 13) stay inert through the
        blocked schedule: the padded instance's real hosts match the
        unpadded sweep at every block size."""
        p = SCENARIOS["iot"]()
        padded = pad_problem_parts(p, 4)
        s = _sweep_state(p)
        sp = _sweep_state(padded)
        base = placement_update(p, s)
        for bk in BLOCKS:
            got = blocked_placement_update(padded, sp, block_apps=bk)
            np.testing.assert_array_equal(
                np.asarray(got.hosts())[:, :2], np.asarray(base.hosts())
            )

    def test_mixed_p_fleet_instances_bitwise(self):
        """Sampled instances across split depths P = 1..3: blocked == the
        production sequential dispatch on each, bit for bit."""
        for p in sample_fleet(3, seed=21, partitions=(1, 2, 3)):
            s = _sweep_state(p)
            base = placement_update(p, s)
            got = blocked_placement_update(p, s, block_apps=4)
            np.testing.assert_array_equal(
                np.asarray(got.x), np.asarray(base.x)
            )

    def test_block_larger_than_fleet_clamps(self):
        """block_apps beyond the app count behaves as one all-apps block."""
        p = SCENARIOS["iot"]()
        s = _sweep_state(p)
        a = p.apps.n_apps
        big = blocked_placement_update(p, s, block_apps=a + 100)
        one = blocked_placement_update(p, s, block_apps=0)
        np.testing.assert_array_equal(np.asarray(big.x), np.asarray(one.x))

    def test_negative_block_rejected(self):
        p = SCENARIOS["iot"]()
        s = _sweep_state(p)
        with pytest.raises(ValueError, match="block_apps"):
            placement_update(p, s, block_apps=-1)


# ---------------------------------------------------------------------------
# End-of-solve parity: the ALT loop lands on the same solution at any block
# ---------------------------------------------------------------------------
SOLVE_KW = dict(m_max=4, t_phi=3, alpha=0.5, tol=1e-3, patience=3)


@pytest.mark.parametrize("name", list(SCENARIOS))
class TestEndOfSolveParity:
    def test_solve_alt_block_invariant(self, name):
        p = SCENARIOS[name]()
        base = solve_alt(p, block_apps=1, **SOLVE_KW)
        for bk in (4, 0):
            got = solve_alt(p, block_apps=bk, **SOLVE_KW)
            np.testing.assert_allclose(got.J, base.J, rtol=1e-5)
            np.testing.assert_allclose(got.history, base.history, rtol=1e-5)
            assert got.iters == base.iters
            np.testing.assert_array_equal(
                np.asarray(got.state.x), np.asarray(base.state.x)
            )

    def test_solve_colocated_block_invariant(self, name):
        p = SCENARIOS[name]()
        base = solve_colocated(p, block_apps=1, **SOLVE_KW)
        got = solve_colocated(p, block_apps=0, **SOLVE_KW)
        np.testing.assert_allclose(got.J, base.J, rtol=1e-5)
        assert got.iters == base.iters


# ---------------------------------------------------------------------------
# Decision certificates: every committed move beats the hysteresis margin
# ---------------------------------------------------------------------------
def _check_cert(cert):
    s_new = np.asarray(cert["S_new"], np.float64)
    s_old = np.asarray(cert["S_old"], np.float64)
    h_old = np.asarray(cert["h_old"])
    h_fin = np.asarray(cert["h_fin"])
    moved_hosts = h_old != h_fin
    np.testing.assert_array_equal(np.asarray(cert["moved"]), moved_hosts)
    # Colocated certs carry ONE joint decision column. The margin property
    # covers the DECISION (joint host vs the kept partition-0 host), not the
    # first-sweep collapse of a not-yet-colocated chain onto the kept host —
    # that pulls partitions 1.. to partition 0's host with no score change.
    if s_new.shape != moved_hosts.shape:
        moved = moved_hosts[:, :1]
    else:
        moved = moved_hosts
    assert np.all(s_new[moved] < (1.0 - MOVE_MARGIN) * s_old[moved]), (
        "a committed move does not beat the hysteresis margin under its "
        "own decision context"
    )
    # Unmoved partitions were scored at their old host: no phantom gains.
    np.testing.assert_array_equal(s_new[~moved], s_old[~moved])


class TestSweepCert:
    @pytest.mark.parametrize("name", list(SCENARIOS))
    @pytest.mark.parametrize("colocate", [False, True])
    def test_cert_margin_holds(self, name, colocate):
        p = SCENARIOS[name]()
        s = _sweep_state(p)
        cert = blocked_sweep_cert(p, s, colocate=colocate, block_apps=4)
        _check_cert(cert)

    def test_cert_hosts_match_update(self):
        p = SCENARIOS["mesh"]()
        s = _sweep_state(p)
        cert = blocked_sweep_cert(p, s, block_apps=4)
        got = blocked_placement_update(p, s, block_apps=4)
        np.testing.assert_array_equal(
            np.asarray(cert["h_fin"]), np.asarray(got.hosts())
        )
        assert int(cert["block"]) == 4

    @settings(max_examples=8, deadline=None)
    @given(
        # Fixed (V, A) so every draw reuses the compiled programs; the
        # property varies the instance and the block size.
        seed=st.integers(min_value=0, max_value=31),
        bk=st.sampled_from([2, 3, 5]),
        colocate=st.booleans(),
    )
    def test_property_cert_and_bitwise(self, seed, bk, colocate):
        """For any random connected instance and block size: the margin
        certificate holds AND the blocked sweep is bitwise the sequential
        production dispatch."""
        p = random_connected(12, 5, seed=seed)
        s = _sweep_state(p)
        cert = blocked_sweep_cert(p, s, colocate=colocate, block_apps=bk)
        _check_cert(cert)
        base = placement_update(p, s, colocate=colocate)
        got = blocked_placement_update(p, s, colocate=colocate, block_apps=bk)
        np.testing.assert_array_equal(np.asarray(got.x), np.asarray(base.x))
        np.testing.assert_array_equal(np.asarray(got.phi), np.asarray(base.phi))


# ---------------------------------------------------------------------------
# lane_chunk: round-body layout is bitwise-inert; mesh combination rejected
# ---------------------------------------------------------------------------
FLEET_KW = dict(m_max=3, t_phi=3, alpha=0.5, tol=1e-3, patience=4)


def _fleet():
    return [
        SCENARIOS["iot"](),
        random_connected(12, 5, seed=3),
        random_connected(14, 6, seed=4),
        random_connected(11, 4, seed=5),
    ]


class TestLaneChunk:
    def test_lane_chunk_bitwise_inert(self):
        fleet = _fleet()
        base = solve_fleet(fleet, lane_chunk=1, **FLEET_KW)
        for lc in (0, 3):
            got = solve_fleet(fleet, lane_chunk=lc, **FLEET_KW)
            np.testing.assert_array_equal(got.J, base.J)
            np.testing.assert_array_equal(got.hosts, base.hosts)
            np.testing.assert_array_equal(got.history, base.history)
            np.testing.assert_array_equal(got.iters, base.iters)

    def test_block_apps_threads_through_fleet(self):
        fleet = _fleet()
        base = solve_fleet(fleet, block_apps=1, **FLEET_KW)
        got = solve_fleet(fleet, block_apps=4, **FLEET_KW)
        np.testing.assert_allclose(got.J, base.J, rtol=1e-5)
        np.testing.assert_array_equal(got.hosts, base.hosts)

    @needs_mesh
    def test_lane_chunk_with_mesh_rejected(self):
        """A nonzero lane_chunk breaks the instance-axis sharding, so a
        committed mesh must reject it loudly. The guard fires only when a
        mesh actually commits — a single-device host falls back unsharded
        (with a warning) before the check, hence the mesh marker."""
        fleet = _fleet() * 2  # 8 instances over the 8 simulated devices
        with pytest.raises(ValueError, match="lane_chunk"):
            solve_fleet(fleet, shard=True, lane_chunk=2, **FLEET_KW)

    @needs_mesh
    def test_lane_chunk_auto_resolves_fused_on_mesh(self):
        """lane_chunk=None under a committed mesh resolves to the fused
        layout and solves; explicit 0 is equally accepted."""
        fleet = _fleet() * 2
        res_auto = solve_fleet(fleet, shard=True, **FLEET_KW)
        res_zero = solve_fleet(fleet, shard=True, lane_chunk=0, **FLEET_KW)
        assert res_auto.shard.sharded and res_zero.shard.sharded
        np.testing.assert_array_equal(res_auto.J, res_zero.J)


# ---------------------------------------------------------------------------
# Apsp0Cache: cached zero-load APSP is bitwise the uncached repair path
# ---------------------------------------------------------------------------
class TestApsp0Cache:
    def test_miss_then_hit_then_invalidate(self):
        probs = _fleet()
        c1 = refresh_apsp0(probs, None)
        assert not c1.reused and c1.misses == 1 and c1.hits == 0
        c2 = refresh_apsp0(probs, c1)
        assert c2 is c1 and c2.reused and c2.hits == 1
        # A different topology invalidates by value: miss, counters carry.
        other = probs[:-1] + [random_connected(11, 4, seed=99)]
        c3 = refresh_apsp0(other, c2)
        assert not c3.reused and c3.misses == 2 and c3.hits == 1

    def test_repair_with_cache_bitwise(self):
        probs = _fleet()
        res = solve_fleet(probs, keep_state=True, **FLEET_KW)
        masks = [np.ones(p.net.n_nodes, np.float32) for p in probs]
        masks[0][int(np.asarray(res.hosts)[0, 0, 0])] = 0.0  # kill a host
        cache = refresh_apsp0(probs, None)
        cold = repair_fleet(probs, res.state, masks)
        warm = repair_fleet(probs, res.state, masks, apsp0=cache)
        np.testing.assert_array_equal(np.asarray(warm.x), np.asarray(cold.x))
        np.testing.assert_array_equal(
            np.asarray(warm.phi), np.asarray(cold.phi)
        )

    def test_cache_shapes_cover_envelope(self):
        probs = _fleet()
        cache = refresh_apsp0(probs, None)
        v_env = max(p.net.n_nodes for p in probs)
        assert cache.dist.shape == (len(probs), v_env, v_env)
        assert cache.nexthop.shape == (len(probs), v_env, v_env)
        d, nh = cache.sp()
        assert d.shape == cache.dist.shape and nh.dtype == jnp.int32
