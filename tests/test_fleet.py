"""Fleet engine tests: padding inertness, batched-vs-sequential equivalence,
and the scenario-fleet generator (repro.fleet)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    iot,
    mesh,
    objective,
    random_connected,
    stage_traffic,
    structured_init,
)
from repro.core.alt import solve_alt
from repro.core.flow import loads
from repro.fleet import (
    FAMILIES,
    METHODS,
    fleet_envelope,
    pad_problem,
    sample_fleet,
    solve_fleet,
    solve_sequential,
    stack_problems,
)

SOLVE_KW = dict(m_max=6, t_phi=5, alpha=0.5, tol=1e-3, patience=4)


def _mixed_fleet():
    return [
        iot(),
        mesh(),
        random_connected(12, 5, seed=3),
        random_connected(20, 8, seed=4),
    ]


# ---------------------------------------------------------------------------
# Padding: masks, envelope, and real-coordinate preservation
# ---------------------------------------------------------------------------
class TestPadding:
    def test_envelope_and_masks(self):
        fleet = _mixed_fleet()
        v, a = fleet_envelope(fleet)
        assert v == 25 and a == 40  # mesh dominates both axes
        v8, a8 = fleet_envelope(fleet, round_to=8)
        assert v8 == 32 and a8 == 40
        padded, info = pad_problem(fleet[2], v, a)
        assert padded.net.n_nodes == v and padded.apps.n_apps == a
        assert info.n_real_nodes == 12 and info.n_real_apps == 5

    def test_real_submatrices_preserved(self):
        p = iot()
        padded, info = pad_problem(p, 24, 31)
        v, a = p.net.n_nodes, p.apps.n_apps
        np.testing.assert_array_equal(padded.net.adj[:v, :v], p.net.adj)
        np.testing.assert_array_equal(padded.net.mu[:v, :v], p.net.mu)
        np.testing.assert_array_equal(padded.net.nu[:v], p.net.nu)
        np.testing.assert_array_equal(padded.apps.lam[:a], p.apps.lam)
        # padded nodes are disconnected, padded apps rate-free
        assert float(jnp.sum(padded.net.adj[v:, :])) == 0.0
        assert float(jnp.sum(padded.net.adj[:, v:])) == 0.0
        assert float(jnp.sum(padded.apps.lam[a:])) == 0.0

    def test_padded_coordinates_carry_zero_traffic(self):
        """The inertness contract: padding must not move any traffic."""
        p = iot()
        v, a = p.net.n_nodes, p.apps.n_apps
        padded, info = pad_problem(p, v + 7, a + 5)
        s = structured_init(padded)
        t = stage_traffic(padded, s)
        # padded apps: zero traffic everywhere; padded nodes: zero traffic
        # for every app and stage
        assert float(jnp.max(jnp.abs(t[a:]))) == 0.0
        assert float(jnp.max(jnp.abs(t[:, :, v:]))) == 0.0
        F, G = loads(padded, s, t)
        assert float(jnp.max(jnp.abs(F[v:, :]))) == 0.0
        assert float(jnp.max(jnp.abs(F[:, v:]))) == 0.0
        assert float(jnp.max(jnp.abs(G[v:]))) == 0.0
        # objective unchanged by padding
        J_pad, _ = objective(padded, s)
        J_ref, _ = objective(p, structured_init(p))
        np.testing.assert_allclose(float(J_pad), float(J_ref), rtol=1e-5)

    def test_padded_apps_stay_inert_under_sweeps(self):
        """Regression: phantom apps must carry zero forwarding mass.

        Without app_live_mask, forwarding sweeps drive the padded apps'
        phi-support into min-index 2-cycles, (I - Phi^T) goes singular, and
        0 * NaN poisons J. Exercise several full outer rounds on a padded
        problem and require exact zeros + finite objectives throughout."""
        from repro.core import forwarding_update, placement_update

        p = random_connected(21, 19, seed=13)
        padded, info = pad_problem(p, 28, 30)
        a = p.apps.n_apps
        s = structured_init(padded)
        for _ in range(4):
            s = placement_update(padded, s)
            s = forwarding_update(padded, s, t_phi=5)
            assert float(jnp.max(jnp.abs(s.phi[a:]))) == 0.0
            J, _ = objective(padded, s)
            assert np.isfinite(float(J))

    def test_padded_hosts_stay_real_through_solve(self):
        p = random_connected(10, 4, seed=5)
        res = solve_fleet([p, iot()], **SOLVE_KW)
        for b in range(2):
            n_real = int(res.node_mask[b].sum())
            real_hosts = res.hosts[b][res.app_mask[b] > 0]
            assert real_hosts.max() < n_real

    def test_stacking_rejects_mixed_cost_kind(self):
        from repro.core import CostModel

        with pytest.raises(ValueError, match="kind"):
            stack_problems([iot(), iot(cost=CostModel(kind="linear"))])


# ---------------------------------------------------------------------------
# Batched solve == sequential solve, per instance
# ---------------------------------------------------------------------------
class TestEquivalence:
    def test_alt_matches_sequential_on_mixed_fleet(self):
        fleet = _mixed_fleet()
        res = solve_fleet(fleet, **SOLVE_KW)
        seq = solve_sequential(fleet, **SOLVE_KW)
        for b in range(len(fleet)):
            np.testing.assert_allclose(res.J[b], seq[b].J, rtol=1e-3)
            np.testing.assert_allclose(res.J_comm[b], seq[b].J_comm, rtol=1e-3)
            np.testing.assert_allclose(res.J_comp[b], seq[b].J_comp, rtol=1e-3)

    def test_early_stop_masking_matches_sequential_breaks(self):
        """With m_max past convergence, the masked scan must reproduce the
        sequential loop's per-instance break points exactly."""
        fleet = [iot(), random_connected(14, 6, seed=11)]
        kw = dict(m_max=20, t_phi=5, alpha=0.5, tol=1e-3, patience=3)
        res = solve_fleet(fleet, **kw)
        seq = solve_sequential(fleet, **kw)
        for b in range(len(fleet)):
            np.testing.assert_allclose(res.J[b], seq[b].J, rtol=1e-3)
            assert int(res.iters[b]) == seq[b].iters
            hist = res.history[b]
            hist = hist[~np.isnan(hist)]
            np.testing.assert_allclose(hist, seq[b].history, rtol=1e-3)

    @pytest.mark.parametrize("method", [m for m in METHODS if m != "ALT"])
    def test_baseline_methods_match_sequential(self, method):
        fleet = [iot(), mesh(), random_connected(12, 5, seed=3)]
        res = solve_fleet(fleet, method=method, **SOLVE_KW)
        seq = solve_sequential(fleet, method=method, **SOLVE_KW)
        for b in range(len(fleet)):
            np.testing.assert_allclose(res.J[b], seq[b].J, rtol=1e-3)

    def test_round_to_envelope_does_not_change_results(self):
        fleet = [iot(), random_connected(12, 5, seed=3)]
        r1 = solve_fleet(fleet, **SOLVE_KW)
        r8 = solve_fleet(fleet, round_to=8, **SOLVE_KW)
        np.testing.assert_allclose(r1.J, r8.J, rtol=1e-3)

    def test_per_instance_reporting(self):
        fleet = _mixed_fleet()
        res = solve_fleet(fleet, **SOLVE_KW)
        rows = res.per_instance()
        assert len(rows) == len(fleet)
        for row, p in zip(rows, fleet):
            assert len(row["hosts"]) == p.apps.n_apps
            assert np.isfinite(row["J"])
            assert row["J"] > 0.0
            assert row["J"] <= row["history"][0]  # best-iterate never regresses


# ---------------------------------------------------------------------------
# Scenario-fleet generator
# ---------------------------------------------------------------------------
class TestGenerator:
    @pytest.mark.parametrize("family", list(FAMILIES))
    def test_families_connected_and_reproducible(self, family):
        import networkx as nx

        make = FAMILIES[family]
        if family in ("iot_hierarchy", "perturbed_geant"):
            p1, p2 = make(seed=9), make(seed=9)
            p3 = make(seed=10)
        else:
            p1, p2 = make(16, 8, seed=9), make(16, 8, seed=9)
            p3 = make(16, 8, seed=10)
        np.testing.assert_array_equal(np.asarray(p1.net.adj), np.asarray(p2.net.adj))
        np.testing.assert_array_equal(np.asarray(p1.apps.lam), np.asarray(p2.apps.lam))
        # different seed -> different instance (rates always re-drawn)
        assert not np.array_equal(np.asarray(p1.net.mu), np.asarray(p3.net.mu))
        g = nx.from_numpy_array(np.asarray(p1.net.adj))
        assert nx.is_connected(g)

    def test_sample_fleet_solvable_end_to_end(self):
        fleet = sample_fleet(8, seed=3)
        assert len(fleet) == 8
        assert len({(p.net.n_nodes, p.apps.n_apps) for p in fleet}) > 1
        res = solve_fleet(fleet, m_max=4, t_phi=4)
        assert np.all(np.isfinite(res.J))
        # every instance improves on (or at least never regresses from) init
        first = res.history[:, 0]
        assert np.all(res.J <= first * (1.0 + 1e-6))

    def test_grids(self):
        from repro.fleet import eta_grid, load_grid

        lg = load_grid(iot, (0.5, 1.0))
        assert float(np.sum(lg[1].apps.lam)) > float(np.sum(lg[0].apps.lam))
        eg = eta_grid(iot, (0.2, 0.8))
        assert float(eg[0].cost.w_comm) == pytest.approx(0.2)
        assert float(eg[0].cost.w_comp) == pytest.approx(0.8)
