"""Partition bridge tests: profiles are sane, the split executor is exact,
and profiles drive the core optimizer end to end."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced_config
from repro.core import CostModel, Network, Problem, solve_alt, solve_colocated
from repro.core.structs import BIG
from repro.models import init_params, logits_fn
from repro.partition import (
    apps_from_profiles,
    profile_arch,
    run_partition,
    split_params,
)


@pytest.mark.parametrize("arch", ARCHS)
def test_profile_shapes_and_compression(arch):
    cfg = get_config(arch)
    p = profile_arch(cfg, seq_len=1024)
    assert p.w1_flops > 0 and p.w2_flops > 0
    assert p.L1_bytes > 0 and p.L0_bytes > 0 and p.L2_bytes > 0
    # Default split puts the lighter partition first (paper's structure),
    # except tiny-layer-count archs where the unembed dominates.
    if cfg.family != "encdec":
        assert p.split_layer <= cfg.n_layers // 2


def test_profile_flops_scale_with_params():
    """6*N*D rule of thumb: per-token forward FLOPs ~ 2 * active params."""
    for arch in ("qwen1.5-0.5b", "gemma-2b", "mamba2-370m"):
        cfg = get_config(arch)
        p = profile_arch(cfg, seq_len=1024)
        total = (p.w1_flops + p.w2_flops) / p.seq_len  # per token
        approx = 2.0 * cfg.n_active_params()
        assert 0.3 * approx < total < 3.0 * approx, (arch, total, approx)


def test_moe_profile_uses_active_flops():
    moe = profile_arch(get_config("mixtral-8x22b"), seq_len=256)
    total_params = get_config("mixtral-8x22b").n_params()
    active_params = get_config("mixtral-8x22b").n_active_params()
    per_token = (moe.w1_flops + moe.w2_flops) / moe.seq_len
    assert per_token < 2.5 * active_params  # not paying for all 8 experts
    assert active_params < 0.5 * total_params


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-370m", "hymba-1.5b", "seamless-m4t-medium"])
def test_split_executor_matches_monolithic(arch):
    """partition1 -> ship activation -> partition2 == full model logits."""
    cfg = reduced_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 1, 32
    key = jax.random.PRNGKey(1)
    if cfg.family == "encdec":
        batch = {
            "feats": jax.random.normal(key, (b, s, cfg.frontend_dim)),
            "dec_tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        }
        k = cfg.n_layers
    else:
        batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
        k = 1
    p1, p2 = split_params(cfg, params, k)
    act = run_partition(cfg, p1, batch, part=1, k=k)
    if cfg.family == "encdec":
        logits = run_partition(
            cfg, p2, {"memory": act, "dec_tokens": batch["dec_tokens"]}, part=2, k=k
        )
    else:
        logits = run_partition(cfg, p2, act, part=2, k=k)
    want = logits_fn(cfg, params, batch)
    np.testing.assert_allclose(
        logits.astype(jnp.float32), want.astype(jnp.float32), rtol=2e-3, atol=2e-3
    )
    # The shipped activation has exactly the profiled L1 size.
    prof = profile_arch(cfg, seq_len=s)
    assert act.size * 2 == prof.L1_bytes * b  # bf16 = 2 bytes/elt


def test_profiles_drive_core_optimizer():
    """End-to-end: 10 arch profiles -> Apps -> ALT solves a small edge net."""
    profiles = [profile_arch(get_config(a), seq_len=256) for a in ARCHS]
    n = 8
    adj = np.zeros((n, n), np.float32)
    mu = np.full((n, n), BIG, np.float32)
    ring = [(i, (i + 1) % n) for i in range(n)]
    for u, v in ring + [(0, 4), (2, 6)]:
        for i, j in ((u, v), (v, u)):
            adj[i, j] = 1.0
            mu[i, j] = 100e6  # 100 MB/s links
    nu = np.array([50e9, 200e9, 50e9, 400e9, 50e9, 200e9, 50e9, 800e9], np.float32)
    net = Network(adj=jnp.asarray(adj), mu=jnp.asarray(mu), nu=jnp.asarray(nu))
    rng = np.random.RandomState(0)
    src = rng.randint(0, n, len(profiles))
    apps = apps_from_profiles(
        profiles, src, src, np.full(len(profiles), 2.0), byte_scale=1.0, flop_scale=1.0
    )
    problem = Problem(net=net, apps=apps, cost=CostModel())
    alt = solve_alt(problem, m_max=10)
    colo = solve_colocated(problem, m_max=10)
    assert np.isfinite(alt.J)
    assert alt.J <= colo.J * 1.001
