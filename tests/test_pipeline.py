"""Pipeline-parallel runner: GPipe schedule over a mesh axis == sequential
stage application. Runs in a subprocess with 8 fake host devices (the test
process itself holds a single-device jax)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply

P_STAGES, M, MB, D = 4, 6, 8, 16
mesh = jax.make_mesh((P_STAGES,), ("pod",))
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (P_STAGES, D, D)) * 0.3

def stage_fn(p_local, x):
    return jnp.tanh(x @ p_local["w"])

params = {"w": w}
x = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))

got = pipeline_apply(stage_fn, params, x, mesh=mesh, axis="pod")

# sequential oracle
ref = x
for s in range(P_STAGES):
    ref = jnp.tanh(ref @ w[s])
err = float(jnp.max(jnp.abs(got - ref)))
print("PIPELINE_ERR", err)
assert err < 1e-5, err
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", _SCRIPT], env=env, capture_output=True,
        text=True, timeout=600, cwd=str(REPO),
    )
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "PIPELINE_OK" in r.stdout
