"""Optional-dependency shims shared by the test modules.

`hypothesis` is a [test] extra, not a runtime dependency, and some minimal
environments (e.g. the benchmark container) don't ship it. Importing this
module instead of hypothesis directly keeps collection working everywhere:
when hypothesis is available the real `given` / `settings` / `st` are
re-exported unchanged; when it is absent, `given` turns the decorated test
into a clean `pytest.skip`, and `settings` / `st` become inert placeholders
whose strategy objects are never drawn from.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the extra
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def decorate(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def skipped(*a, **k):
                pass

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn

        return decorate

    class _Strategy:
        """Placeholder strategy: only ever passed around, never drawn."""

        def __init__(self, name, args, kwargs):
            self._repr = f"st.{name}{args}{kwargs or ''}"

        def __repr__(self):
            return self._repr

    class _StrategiesStub:
        def __getattr__(self, name):
            def make(*args, **kwargs):
                return _Strategy(name, args, kwargs)

            return make

    st = _StrategiesStub()
