"""Split-point Pareto search + the profile-bridge fixes it builds on (PR 9).

Covers DESIGN.md section 17 end to end:
  * bitwise P=2 back-compat pin — the candidate-set path at the legacy
    default split reproduces the pre-split-search ArchProfile numbers
    exactly, for every pre-existing zoo config (verbatim port of the old
    arithmetic lives in _legacy_profile below);
  * the profile_arch split-validation bugfixes (encdec honored, named
    ValueErrors, dead unembed term gone);
  * per-layer-type FLOPs accounting for interleaved hybrids, cross-checked
    against launch.hlo_cost on real lowered models;
  * apps_from_profiles mixed-depth padding + named-ValueError validation;
  * pareto_front dominance filtering and the sweep_zoo end-to-end report
    contract (check_fronts);
  * hypothesis property: every enumerated candidate yields finite,
    conservation-satisfying solve_fleet results through mixed-P padding.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import ARCHS, ZOO, get_config, reduced_config
from repro.core.scenarios import SCENARIOS
from repro.core.structs import CostModel, Problem
from repro.fleet import solve_fleet
from repro.partition.pareto import check_fronts, pareto_front, sweep_zoo
from repro.partition.profile import (
    ArchProfile,
    apps_from_profiles,
    enumerate_candidates,
    flops_per_token_layer,
    layer_flops_table,
    profile_arch,
    total_profile_layers,
)
from tests._optional_deps import given, settings, st

SEQ = 128
N_OUT = 32


# ---------------------------------------------------------------------------
# 1. bitwise P=2 back-compat pin (verbatim port of the legacy arithmetic)
# ---------------------------------------------------------------------------
def _legacy_profile(cfg, seq_len, n_out_tokens):
    """The pre-PR-9 profile_arch arithmetic, ported verbatim (minus the dead
    `2.0 * seq_len * cfg.vocab * 0` encoder-unembed term, which is + 0.0).

    Returns (split_layer, L0, L1, L2, w1, w2) for the legacy default cut."""
    if cfg.family == "encdec":
        split_layer = cfg.n_layers
        l0 = seq_len * (cfg.frontend_dim * 2.0 if cfg.frontend != "none" else 4.0)
        l1 = seq_len * cfg.d_model * 2.0
        l2 = n_out_tokens * 4.0
        w1 = seq_len * sum(
            flops_per_token_layer(cfg, seq_len) for _ in range(cfg.n_layers)
        )
        w2 = seq_len * sum(
            flops_per_token_layer(cfg, seq_len, decoder=True)
            for _ in range(cfg.n_dec_layers)
        )
        w2 += 2.0 * n_out_tokens * cfg.d_model * cfg.vocab
        return split_layer, l0, l1, l2, w1, w2
    n_l = cfg.n_layers
    split_layer = max(1, n_l // 4)
    per_layer = flops_per_token_layer(cfg, seq_len)
    l0 = seq_len * (cfg.frontend_dim * 2.0 if cfg.frontend != "none" else 4.0)
    l1 = seq_len * cfg.d_model * 2.0
    l2 = n_out_tokens * 4.0
    w_unembed = 2.0 * seq_len * cfg.d_model * cfg.vocab
    w1 = seq_len * per_layer * split_layer + 0.0
    w2 = seq_len * per_layer * (n_l - split_layer) + w_unembed
    return split_layer, l0, l1, l2, w1, w2


@pytest.mark.parametrize("arch", ARCHS)
def test_p2_default_profile_bitwise_pin(arch):
    """New generalized path at the legacy default split == old numbers,
    bit for bit, for all pre-existing zoo configs."""
    cfg = get_config(arch)
    prof = profile_arch(cfg, seq_len=SEQ, n_out_tokens=N_OUT)
    k, l0, l1, l2, w1, w2 = _legacy_profile(cfg, SEQ, N_OUT)
    assert prof.n_parts == 2
    assert prof.split_layer == k
    assert prof.L0_bytes == l0
    assert prof.L1_bytes == l1
    assert prof.L2_bytes == l2
    assert prof.w1_flops == w1
    assert prof.w2_flops == w2
    assert prof.L == (l0, l1, l2)
    assert prof.w == (w1, w2)


@pytest.mark.parametrize("arch", ARCHS)
def test_p2_apps_bitwise_pin(arch):
    """apps_from_profiles at uniform P=2 reproduces the legacy L/w arrays
    (the old code built [L0, L1, L2] / [w1, w2] directly)."""
    cfg = get_config(arch)
    prof = profile_arch(cfg, seq_len=SEQ, n_out_tokens=N_OUT)
    src = np.array([0, 1, 2])
    lam = np.array([0.5, 1.0, 2.0])
    apps = apps_from_profiles(
        [prof] * 3, src, src, lam, byte_scale=1e-6, flop_scale=1e-9
    )
    _, l0, l1, l2, w1, w2 = _legacy_profile(cfg, SEQ, N_OUT)
    legacy_L = (np.array([[l0, l1, l2]] * 3) * 1e-6).astype(np.float32)
    legacy_w = (np.array([[w1, w2]] * 3) * 1e-9).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(apps.L), legacy_L)
    np.testing.assert_array_equal(np.asarray(apps.w), legacy_w)
    np.testing.assert_array_equal(np.asarray(apps.parts), [2, 2, 2])


# ---------------------------------------------------------------------------
# 2. profile_arch split validation (the satellite-1 bugfixes)
# ---------------------------------------------------------------------------
class TestSplitValidation:
    def test_both_split_args_raise(self):
        cfg = get_config("qwen1.5-0.5b")
        with pytest.raises(ValueError, match="not both"):
            profile_arch(cfg, split=3, splits=(3,))

    @pytest.mark.parametrize("bad", [0, -2, 10**6])
    def test_out_of_range_raises(self, bad):
        cfg = get_config("qwen1.5-0.5b")
        with pytest.raises(ValueError, match="out of range"):
            profile_arch(cfg, split=bad)

    def test_descending_splits_raise(self):
        cfg = get_config("gemma-2b")
        with pytest.raises(ValueError, match="strictly ascending"):
            profile_arch(cfg, splits=(5, 5))
        with pytest.raises(ValueError, match="strictly ascending"):
            profile_arch(cfg, splits=(7, 3))

    def test_encdec_honors_split(self):
        """The historical code silently ignored split= for encdec; now any
        interior boundary is legal and actually moves the cut."""
        cfg = get_config("seamless-m4t-medium")
        inside_enc = profile_arch(cfg, seq_len=SEQ, split=2)
        assert inside_enc.split_layer == 2
        boundary = profile_arch(cfg, seq_len=SEQ, split=cfg.n_layers)
        default = profile_arch(cfg, seq_len=SEQ)
        assert boundary == default  # explicit boundary == legacy default
        # a cut INSIDE the decoder ships memory + decoder hiddens (2x)
        inside_dec = profile_arch(cfg, seq_len=SEQ, split=cfg.n_layers + 1)
        assert inside_dec.L1_bytes == 2.0 * boundary.L1_bytes
        with pytest.raises(ValueError, match="encoder/decoder boundary"):
            profile_arch(cfg, split=total_profile_layers(cfg))

    def test_empty_splits_is_unsplit_chain(self):
        cfg = get_config("mamba2-370m")
        prof = profile_arch(cfg, seq_len=SEQ, splits=())
        assert prof.n_parts == 1
        assert len(prof.L_bytes) == 2
        default = profile_arch(cfg, seq_len=SEQ)
        assert prof.w_flops[0] == pytest.approx(sum(default.w_flops), rel=1e-12)

    def test_compression_ratio_subbyte_and_zero(self):
        """Sub-byte L0 must not be clamped to 1.0 (old max(L0, 1.0) bug);
        a zero L0 raises a named error instead of silently dividing."""
        p = ArchProfile(
            arch="x", splits=(1,), n_layers_total=2, seq_len=1,
            L_bytes=(0.5, 1.0, 4.0), w_flops=(1.0, 1.0),
        )
        assert p.compression_ratio() == 2.0
        z = dataclasses.replace(p, L_bytes=(0.0, 1.0, 4.0))
        with pytest.raises(ValueError, match="compression_ratio"):
            z.compression_ratio()


# ---------------------------------------------------------------------------
# 3. interleaved-hybrid per-layer-type accounting (the satellite-2 bugfix)
# ---------------------------------------------------------------------------
class TestInterleavedHybrids:
    @pytest.mark.parametrize("arch", ["nemotron-h-8b", "zamba2-2.7b"])
    def test_layer_mix_and_counts(self, arch):
        cfg = get_config(arch)
        p = cfg.hybrid_attn_period
        assert p >= 1
        na = cfg.n_attn_layers()
        assert na == sum(
            1 for l in range(cfg.n_layers) if l % p == p - 1
        )
        assert 0 < na < cfg.n_layers  # genuinely mixed stack
        for l in range(cfg.n_layers):
            has_attn, has_ssm = cfg.layer_mix(l)
            assert has_attn != has_ssm  # interleaved: one branch per block

    def test_uniform_table_unchanged_for_parallel_hybrid(self):
        """hymba (hybrid_attn_period=0) keeps the every-block-has-both
        accounting — that matches its actual model code."""
        cfg = get_config("hymba-1.5b")
        table = layer_flops_table(cfg, SEQ)
        assert len(set(table)) == 1
        assert table[0] == flops_per_token_layer(cfg, SEQ)

    def test_layer_none_raises_for_interleaved(self):
        cfg = get_config("nemotron-h-8b")
        with pytest.raises(ValueError, match="interleaved"):
            flops_per_token_layer(cfg, SEQ)

    @pytest.mark.parametrize("arch", ["nemotron-h-8b", "zamba2-2.7b"])
    def test_two_block_costs_and_profile_total(self, arch):
        """The table has exactly the attention-block and SSM-block costs,
        and the profile total is their count-weighted sum + unembed —
        NOT n_layers * (attn + ssm) as the old uniform bug would give."""
        cfg = get_config(arch)
        table = layer_flops_table(cfg, SEQ)
        costs = sorted(set(table))
        assert len(costs) == 2
        na = cfg.n_attn_layers()
        attn_cost = flops_per_token_layer(cfg, SEQ, layer=cfg.hybrid_attn_period - 1)
        ssm_cost = flops_per_token_layer(cfg, SEQ, layer=0)
        assert sorted({attn_cost, ssm_cost}) == costs
        prof = profile_arch(cfg, seq_len=SEQ, n_out_tokens=N_OUT)
        unembed = 2.0 * SEQ * cfg.d_model * cfg.vocab
        expect = SEQ * (na * attn_cost + (cfg.n_layers - na) * ssm_cost) + unembed
        assert sum(prof.w_flops) == pytest.approx(expect, rel=1e-12)
        # the old uniform bug charged EVERY block both branches (the
        # parallel-hybrid reading) — the interleaved total must be lower
        parallel = dataclasses.replace(cfg, hybrid_attn_period=0)
        buggy = SEQ * cfg.n_layers * flops_per_token_layer(parallel, SEQ)
        assert sum(prof.w_flops) < buggy + unembed

    def test_init_params_rejects_interleaved(self):
        from jax import random
        from repro.models import init_params

        cfg = reduced_config("nemotron-h-8b")
        assert cfg.hybrid_attn_period >= 1  # survives reduction
        with pytest.raises(ValueError, match="profile-only"):
            init_params(cfg, random.PRNGKey(0))

    def test_n_params_interleaved_below_parallel(self):
        """Dropping the attention branch from most blocks must shrink the
        parameter count vs the parallel-hybrid (period=0) reading."""
        cfg = get_config("nemotron-h-8b")
        parallel = dataclasses.replace(cfg, hybrid_attn_period=0)
        assert cfg.n_params() < parallel.n_params()


# ---------------------------------------------------------------------------
# 4. HLO cross-check: analytic profile FLOPs vs launch.hlo_cost (satellite 2)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-370m", "hymba-1.5b"])
def test_profile_flops_vs_hlo_cost(arch):
    """sum(w_flops) for a reduced config within 2x of the dot-FLOPs the
    compiled logits_fn actually contains (same gate as test_dryrun's
    whole-model check; attention masking / non-dot SSM ops are the gap)."""
    import jax
    import jax.numpy as jnp
    from repro.launch import hlo_cost
    from repro.models import init_params, logits_fn

    seq = 64
    cfg = reduced_config(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((1, seq), jnp.int32)}
    hlo = (
        jax.jit(lambda p, b: logits_fn(cfg, p, b))
        .lower(params, batch)
        .compile()
        .as_text()
    )
    measured = hlo_cost.analyze(hlo)["flops"]
    analytic = sum(profile_arch(cfg, seq_len=seq).w_flops)
    ratio = measured / analytic
    assert 0.5 < ratio < 2.0, (arch, ratio, measured, analytic)


# ---------------------------------------------------------------------------
# 5. apps_from_profiles: mixed-depth padding + named validation (satellite 3)
# ---------------------------------------------------------------------------
class TestAppsFromProfiles:
    def test_mixed_depth_padding(self):
        cfg = get_config("gemma-2b")
        p1 = profile_arch(cfg, seq_len=SEQ, splits=())
        p2 = profile_arch(cfg, seq_len=SEQ)
        p4 = profile_arch(cfg, seq_len=SEQ, splits=(4, 9, 14))
        src = np.array([0, 1, 2])
        apps = apps_from_profiles([p1, p2, p4], src, src, np.ones(3))
        assert apps.L.shape == (3, 5)  # K = max P + 1
        assert apps.w.shape == (3, 4)
        np.testing.assert_array_equal(np.asarray(apps.parts), [1, 2, 4])
        L = np.asarray(apps.L, np.float64)
        w = np.asarray(apps.w, np.float64)
        # final packet sits at index parts; phantom stages beyond are 0
        assert L[0, 1] == np.float32(p1.L_bytes[-1])
        assert (L[0, 2:] == 0).all() and (w[0, 1:] == 0).all()
        assert L[1, 2] == np.float32(p2.L_bytes[-1])
        assert (L[1, 3:] == 0).all() and (w[1, 2:] == 0).all()
        np.testing.assert_array_equal(
            w[2], np.asarray(p4.w_flops, np.float32).astype(np.float64)
        )

    def test_empty_profiles_raise(self):
        with pytest.raises(ValueError, match="empty profile list"):
            apps_from_profiles([], np.array([]), np.array([]), np.array([]))

    def test_length_mismatch_raises_named(self):
        cfg = get_config("qwen1.5-0.5b")
        p = profile_arch(cfg, seq_len=SEQ)
        with pytest.raises(ValueError, match="2 profiles.*src has 1"):
            apps_from_profiles(
                [p, p], np.array([0]), np.array([0, 1]), np.array([1.0, 1.0])
            )

    @pytest.mark.parametrize("kw", [{"byte_scale": 0.0},
                                    {"flop_scale": -1.0},
                                    {"byte_scale": float("nan")}])
    def test_bad_scales_raise(self, kw):
        cfg = get_config("qwen1.5-0.5b")
        p = profile_arch(cfg, seq_len=SEQ)
        with pytest.raises(ValueError, match="finite and positive"):
            apps_from_profiles(
                [p], np.array([0]), np.array([1]), np.array([1.0]), **kw
            )


# ---------------------------------------------------------------------------
# 6. candidate enumeration
# ---------------------------------------------------------------------------
class TestEnumerateCandidates:
    def test_counts_and_determinism(self):
        import math

        cfg = get_config("qwen1.5-0.5b")
        total = total_profile_layers(cfg)
        cands, n_possible = enumerate_candidates(
            cfg, seq_len=SEQ, max_per_p=8
        )
        again, _ = enumerate_candidates(cfg, seq_len=SEQ, max_per_p=8)
        assert cands == again  # fully deterministic
        assert n_possible == sum(
            math.comb(total - 1, p - 1) for p in (1, 2, 3, 4)
        )
        by_p = {}
        for c in cands:
            by_p.setdefault(c.n_parts, []).append(c)
        assert sorted(by_p) == [1, 2, 3, 4]
        for p, group in by_p.items():
            assert len(group) <= 8
            # endpoints of the lexicographic combination list survive
            if p >= 2:
                assert group[0].splits[0] == 1
                assert group[-1].splits[-1] == total - 1

    def test_total_flops_split_invariant(self):
        """Every candidate of one arch does the same total work — the
        normalization in pareto.sweep_zoo depends on this."""
        for arch in ZOO:
            cfg = get_config(arch)
            cands, _ = enumerate_candidates(cfg, seq_len=SEQ, max_per_p=4)
            totals = {sum(c.w_flops) for c in cands}
            base = sum(profile_arch(cfg, seq_len=SEQ).w_flops)
            assert all(
                abs(t - base) / base < 1e-9 for t in totals
            ), (arch, totals)

    def test_bad_args_raise(self):
        cfg = get_config("qwen1.5-0.5b")
        with pytest.raises(ValueError, match="max_per_p"):
            enumerate_candidates(cfg, max_per_p=0)
        with pytest.raises(ValueError, match="partition counts"):
            enumerate_candidates(cfg, parts=(0,))


# ---------------------------------------------------------------------------
# 7. pareto_front dominance filtering
# ---------------------------------------------------------------------------
class TestParetoFront:
    def test_simple_dominance(self):
        mask = pareto_front([[1.0, 1.0], [2.0, 2.0], [0.5, 3.0]])
        np.testing.assert_array_equal(mask, [True, False, True])

    def test_duplicates_both_survive(self):
        mask = pareto_front([[1.0, 1.0], [1.0, 1.0], [2.0, 0.5]])
        np.testing.assert_array_equal(mask, [True, True, True])

    def test_partial_tie_dominates(self):
        # equal in one column, strictly better in the other -> dominates
        mask = pareto_front([[1.0, 2.0], [1.0, 1.0]])
        np.testing.assert_array_equal(mask, [False, True])

    def test_non_2d_raises(self):
        with pytest.raises(ValueError, match="expected \\[N, D\\]"):
            pareto_front([1.0, 2.0])

    def test_single_point_kept(self):
        np.testing.assert_array_equal(pareto_front([[3.0, 3.0, 3.0]]), [True])


# ---------------------------------------------------------------------------
# 8. sweep_zoo end-to-end (one batched solve) + check_fronts contract
# ---------------------------------------------------------------------------
class TestSweepZoo:
    @pytest.fixture(scope="class")
    def report(self):
        return sweep_zoo(
            archs=("qwen1.5-0.5b", "nemotron-h-8b"),
            topologies=("iot",),
            loads=(1.0,),
            etas=(0.5,),
            max_per_p=4,
            seq_len=64,
            m_max=2,
            t_phi=2,
            round_to=4,
        )

    def test_report_shape(self, report):
        # 1 topology x 1 load: the whole batch lands in one cell group
        assert report["n_instances"] == report["candidates_per_topo_load"]
        assert len(report["cells"]) == 2  # one per (arch, topo, load)
        for cell in report["cells"]:
            assert cell["n_points"] >= 4  # mixed P=1..4 candidates
            parts_seen = {p["parts"] for p in cell["points"]}
            assert parts_seen == {1, 2, 3, 4}  # genuinely mixed-P batch
            for p in cell["points"]:
                assert np.isfinite([p["latency"], p["compute"], p["egress"]]).all()
                assert len(p["splits"]) == p["parts"] - 1

    def test_fronts_verify(self, report):
        check_fronts(report)  # raises on any violated contract

    def test_tampered_front_caught(self, report):
        import copy

        bad = copy.deepcopy(report)
        cell = bad["cells"][0]
        dominated = [
            i for i, p in enumerate(cell["points"]) if not p["on_front"]
        ]
        cell["front"] = sorted(cell["front"] + dominated[:1])
        with pytest.raises(ValueError, match="re-verified"):
            check_fronts(bad)

    def test_accounting_not_silent(self, report):
        assert report["cut_sets_possible"] > report["n_instances"]
        assert report["cut_sets_dropped"] >= 0
        assert (
            report["cut_sets_possible"]
            == report["cut_sets_dropped"] + report["candidates_per_topo_load"]
        )

    def test_unknown_topology_raises(self):
        with pytest.raises(ValueError, match="unknown topology"):
            sweep_zoo(archs=("qwen1.5-0.5b",), topologies=("nope",))

    def test_bad_eta_raises(self):
        with pytest.raises(ValueError, match="eta"):
            sweep_zoo(archs=("qwen1.5-0.5b",), etas=(1.5,))


# ---------------------------------------------------------------------------
# 9. mixed-P candidates through solve_fleet: finite + conservation
# ---------------------------------------------------------------------------
def _solve_candidate_batch(profiles, eta=0.5, m_max=2):
    """One iot-scenario problem per profile; all solved in one fleet call."""
    base = SCENARIOS["iot"](load_scale=0.5)
    src = np.asarray(base.apps.src)
    dst = np.asarray(base.apps.dst)
    lam = np.asarray(base.apps.lam)
    cost = CostModel(w_comm=eta, w_comp=1.0 - eta)
    problems = []
    for prof in profiles:
        byte_scale = 2.0 / max(prof.L_bytes)
        flop_scale = 1.3 / sum(prof.w_flops)
        apps = apps_from_profiles(
            [prof] * len(src), src, dst, lam,
            byte_scale=byte_scale, flop_scale=flop_scale,
        )
        problems.append(
            Problem(net=base.net, apps=apps, cost=cost, hop_bound=base.hop_bound)
        )
    return solve_fleet(problems, m_max=m_max, t_phi=2, round_to=4, trace=False)


def test_mixed_p_solve_finite_and_conserving():
    """Deterministic slice of the hypothesis property below: a mixed-depth
    candidate batch (P = 1, 2, 4 of one arch) solves to finite objectives
    satisfying J = w_comm*J_comm + w_comp*J_comp, with hosts inside the
    real node block and per-app depth preserved through the padding."""
    cfg = get_config("gemma-2b")
    profiles = [
        profile_arch(cfg, seq_len=64, splits=()),
        profile_arch(cfg, seq_len=64),
        profile_arch(cfg, seq_len=64, splits=(4, 9, 14)),
    ]
    res = _solve_candidate_batch(profiles)
    V = int(SCENARIOS["iot"](load_scale=0.5).net.adj.shape[0])
    for prof, row in zip(profiles, res.per_instance()):
        assert np.isfinite([row["J"], row["J_comm"], row["J_comp"]]).all()
        assert row["J"] == pytest.approx(
            0.5 * row["J_comm"] + 0.5 * row["J_comp"], rel=1e-4
        )
        assert row["partitions"] == prof.n_parts
        assert "padded_host_leaks" not in row
        for hosts in row["hosts"]:
            assert len(hosts) == prof.n_parts
            assert all(0 <= h < V for h in hosts)


@given(data=st.data())
@settings(max_examples=5, deadline=None)
def test_property_any_candidate_solves(data):
    """Hypothesis property (skipped cleanly without the extra): ANY
    enumerated cut set of ANY zoo config yields finite, conservation-
    satisfying solve_fleet results through the mixed-P padding."""
    arch = data.draw(st.sampled_from(list(ZOO)))
    cfg = get_config(arch)
    total = total_profile_layers(cfg)
    n_cuts = data.draw(st.integers(min_value=0, max_value=3))
    cuts = tuple(
        sorted(
            data.draw(
                st.lists(
                    st.integers(min_value=1, max_value=total - 1),
                    min_size=n_cuts, max_size=n_cuts, unique=True,
                )
            )
        )
    )
    prof = profile_arch(cfg, seq_len=64, splits=cuts)
    assert np.isfinite(prof.L_bytes).all() and np.isfinite(prof.w_flops).all()
    assert all(v > 0 for v in prof.w_flops)
    res = _solve_candidate_batch([prof])
    row = res.per_instance()[0]
    assert np.isfinite([row["J"], row["J_comm"], row["J_comp"]]).all()
    assert row["J"] == pytest.approx(
        0.5 * row["J_comm"] + 0.5 * row["J_comp"], rel=1e-4
    )
    assert row["partitions"] == prof.n_parts
    assert "padded_host_leaks" not in row
