"""Dry-run + roofline harness tests.

The full 80-cell matrix runs offline (results/dryrun/*.json are committed
artifacts); here we (a) validate the HLO cost model against analytic FLOPs,
(b) run one real production-mesh cell in a subprocess (XLA_FLAGS isolation),
(c) check the recorded artifacts cover every required (arch x shape x mesh)
cell, and (d) sanity-check the roofline math."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
RESULTS = REPO / "results" / "dryrun"


# ---------------------------------------------------------------------------
# HLO cost model
# ---------------------------------------------------------------------------
class TestHloCost:
    def test_dot_flops_exact(self):
        import jax
        import jax.numpy as jnp
        from repro.launch import hlo_cost

        a = jnp.zeros((128, 256), jnp.float32)
        b = jnp.zeros((256, 64), jnp.float32)
        hlo = jax.jit(lambda x, y: x @ y).lower(a, b).compile().as_text()
        res = hlo_cost.analyze(hlo)
        assert res["flops"] == 2 * 128 * 256 * 64

    def test_scan_trip_scaling(self):
        import jax
        import jax.numpy as jnp
        from repro.launch import hlo_cost

        def f(x, w):
            def body(c, _):
                return c @ w, None

            out, _ = jax.lax.scan(body, x, None, length=7)
            return out

        x = jnp.zeros((32, 32), jnp.float32)
        hlo = jax.jit(f).lower(x, x).compile().as_text()
        res = hlo_cost.analyze(hlo)
        assert res["flops"] == 7 * 2 * 32 * 32 * 32, res["flops"]

    def test_flops_close_to_analytic_train(self):
        """Whole-model check: HLO flops within 2x of 6*N*D (remat/attn gap)."""
        import dataclasses
        import jax
        from repro.configs import get_config
        from repro.launch import hlo_cost
        from repro.launch import steps as St
        from repro.models.config import SHAPES

        cfg = dataclasses.replace(
            get_config("qwen1.5-0.5b"), remat=False, n_layers=4
        )
        shape = dataclasses.replace(SHAPES["train_4k"], global_batch=4, seq_len=512)
        step = St.make_train_step(cfg)
        p = St.param_specs(cfg)
        o = St.opt_specs(cfg)
        b = St.batch_specs(cfg, shape)
        hlo = jax.jit(step).lower(p, o, b).compile().as_text()
        res = hlo_cost.analyze(hlo)
        toks = shape.global_batch * shape.seq_len
        analytic = 6.0 * cfg.n_active_params() * toks
        assert 0.5 < res["flops"] / analytic < 2.0, res["flops"] / analytic


# ---------------------------------------------------------------------------
# one real production-mesh cell (subprocess: needs fresh XLA_FLAGS)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", "qwen1.5-0.5b", "--shape", "decode_32k",
            "--tag", "citest",
        ],
        env=env, capture_output=True, text=True, timeout=1200, cwd=str(REPO),
    )
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    assert "OK " in r.stdout
    rec = json.loads(
        (RESULTS / "qwen1.5-0.5b__decode_32k__16x16-citest.json").read_text()
    )
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["hlo_flops"] > 0
    assert "all-gather" in rec["collective_bytes"] or "all-reduce" in rec["collective_bytes"]


# ---------------------------------------------------------------------------
# the committed 80-cell matrix is complete
# ---------------------------------------------------------------------------
def test_dryrun_matrix_complete():
    from repro.configs import ARCHS, get_config
    from repro.models.config import SHAPES, shape_applicable

    # Tagged files (e.g. the -citest cell above) are one-off runs, not the
    # committed matrix; only untagged arch__shape__mesh.json artifacts count.
    have_matrix = RESULTS.exists() and any(
        "-" not in f.stem.split("__")[-1] for f in RESULTS.glob("*__*__*.json")
    )
    if not have_matrix:
        pytest.skip("dry-run matrix artifacts not generated yet")

    missing, failed = [], []
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                f = RESULTS / f"{arch}__{shape}__{mesh}.json"
                if not f.exists():
                    missing.append(f.name)
                    continue
                rec = json.loads(f.read_text())
                ok, _ = shape_applicable(get_config(arch), SHAPES[shape])
                want = "ok" if ok else "skipped"
                if rec["status"] != want:
                    failed.append((f.name, rec["status"], rec.get("error", "")[:100]))
    assert not missing, f"{len(missing)} cells missing: {missing[:5]}"
    assert not failed, failed[:3]


def test_dryrun_skips_match_design():
    """long_500k skips exactly the pure full-attention archs."""
    from repro.configs import ARCHS, get_config
    from repro.models.config import SHAPES, shape_applicable

    runs = {a for a in ARCHS if shape_applicable(get_config(a), SHAPES["long_500k"])[0]}
    assert runs == {"mamba2-370m", "hymba-1.5b", "mixtral-8x22b"}


# ---------------------------------------------------------------------------
# roofline math
# ---------------------------------------------------------------------------
class TestRoofline:
    def test_terms_and_dominance(self):
        from benchmarks.roofline import analyze_record

        rec = {
            "status": "ok", "arch": "qwen1.5-0.5b", "shape": "train_4k",
            "mesh": "16x16", "n_devices": 256,
            "hlo_flops": 197e12,  # exactly 1 second of compute
            "hlo_bytes_accessed": 819e9 * 2,  # 2 seconds of HBM
            "collective_bytes": {"all-reduce": 50e9},  # 2 s (factor 2)
        }
        a = analyze_record(rec)
        assert abs(a["t_compute_s"] - 1.0) < 1e-9
        assert abs(a["t_memory_s"] - 2.0) < 1e-9
        assert abs(a["t_collective_s"] - 2.0) < 1e-9
        assert a["dominant"] in ("memory", "collective")
        assert 0 < a["mfu_bound"] <= 1.0

    def test_model_flops_kinds(self):
        from benchmarks.roofline import model_flops

        train = model_flops("qwen1.5-0.5b", "train_4k")
        prefill = model_flops("qwen1.5-0.5b", "prefill_32k")
        decode = model_flops("qwen1.5-0.5b", "decode_32k")
        assert train > prefill > decode > 0

    def test_moe_uses_active_params(self):
        from benchmarks.roofline import model_flops
        from repro.configs import get_config

        mf = model_flops("mixtral-8x22b", "train_4k")
        cfg = get_config("mixtral-8x22b")
        d = 256 * 4096
        assert abs(mf - 6.0 * cfg.n_active_params() * d) < 1e-6 * mf
        assert cfg.n_active_params() < 0.5 * cfg.n_params()
