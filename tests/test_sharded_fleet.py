"""Sharded fleet execution: the round engine over a real instance-axis mesh.

These tests are written against a simulated multi-device CPU mesh:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_sharded_fleet.py

(the dedicated CI job runs exactly that). Tests that need >= 2 devices skip
cleanly on a single-device run; the explicit-fallback tests run everywhere.

What is pinned here:

  * sharded vs unsharded `solve_fleet` parity at rtol 1e-5 for all four
    methods on a mixed-size fleet, including a non-divisible batch (B=10 on
    8 devices) that now pads-and-trims instead of silently no-oping;
  * engine outputs actually carry the fleet `NamedSharding` — not a
    replicated fallback (`carries_fleet_sharding` + `ShardPlan.output_sharded`);
  * the DESIGN.md section 9 inertness contract extended across shard
    boundaries: phantom pad instances and tail repeats are *bitwise*-inert
    to the real instances' objective/hosts regardless of which device any
    lane lands on (hypothesis property + deterministic anchors).
"""
import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from tests._optional_deps import given, settings, st

from repro.core import iot, mesh as mesh_scenario, random_connected
from repro.core.engine import engine_solve
from repro.distributed.sharding import (
    FLEET_AXIS,
    carries_fleet_sharding,
    fleet_sharding,
    shard_fleet,
)
from repro.fleet import (
    METHODS,
    ShardPlan,
    envelope_cap_chunk,
    pad_batch_to_multiple,
    pad_problem_parts,
    sample_fleet,
    solve_fleet,
    stack_problems,
)
from repro.launch.mesh import make_fleet_mesh

N_DEV = len(jax.devices())
needs_mesh = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >= 2 devices; run under "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8",
)

# Small budgets: every solve below compiles once per (V, A, B, kwargs)
# signature and parity is structural, not about deep convergence.
SOLVE_KW = dict(m_max=3, t_phi=3, alpha=0.5, tol=1e-3, patience=4)


def _pool():
    """Mixed-size instance pool. `mesh_scenario()` comes first so every
    prefix of the pool shares one (V, A) envelope — the bitwise tests rely
    on the envelope (and hence the compiled program) not changing when
    later, smaller instances are swapped around."""
    return [
        mesh_scenario(),
        iot(),
        random_connected(12, 5, seed=3),
        random_connected(20, 8, seed=4),
        random_connected(16, 6, seed=5),
        random_connected(14, 7, seed=6),
        random_connected(18, 9, seed=7),
        random_connected(11, 4, seed=8),
    ]


def _assert_parity(sharded, unsharded, rtol=1e-5):
    np.testing.assert_allclose(sharded.J, unsharded.J, rtol=rtol)
    np.testing.assert_allclose(sharded.J_comm, unsharded.J_comm, rtol=rtol)
    np.testing.assert_allclose(sharded.J_comp, unsharded.J_comp, rtol=rtol)
    np.testing.assert_array_equal(sharded.iters, unsharded.iters)
    np.testing.assert_array_equal(sharded.hosts, unsharded.hosts)
    np.testing.assert_allclose(sharded.history, unsharded.history, rtol=rtol)


# ---------------------------------------------------------------------------
# Sharded vs unsharded parity on the simulated mesh
# ---------------------------------------------------------------------------
@needs_mesh
class TestShardedParity:
    @pytest.mark.parametrize("method", METHODS)
    def test_matches_unsharded_all_methods(self, method):
        fleet = _pool()[:N_DEV] if N_DEV <= 8 else _pool()
        res_s = solve_fleet(fleet, method=method, shard=True, **SOLVE_KW)
        res_u = solve_fleet(fleet, method=method, shard=False, **SOLVE_KW)
        _assert_parity(res_s, res_u)
        assert res_s.shard.sharded
        assert res_s.shard.reason == "sharded"
        assert res_s.shard.n_devices == N_DEV

    def test_non_divisible_batch_pads_and_trims(self):
        """B=10 on 8 devices: the old hook silently fell back to one device;
        now the batch is padded to the next device multiple with inert
        repeats, solved sharded, and trimmed back to 10 results."""
        pool = _pool()
        fleet = pool + pool[:2]
        assert len(fleet) % N_DEV != 0
        res_s = solve_fleet(fleet, shard=True, **SOLVE_KW)
        res_u = solve_fleet(fleet, shard=False, **SOLVE_KW)
        _assert_parity(res_s, res_u)
        assert res_s.n_instances == len(fleet)
        expected = -(-len(fleet) // N_DEV) * N_DEV
        assert res_s.shard.padded_batch == expected
        assert res_s.shard.sharded and res_s.shard.output_sharded

    def test_chunked_and_sharded_compose(self):
        """chunk_size is rounded up to a device multiple so every chunk runs
        the committed layout; results still match the unsharded path."""
        pool = _pool()
        fleet = pool + pool[:4]  # 12 instances
        res_s = solve_fleet(
            fleet, shard=True, chunk_size=N_DEV // 2 + 1, **SOLVE_KW
        )
        res_u = solve_fleet(fleet, shard=False, **SOLVE_KW)
        _assert_parity(res_s, res_u)
        assert res_s.shard.output_sharded
        # every chunk padded to a device multiple
        assert res_s.shard.padded_batch % N_DEV == 0

    def test_colocated_mixed_fleet(self):
        fleet = _pool()
        res_s = solve_fleet(fleet, method="CoLocated", shard=True, **SOLVE_KW)
        res_u = solve_fleet(fleet, method="CoLocated", shard=False, **SOLVE_KW)
        _assert_parity(res_s, res_u)


# ---------------------------------------------------------------------------
# Outputs really are laid out over the fleet axis (no silent fallback)
# ---------------------------------------------------------------------------
@needs_mesh
class TestOutputsCarryFleetSharding:
    def test_engine_outputs_carry_named_sharding(self):
        """Drive the engine directly with committed inputs and check the
        device layout of what comes back — not a proxy flag."""
        fleet, _ = pad_batch_to_multiple(_pool(), N_DEV)
        stacked, info = stack_problems(fleet)
        fmesh = make_fleet_mesh()
        stacked, info = shard_fleet((stacked, info), fmesh)
        assert stacked.net.adj.sharding == fleet_sharding(fmesh)
        out = engine_solve(stacked, colocate=False, **SOLVE_KW)
        for key in ("J", "J_comm", "J_comp", "hosts", "history", "iters"):
            assert carries_fleet_sharding(out[key]), (
                f"engine output {key!r} lost the fleet sharding: "
                f"{getattr(out[key], 'sharding', None)}"
            )
        assert out["J"].sharding.spec == P(FLEET_AXIS)

    def test_fleet_result_records_output_sharding(self):
        res = solve_fleet(_pool(), shard=True, **SOLVE_KW)
        assert res.shard.output_sharded
        assert res.shard.n_devices == N_DEV

    def test_carries_fleet_sharding_rejects_fallbacks(self):
        fmesh = make_fleet_mesh()
        x = jax.device_put(np.arange(float(2 * N_DEV)), fleet_sharding(fmesh))
        assert carries_fleet_sharding(x)
        assert not carries_fleet_sharding(np.arange(8.0))  # host array
        assert not carries_fleet_sharding(jax.numpy.arange(8.0))  # 1 device
        replicated = jax.device_put(
            jax.numpy.arange(8.0),
            jax.sharding.NamedSharding(fmesh, P()),
        )
        assert not carries_fleet_sharding(replicated)


# ---------------------------------------------------------------------------
# Explicit layout decisions (run on any device count)
# ---------------------------------------------------------------------------
class TestExplicitLayoutDecisions:
    def test_unsharded_plan_is_explicit(self):
        res = solve_fleet([iot(), random_connected(12, 5, seed=3)], **SOLVE_KW)
        assert res.shard == ShardPlan(
            requested=False, n_devices=1, batch=2, padded_batch=2,
            reason="not-requested", output_sharded=False,
        )

    def test_single_device_fallback_is_logged(self, caplog):
        """shard=True on a 1-device mesh must run, must say so in the plan,
        and must warn — the silent-fallback bug this PR removes."""
        fleet = [iot(), random_connected(12, 5, seed=3)]
        with caplog.at_level("WARNING", logger="repro.fleet"):
            res = solve_fleet(fleet, shard=True, devices=1, **SOLVE_KW)
        assert res.shard.requested and not res.shard.sharded
        assert res.shard.reason == "single-device"
        assert not res.shard.output_sharded
        assert any("single-device" in r.message for r in caplog.records)
        ref = solve_fleet(fleet, **SOLVE_KW)
        np.testing.assert_allclose(res.J, ref.J, rtol=1e-5)

    def test_too_many_devices_raises(self):
        with pytest.raises(ValueError, match="devices"):
            solve_fleet([iot()], shard=True, devices=N_DEV + 1, **SOLVE_KW)

    def test_devices_without_shard_raises(self):
        with pytest.raises(ValueError, match="shard"):
            solve_fleet([iot()], devices=1, **SOLVE_KW)

    def test_shard_plan_serializes(self):
        """The CLI emits the plan as JSON; keep it a plain-data dataclass."""
        res = solve_fleet([iot()], **SOLVE_KW)
        d = dataclasses.asdict(res.shard)
        assert d["reason"] == "not-requested"
        assert isinstance(d["padded_batch"], int)


# ---------------------------------------------------------------------------
# Per-tier envelope caps
# ---------------------------------------------------------------------------
class TestEnvelopeCap:
    def test_cap_bounds_chunk_for_tier(self):
        fleet = [random_connected(24, 10, seed=s) for s in range(6)]
        # Tiny budget: forces chunking; generous budget: leaves one batch.
        tiny = envelope_cap_chunk(fleet, round_to=1, n_devices=1, cap_gb=1e-4)
        big = envelope_cap_chunk(fleet, round_to=1, n_devices=1, cap_gb=64.0)
        assert 1 <= tiny < len(fleet) <= big
        # More devices admit proportionally more lanes per chunk.
        assert envelope_cap_chunk(
            fleet, round_to=1, n_devices=4, cap_gb=1e-4
        ) == 4 * tiny

    def test_capped_solve_matches_uncapped(self):
        fleet = [random_connected(14, 6, seed=s) for s in range(5)]
        ref = solve_fleet(fleet, **SOLVE_KW)
        capped = solve_fleet(fleet, envelope_cap_gb=1e-4, **SOLVE_KW)
        np.testing.assert_allclose(capped.J, ref.J, rtol=1e-5)
        np.testing.assert_array_equal(capped.hosts, ref.hosts)

    def test_cap_rejects_nonpositive(self):
        with pytest.raises(ValueError, match="positive"):
            envelope_cap_chunk([iot()], round_to=1, n_devices=1, cap_gb=0.0)


# ---------------------------------------------------------------------------
# Inertness across shard boundaries (DESIGN.md section 9, extended)
# ---------------------------------------------------------------------------
@needs_mesh
class TestInertnessAcrossShards:
    """Phantom pad instances and tail repeats must be *bitwise*-inert to the
    real instances' objective and hosts regardless of which device any lane
    lands on. Engine lanes are arithmetically independent (the only
    cross-instance op is the `any_active` exit reduction, which can only
    add freeze-masked — hence bit-identical — trips), so swapping what the
    other lanes contain, or where a real instance sits in the batch, must
    not change its result by a single bit."""

    def _solve(self, fleet):
        return solve_fleet(fleet, shard=True, **SOLVE_KW)

    def test_rotation_moves_instances_across_devices_bitwise(self):
        pool = _pool()
        base = self._solve(pool)
        for rot in (1, 3, 5):
            rotated = pool[rot:] + pool[:rot]
            res = self._solve(rotated)
            np.testing.assert_array_equal(
                np.concatenate([res.J[-rot:], res.J[:-rot]]), base.J
            )
            np.testing.assert_array_equal(
                np.concatenate([res.hosts[-rot:], res.hosts[:-rot]]),
                base.hosts,
            )

    def test_tail_repeats_bitwise_inert(self):
        """Auto-padding repeats (B=6 -> 8) give the same bits as solving the
        divisible fleet, and each repeat lane reproduces lane 0 exactly."""
        pool = _pool()[:6]
        res = self._solve(pool)  # pads 6 -> 8 internally
        explicit = self._solve(pool + [pool[0], pool[0]])
        np.testing.assert_array_equal(explicit.J[:6], res.J)
        np.testing.assert_array_equal(explicit.hosts[:6], res.hosts)
        np.testing.assert_array_equal(
            explicit.J[6:], np.repeat(res.J[:1], 2)
        )

    @settings(max_examples=8, deadline=None)
    @given(
        # n_real <= 6 keeps fleet + phantom <= 8 lanes, so every draw pads
        # to the SAME lane count and reuses one compiled program.
        n_real=st.integers(min_value=1, max_value=6),
        rot=st.integers(min_value=0, max_value=7),
        phantom_seed=st.integers(min_value=0, max_value=3),
    )
    def test_property_phantoms_and_position_bitwise_inert(
        self, n_real, rot, phantom_seed
    ):
        """For any real-prefix size, lane rotation, and appended phantom
        instance: the real instances' J/hosts are bitwise unchanged.

        The pool's first instance fixes the (V, A) envelope and the unified
        hop bound, and every solve pads to the same lane count, so all draws
        share ONE compiled program — any bit that changes would be a lane
        leaking across a shard boundary."""
        pool = _pool()
        fleet = pool[:1] + pool[1 : 1 + n_real]  # envelope-dominant + n_real
        base = self._solve(fleet)

        # (a) phantom appended: a small instance that changes neither the
        # envelope nor the unified hop bound.
        phantom = random_connected(8, 3, seed=100 + phantom_seed)
        with_phantom = self._solve(fleet + [phantom])
        np.testing.assert_array_equal(with_phantom.J[: len(fleet)], base.J)
        np.testing.assert_array_equal(
            with_phantom.hosts[: len(fleet)], base.hosts
        )

        # (b) rotation: same instances on different lanes/devices.
        r = rot % len(fleet)
        if r:
            rotated = self._solve(fleet[r:] + fleet[:r])
            np.testing.assert_array_equal(
                np.concatenate([rotated.J[-r:], rotated.J[:-r]]), base.J
            )
            np.testing.assert_array_equal(
                np.concatenate([rotated.hosts[-r:], rotated.hosts[:-r]]),
                base.hosts,
            )


# ---------------------------------------------------------------------------
# Phantom *stages* across shard boundaries (DESIGN.md section 13)
# ---------------------------------------------------------------------------
@needs_mesh
class TestStagePaddingAcrossShards:
    """The section 9 inertness contract extended to the stage axis on a real
    mesh: padding split depths (phantom stages, `Apps.parts` gating) must be
    bitwise-invisible to every real lane regardless of which device it lands
    on, and mixed-P fleets must keep sharded == unsharded parity."""

    def test_mixed_p_fleet_sharded_parity(self):
        fleet = sample_fleet(8, seed=21, partitions=(1, 2, 3))
        assert sorted({p.apps.n_parts for p in fleet}) == [1, 2, 3]
        res_s = solve_fleet(fleet, shard=True, **SOLVE_KW)
        res_u = solve_fleet(fleet, shard=False, **SOLVE_KW)
        _assert_parity(res_s, res_u)
        assert res_s.shard.sharded and res_s.shard.output_sharded

    def test_stage_padding_bitwise_on_mesh(self):
        """Padding every instance of the P=2 pool to K=5 (P=4 envelope with
        two phantom stages each) leaves the sharded solve bitwise on J,
        history, and the real partitions' hosts."""
        pool = _pool()
        base = solve_fleet(pool, shard=True, **SOLVE_KW)
        padded = [pad_problem_parts(p, 4) for p in pool]
        res = solve_fleet(padded, shard=True, **SOLVE_KW)
        assert res.shard.output_sharded
        np.testing.assert_array_equal(res.J, base.J)
        np.testing.assert_array_equal(res.history, base.history)
        np.testing.assert_array_equal(res.iters, base.iters)
        np.testing.assert_array_equal(res.hosts[:, :, :2], base.hosts)

    @settings(max_examples=4, deadline=None)
    @given(
        k_env=st.integers(min_value=4, max_value=6),
        rot=st.integers(min_value=0, max_value=7),
    )
    def test_property_stage_padding_and_rotation_bitwise(self, k_env, rot):
        """For any K envelope and lane rotation: real results are bitwise
        unchanged by phantom stages, wherever each lane lands."""
        pool = _pool()
        base = solve_fleet(pool, shard=True, **SOLVE_KW)
        padded = [pad_problem_parts(p, k_env - 1) for p in pool]
        r = rot % len(padded)
        rotated = padded[r:] + padded[:r]
        res = solve_fleet(rotated, shard=True, **SOLVE_KW)
        J = np.concatenate([res.J[-r:], res.J[:-r]]) if r else res.J
        hosts = (
            np.concatenate([res.hosts[-r:], res.hosts[:-r]]) if r else res.hosts
        )
        np.testing.assert_array_equal(J, base.J)
        np.testing.assert_array_equal(hosts[:, :, :2], base.hosts)
