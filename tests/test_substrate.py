"""Substrate tests: data pipeline, checkpointing (fault tolerance, elastic
restore), optimizer, gradient compression, quantization, train loop."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _optional_deps import given, settings, st

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, make_pipeline
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule
from repro.optim.compression import compress_int8, decompress_int8
from repro.models.quant import dequantize_leaf, quantize_leaf, quantize_params


# ---------------------------------------------------------------------------
# data pipeline: determinism + checkpointable stream position
# ---------------------------------------------------------------------------
class TestData:
    CFG = DataConfig(vocab=1000, seq_len=64, global_batch=4, seed=7)

    def test_deterministic(self):
        a = make_pipeline(self.CFG)
        b = make_pipeline(self.CFG)
        for _ in range(3):
            np.testing.assert_array_equal(a.next_batch(), b.next_batch())

    def test_resume_mid_stream(self):
        a = make_pipeline(self.CFG)
        for _ in range(5):
            a.next_batch()
        state = a.state()
        want = a.next_batch()
        b = make_pipeline(self.CFG, state)
        np.testing.assert_array_equal(b.next_batch(), want)

    def test_batches_differ_across_steps(self):
        a = make_pipeline(self.CFG)
        assert not np.array_equal(a.next_batch(), a.next_batch())

    def test_tokens_in_range(self):
        a = make_pipeline(self.CFG)
        batch = a.next_batch()
        assert batch.min() >= 0 and batch.max() < self.CFG.vocab


# ---------------------------------------------------------------------------
# checkpoint manager: atomicity, keep-K, elastic restore
# ---------------------------------------------------------------------------
class TestCheckpoint:
    def _tree(self, key=0):
        k = jax.random.PRNGKey(key)
        return {
            "w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(5.0), "step": jnp.int32(3)},
        }

    def test_roundtrip(self, tmp_path):
        cm = CheckpointManager(tmp_path)
        tree = self._tree()
        cm.save(10, tree, extra={"data": {"seed": 1, "step": 10}})
        got, extra, step = cm.restore(None, tree)
        assert step == 10 and extra["data"]["step"] == 10
        jax.tree.map(np.testing.assert_array_equal, got, tree)

    def test_keep_k_prunes(self, tmp_path):
        cm = CheckpointManager(tmp_path, keep=2)
        t = self._tree()
        for s in (1, 2, 3, 4):
            cm.save(s, t)
        assert cm.steps() == [3, 4]

    def test_latest_and_explicit_step(self, tmp_path):
        cm = CheckpointManager(tmp_path, keep=5)
        cm.save(1, self._tree(1))
        cm.save(2, self._tree(2))
        got, _, step = cm.restore(1, self._tree())
        assert step == 1
        jax.tree.map(np.testing.assert_array_equal, got, self._tree(1))

    def test_interrupted_save_keeps_previous(self, tmp_path):
        """A .tmp dir left behind by a crash must not shadow the good ckpt."""
        cm = CheckpointManager(tmp_path)
        cm.save(5, self._tree())
        (tmp_path / "step_00000009.tmp").mkdir()
        assert cm.latest_step() == 5
        got, _, step = cm.restore(None, self._tree())
        assert step == 5

    def test_elastic_restore_other_mesh(self, tmp_path):
        """Save unsharded, restore onto a different sharding (mesh reshape)."""
        cm = CheckpointManager(tmp_path)
        tree = {"w": jnp.arange(32.0).reshape(8, 4)}
        cm.save(1, tree)
        mesh = jax.make_mesh((1,), ("data",))
        from jax.sharding import NamedSharding, PartitionSpec as P

        sh = {"w": NamedSharding(mesh, P("data", None))}
        got, _, _ = cm.restore(None, tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
        assert got["w"].sharding == sh["w"]

    def test_structure_mismatch_rejected(self, tmp_path):
        cm = CheckpointManager(tmp_path)
        cm.save(1, self._tree())
        with pytest.raises(AssertionError):
            cm.restore(None, {"only_one": jnp.zeros(3)})


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
class TestOptim:
    def test_adamw_converges_quadratic(self):
        params = {"x": jnp.array([3.0, -2.0])}
        opt = adamw_init(params)
        for _ in range(300):
            grads = jax.grad(lambda p: jnp.sum(jnp.square(p["x"])))(params)
            params, opt = adamw_update(grads, opt, params, 0.05, weight_decay=0.0)
        assert float(jnp.max(jnp.abs(params["x"]))) < 0.05

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((4,), 10.0)}
        clipped, gn = clip_by_global_norm(g, 1.0)
        assert abs(float(gn) - 20.0) < 1e-4
        total = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
        assert abs(total - 1.0) < 1e-4

    def test_cosine_schedule_shape(self):
        lrs = [
            float(cosine_schedule(jnp.int32(s), peak_lr=1.0, warmup=10, total=100))
            for s in (0, 5, 10, 55, 100)
        ]
        assert lrs[0] == 0.0 and abs(lrs[2] - 1.0) < 1e-6
        assert lrs[1] < lrs[2] and lrs[3] < lrs[2] and lrs[4] <= lrs[3]

    def test_weight_decay_shrinks(self):
        params = {"x": jnp.array([1.0])}
        opt = adamw_init(params)
        zero_g = {"x": jnp.zeros(1)}
        p2, _ = adamw_update(zero_g, opt, params, 0.1, weight_decay=0.5)
        assert float(p2["x"][0]) < 1.0


# ---------------------------------------------------------------------------
# gradient compression (error feedback)
# ---------------------------------------------------------------------------
class TestCompression:
    def test_roundtrip_error_bounded(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        q, s, err = compress_int8(x)
        recon = decompress_int8(q, s)
        rel = float(jnp.linalg.norm(recon - x) / jnp.linalg.norm(x))
        assert rel < 0.01

    def test_error_feedback_unbiased_over_steps(self):
        """With error feedback, the accumulated transmitted signal tracks the
        accumulated true signal (the residual stays bounded)."""
        key = jax.random.PRNGKey(1)
        err = jnp.zeros((256,))
        sent = jnp.zeros((256,))
        total = jnp.zeros((256,))
        for i in range(50):
            key, k = jax.random.split(key)
            g = jax.random.normal(k, (256,)) * (1.0 + i % 3)
            total = total + g
            q, s, err = compress_int8(g, err)
            sent = sent + decompress_int8(q, s)
        drift = float(jnp.linalg.norm(sent - total) / jnp.linalg.norm(total))
        assert drift < 0.01

    @given(st.integers(0, 2**31 - 1), st.floats(0.1, 1e4))
    @settings(max_examples=20, deadline=None)
    def test_compression_scale_invariant(self, seed, scale):
        x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
        q, s, _ = compress_int8(x)
        recon = decompress_int8(q, s)
        assert float(jnp.max(jnp.abs(recon - x))) <= float(s) * 0.51 + 1e-6


# ---------------------------------------------------------------------------
# int8 weight-only quantization
# ---------------------------------------------------------------------------
class TestQuant:
    def test_leaf_roundtrip(self):
        w = jax.random.normal(jax.random.PRNGKey(0), (64, 1024)) * 0.02
        q = quantize_leaf(w)
        rel = float(
            jnp.linalg.norm(dequantize_leaf(q, jnp.float32) - w) / jnp.linalg.norm(w)
        )
        assert rel < 0.01

    def test_per_layer_scales(self):
        w = jnp.stack([jnp.ones((4, 4)) * 0.001, jnp.ones((4, 4)) * 100.0])
        q = quantize_leaf(w, per_layer=True)
        assert q["__s"].shape == (2,)
        back = dequantize_leaf(q, jnp.float32)
        np.testing.assert_allclose(back, w, rtol=0.01)

    def test_tree_quantization_targets_large_leaves(self):
        from repro.configs import reduced_config
        from repro.models import init_params

        cfg = reduced_config("qwen1.5-0.5b")
        params = init_params(cfg, jax.random.PRNGKey(0))
        q = quantize_params(params)
        # Embedding is large -> quantized; norms stay float.
        assert "__q" in q["embed"]["embed"]
        assert not isinstance(q["final_norm"], dict)


# ---------------------------------------------------------------------------
# train loop end-to-end (subprocess; exercises checkpoint + resume + signals)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_train_loop_resume(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    base = [
        sys.executable, "-m", "repro.launch.train", "--arch", "qwen1.5-0.5b",
        "--reduced", "--global-batch", "4", "--seq-len", "64",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "4", "--log-every", "2",
    ]
    r1 = subprocess.run(
        base + ["--steps", "8"], env=env, capture_output=True, text=True,
        timeout=600, cwd="/root/repo",
    )
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = subprocess.run(
        base + ["--steps", "12", "--resume"], env=env, capture_output=True,
        text=True, timeout=600, cwd="/root/repo",
    )
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 8" in r2.stdout
