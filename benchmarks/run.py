"""Benchmark harness entry point: one benchmark per paper table/figure plus
the framework-level benches. Prints `name,<payload>` lines and exits nonzero
if any paper claim fails.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,fig4,...]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    fig2_scenarios,
    fig4_load_sweep,
    fig5_tradeoff,
    fleet_bench,
    kernel_bench,
    roofline,
    scale_control_plane,
    table1_topologies,
)

# Every benchmarks/*.py module (except this harness) is registered here, so
# --only accepts each by name and the table is the complete inventory.
BENCHES = {
    "table1": table1_topologies.run,   # Table I scenario configs
    "fig2": fig2_scenarios.run,        # scenarios x methods (headline)
    "fig4": fig4_load_sweep.run,       # load sweep (batched fleet)
    "fig5": fig5_tradeoff.run,         # comm/comp tradeoff (batched fleet)
    "kernels": kernel_bench.run,       # Pallas kernels vs oracles
    "scale": scale_control_plane.run,  # beyond-paper: fleet-scale control
    "fleet": fleet_bench.run,          # batched-vs-sequential fleet engine
    "roofline": roofline.run,          # informational; needs dry-run artifacts
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    args = ap.parse_args()
    names = list(BENCHES) if not args.only else args.only.split(",")
    failures = []
    for name in names:
        t0 = time.time()
        print(f"=== {name} ===", flush=True)
        try:
            BENCHES[name]()
            print(f"=== {name} done ({time.time() - t0:.1f}s) ===", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"FAILED: {failures}")
        return 1
    print("all benchmarks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
