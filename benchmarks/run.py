"""Benchmark harness entry point: one benchmark per paper table/figure plus
the framework-level benches. Prints `name,<payload>` lines and exits nonzero
if any paper claim fails.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,fig4,...] [--json-out]
        [--check-trend] [--trend-tol 0.2] [--trend-metrics all|ratios]

`--json-out` persists each bench's result dict as `BENCH_<name>.json` at the
repo root (commit hash + dirty-worktree flag + timings + speedups), so the
perf trajectory is tracked PR-over-PR and CI can upload the files as
artifacts. Under
SCALE_SMALL=1 the file is `BENCH_<name>.small.json` instead: small-tier
smoke numbers must never overwrite (or be compared against) the full-scale
trajectory.

`--check-trend` is the trend-lint: it compares the fresh result against the
committed baseline JSON for the same scale tier and exits nonzero on a
>`--trend-tol` (default 20%) regression of any per-round timing (lower is
better) or speedup/ratio metric (higher is better). `--trend-metrics ratios`
restricts the check to machine-portable metrics — what CI uses, since raw
per-round milliseconds are only comparable on similar hardware. Portable
metrics are the speedups/ratios plus the solver-telemetry counts
(`rounds_executed`, `pad_overhead`): more rounds to hit the same tolerance
is a convergence regression no matter the machine. A baseline section
recorded as `{"skipped": true}` is REFUSED when the fresh run produced
numbers for it (a partial baseline lints nothing, forever), and a baseline
whose commit is not an ancestor of HEAD — or that was measured from a
dirty worktree — is warned about.

When REPRO_FLEET_SECTIONS explicitly requests the fleet bench's
`shard_axis` section, the harness sets
`XLA_FLAGS=--xla_force_host_platform_device_count=8` BEFORE importing jax,
so a single-device host that asks for the mesh section actually gets a
mesh. The default run leaves XLA_FLAGS alone — forcing the split shifts
every other section's warm timings, so committed baselines stay measured
on the native topology.

Observability (DESIGN.md section 14): each bench runs inside a host span
and with a cleared metrics registry; whatever the instrumented solvers
record lands in the bench result under "metrics", so the committed BENCH
files carry telemetry alongside timings. REPRO_TRACE=path.jsonl records
the span trace across the whole run.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time
import traceback


def _fleet_shard_requested() -> bool:
    """Was the fleet bench's shard-axis section EXPLICITLY requested?

    Explicit means REPRO_FLEET_SECTIONS names `shard_axis` (and --only does
    not exclude the fleet bench). The default run deliberately does NOT
    count: forcing the simulated 8-device mesh reshapes the host's XLA
    device topology, which shifts every section's warm timings (measured:
    the batched engine loses ~30% warm throughput under the split), so the
    committed baselines must be measured without it and the shard section
    reports itself skipped on single-device hosts instead.
    """
    only = None
    for i, a in enumerate(sys.argv):
        if a == "--only" and i + 1 < len(sys.argv):
            only = sys.argv[i + 1]
        elif a.startswith("--only="):
            only = a.split("=", 1)[1]
    if only is not None and "fleet" not in only.split(","):
        return False
    sections = os.environ.get("REPRO_FLEET_SECTIONS")
    if not sections:
        return False
    return "shard_axis" in [s.strip() for s in sections.split(",")]


# Must run BEFORE anything imports jax: the XLA platform reads XLA_FLAGS at
# backend initialization, so a single-device host can only present the
# simulated 8-CPU mesh the shard-axis section needs if the flag is already
# set here. If jax snuck in first (run.py imported from another script),
# leave the environment alone — a flag change would silently not apply.
if _fleet_shard_requested() and "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8"
        ).strip()

from benchmarks import (  # noqa: E402
    fig2_scenarios,
    fig4_load_sweep,
    fig5_tradeoff,
    fleet_bench,
    kernel_bench,
    pareto_bench,
    roofline,
    scale_control_plane,
    serve_bench,
    table1_topologies,
)
from repro.obs import metrics as obs_metrics  # noqa: E402
from repro.obs import trace as obs_trace  # noqa: E402

# Every benchmarks/*.py module (except this harness) is registered here, so
# --only accepts each by name and the table is the complete inventory.
BENCHES = {
    "table1": table1_topologies.run,   # Table I scenario configs
    "fig2": fig2_scenarios.run,        # scenarios x methods (headline)
    "fig4": fig4_load_sweep.run,       # load sweep (batched fleet)
    "fig5": fig5_tradeoff.run,         # comm/comp tradeoff (batched fleet)
    "kernels": kernel_bench.run,       # Pallas kernels vs oracles
    "scale": scale_control_plane.run,  # beyond-paper: fleet-scale control
    "fleet": fleet_bench.run,          # batched-vs-sequential + solver axis
    "pareto": pareto_bench.run,        # split-point Pareto search (DESIGN 17)
    "serve": serve_bench.run,          # chaos control loop (epochs/sec, p95)
    "roofline": roofline.run,          # informational; needs dry-run artifacts
}

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _scale_tier() -> str:
    return "small" if os.environ.get("SCALE_SMALL") else "full"


def bench_json_path(name: str) -> pathlib.Path:
    """Per-tier result file: small-tier smoke runs get their own baseline."""
    suffix = "" if _scale_tier() == "full" else ".small"
    return REPO_ROOT / f"BENCH_{name}{suffix}.json"


def _git(*argv: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        ["git", *argv], cwd=REPO_ROOT, capture_output=True, text=True
    )


def _commit_hash() -> str:
    try:
        r = _git("rev-parse", "HEAD")
        return r.stdout.strip() if r.returncode == 0 else "unknown"
    except Exception:
        return "unknown"


def _worktree_dirty() -> bool | None:
    """Uncommitted changes in tracked files (None if git is unavailable)."""
    try:
        r = _git("status", "--porcelain", "--untracked-files=no")
        return bool(r.stdout.strip()) if r.returncode == 0 else None
    except Exception:
        return None


def _baseline_commit_is_ancestor(commit: str) -> bool | None:
    """Whether `commit` is an ancestor of HEAD (None = undecidable)."""
    if not commit or commit == "unknown":
        return None
    try:
        r = _git("merge-base", "--is-ancestor", commit, "HEAD")
    except Exception:
        return None
    if r.returncode == 0:
        return True
    if r.returncode == 1:
        return False
    return None  # unknown object (shallow clone, foreign repo), can't say


def write_json(name: str, payload, elapsed_s: float) -> pathlib.Path:
    """Persist one bench result as BENCH_<name>[.small].json at the repo root."""
    path = bench_json_path(name)
    record = {
        "bench": name,
        "commit": _commit_hash(),
        # Provenance: a baseline measured from an uncommitted tree is not
        # reproducible from its recorded commit — flag it in the file so a
        # trend comparison (and a reviewer) can see it.
        "dirty": _worktree_dirty(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "scale": _scale_tier(),
        "elapsed_s": round(elapsed_s, 2),
        "result": payload,
    }
    path.write_text(json.dumps(record, indent=1, default=str) + "\n")
    return path


# ---------------------------------------------------------------------------
# Trend lint: fresh timings vs the committed BENCH_<name>.json baseline
# ---------------------------------------------------------------------------
def trend_metrics(result, prefix: str = "") -> dict:
    """Extract comparable leaves: {dotted.path: (value, direction, portable)}.

    direction "lower" — per-round / per-op timings (path contains
    "per_round", or the key is a microsecond/millisecond reading); raw
    end-to-end seconds are deliberately excluded as too noisy. Also the
    solver-telemetry counts (`rounds_executed`, `pad_overhead`): more
    rounds — or more inert pad lanes — at the same tolerance is a
    convergence/layout regression.
    direction "higher" — speedups and ratios.

    portable=True marks metrics comparable across machines (speedups,
    ratios, and the telemetry counts — round counts don't depend on the
    hardware clock); --trend-metrics ratios keeps only those.
    """
    out = {}
    if isinstance(result, dict):
        for k, v in result.items():
            out.update(trend_metrics(v, f"{prefix}{k}."))
        return out
    if not isinstance(result, (int, float)) or isinstance(result, bool):
        return out
    path = prefix.rstrip(".")
    key = path.rsplit(".", 1)[-1]
    if "speedup" in key or "ratio" in key:
        out[path] = (float(result), "higher", True)
    elif "rounds_executed" in key or "pad_overhead" in key:
        out[path] = (float(result), "lower", True)
    elif "per_round" in path or key.endswith(("_ms", "_us")):
        out[path] = (float(result), "lower", False)
    elif key.endswith("_per_s"):
        # Throughput rates (candidates/sec, epochs/sec): higher is better,
        # but absolute rates are hardware-bound — compared only under
        # --trend-metrics all (the pareto CI job uses a generous tol).
        out[path] = (float(result), "higher", False)
    return out


def skipped_sections(result, prefix: str = "") -> list[str]:
    """Dotted paths of every `{"skipped": true}` marker in a result dict."""
    out = []
    if isinstance(result, dict):
        if result.get("skipped") is True:
            out.append(prefix.rstrip("."))
        for k, v in result.items():
            out.extend(skipped_sections(v, f"{prefix}{k}."))
    return out


def check_trend(
    name: str, fresh, baseline_record, *, tol: float, ratios_only: bool
) -> list[str]:
    """Compare one fresh result dict to its committed baseline record.

    Returns human-readable regression strings (empty = clean)."""
    regressions = []
    # Provenance guards. (1) A baseline whose section never ran has no
    # numbers to compare — linting "against" it silently passes forever, so
    # a section the fresh run DID produce numbers for refuses the partial
    # baseline outright. (2) A baseline from a commit that is not an
    # ancestor of HEAD (rebased away, or measured on another branch) is
    # only warned about: the numbers may still be comparable, but the
    # reader should know the trajectory has a seam.
    base_skipped = set(skipped_sections(baseline_record.get("result", {})))
    fresh_skipped = set(skipped_sections(fresh))
    stale = sorted(base_skipped - fresh_skipped)
    if stale:
        for path in stale:
            regressions.append(
                f"{name}:{path} baseline section was recorded as skipped — "
                "no numbers to lint against; regenerate the baseline with "
                "the section enabled"
            )
            print(
                f"trend,{name} {path}: baseline skipped [REFUSED]", flush=True
            )
    b_commit = baseline_record.get("commit", "")
    if _baseline_commit_is_ancestor(b_commit) is False:
        print(
            f"trend,{name} WARNING: baseline commit {b_commit[:12]} is not "
            "an ancestor of HEAD (rebase? foreign baseline?) — comparison "
            "may span divergent code",
            flush=True,
        )
    if baseline_record.get("dirty"):
        print(
            f"trend,{name} WARNING: baseline was recorded from a dirty "
            "worktree — its commit hash does not pin the measured code",
            flush=True,
        )
    base = trend_metrics(baseline_record.get("result", {}))
    new = trend_metrics(fresh)
    for path, (b_val, direction, portable) in sorted(base.items()):
        if path not in new:
            continue
        if ratios_only and not portable:
            continue
        n_val = new[path][0]
        if direction == "lower":
            # Zero-baseline counts (e.g. pad overhead 0.0) can't regress by
            # ratio; any increase from exactly zero is flagged.
            bad = n_val > 0 if b_val == 0 else n_val > b_val * (1.0 + tol)
        else:
            bad = n_val < b_val * (1.0 - tol)
        pct = "" if b_val == 0 else f" ({(n_val / b_val - 1) * 100:+.0f}%)"
        arrow = f"{b_val:.4g} -> {n_val:.4g}{pct}"
        status = "REGRESSION" if bad else "ok"
        print(f"trend,{name} {path}: {arrow} [{status}]", flush=True)
        if bad:
            regressions.append(f"{name}:{path} {arrow}")
    return regressions


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument(
        "--json-out",
        action="store_true",
        help="write BENCH_<name>[.small].json (commit hash + result dict)",
    )
    ap.add_argument(
        "--check-trend",
        action="store_true",
        help="fail on >--trend-tol regressions vs the committed baseline "
        "JSON of the same scale tier",
    )
    ap.add_argument(
        "--trend-tol",
        type=float,
        default=0.2,
        help="fractional regression tolerance for --check-trend (default 0.2)",
    )
    ap.add_argument(
        "--trend-metrics",
        choices=("all", "ratios"),
        default="all",
        help="'ratios' compares only speedups/ratios (machine-portable; "
        "use in CI where absolute timings are not comparable)",
    )
    ap.add_argument(
        "--use-pallas",
        action="store_true",
        help="benchmark the Pallas kernel path instead of pure XLA (exported "
        "to benches via REPRO_BENCH_USE_PALLAS; see benchmarks/_knobs.py)",
    )
    ap.add_argument(
        "--interpret",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="with --use-pallas, run kernel bodies under the Pallas "
        "interpreter (CPU validation). A real TPU/GPU benchmark run passes "
        "--use-pallas --no-interpret; no effect without --use-pallas",
    )
    args = ap.parse_args()
    if args.use_pallas:
        os.environ["REPRO_BENCH_USE_PALLAS"] = "1"
        os.environ["REPRO_BENCH_INTERPRET"] = "1" if args.interpret else "0"
    obs_trace.maybe_configure_from_env()
    names = list(BENCHES) if not args.only else args.only.split(",")
    failures = []
    regressions = []
    for name in names:
        # Read the committed baseline BEFORE --json-out overwrites it.
        baseline = None
        if args.check_trend and bench_json_path(name).exists():
            baseline = json.loads(bench_json_path(name).read_text())
        t0 = time.time()
        print(f"=== {name} ===", flush=True)
        try:
            # Per-bench metrics isolation: whatever the instrumented solvers
            # record during THIS bench rides on its result (and baseline).
            obs_metrics.registry.reset()
            with obs_trace.span("bench", bench=name):
                result = BENCHES[name]()
            if isinstance(result, dict):
                snap = obs_metrics.registry.snapshot()
                if snap:
                    result["metrics"] = snap
            elapsed = time.time() - t0
            if args.check_trend and result is not None:
                if baseline is None:
                    # First run of a new bench (e.g. BENCH_serve.json before
                    # it ever landed): warn AND record the fresh result as
                    # the baseline, so the next run has something to lint
                    # against instead of KeyError-ing or silently skipping
                    # forever.
                    path = write_json(name, result, time.time() - t0)
                    print(
                        f"trend,{name} no committed baseline for tier "
                        f"'{_scale_tier()}' — recorded "
                        f"{path.relative_to(REPO_ROOT)} as the new baseline",
                        flush=True,
                    )
                else:
                    regressions += check_trend(
                        name,
                        result,
                        baseline,
                        tol=args.trend_tol,
                        ratios_only=args.trend_metrics == "ratios",
                    )
            if args.json_out and result is not None:
                path = write_json(name, result, elapsed)
                print(f"wrote {path.relative_to(REPO_ROOT)}", flush=True)
            print(f"=== {name} done ({elapsed:.1f}s) ===", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"FAILED: {failures}")
        return 1
    if regressions:
        print("TREND REGRESSIONS (>{:.0%}):".format(args.trend_tol))
        for r in regressions:
            print(f"  {r}")
        return 2
    print("all benchmarks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
