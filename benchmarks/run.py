"""Benchmark harness entry point: one benchmark per paper table/figure plus
the framework-level benches. Prints `name,<payload>` lines and exits nonzero
if any paper claim fails.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,fig4,...] [--json-out]

`--json-out` persists each bench's result dict as `BENCH_<name>.json` at the
repo root (commit hash + timings + speedups), so the perf trajectory is
tracked PR-over-PR and CI can upload the files as artifacts.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import time
import traceback

from benchmarks import (
    fig2_scenarios,
    fig4_load_sweep,
    fig5_tradeoff,
    fleet_bench,
    kernel_bench,
    roofline,
    scale_control_plane,
    table1_topologies,
)

# Every benchmarks/*.py module (except this harness) is registered here, so
# --only accepts each by name and the table is the complete inventory.
BENCHES = {
    "table1": table1_topologies.run,   # Table I scenario configs
    "fig2": fig2_scenarios.run,        # scenarios x methods (headline)
    "fig4": fig4_load_sweep.run,       # load sweep (batched fleet)
    "fig5": fig5_tradeoff.run,         # comm/comp tradeoff (batched fleet)
    "kernels": kernel_bench.run,       # Pallas kernels vs oracles
    "scale": scale_control_plane.run,  # beyond-paper: fleet-scale control
    "fleet": fleet_bench.run,          # batched-vs-sequential + solver axis
    "roofline": roofline.run,          # informational; needs dry-run artifacts
}

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _commit_hash() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=REPO_ROOT,
            capture_output=True,
            text=True,
            check=True,
        ).stdout.strip()
    except Exception:
        return "unknown"


def write_json(name: str, payload, elapsed_s: float) -> pathlib.Path:
    """Persist one bench result as BENCH_<name>.json at the repo root."""
    path = REPO_ROOT / f"BENCH_{name}.json"
    record = {
        "bench": name,
        "commit": _commit_hash(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "elapsed_s": round(elapsed_s, 2),
        "result": payload,
    }
    path.write_text(json.dumps(record, indent=1, default=str) + "\n")
    return path


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument(
        "--json-out",
        action="store_true",
        help="write BENCH_<name>.json (commit hash + result dict) per bench",
    )
    args = ap.parse_args()
    names = list(BENCHES) if not args.only else args.only.split(",")
    failures = []
    for name in names:
        t0 = time.time()
        print(f"=== {name} ===", flush=True)
        try:
            result = BENCHES[name]()
            elapsed = time.time() - t0
            if args.json_out and result is not None:
                path = write_json(name, result, elapsed)
                print(f"wrote {path.relative_to(REPO_ROOT)}", flush=True)
            print(f"=== {name} done ({elapsed:.1f}s) ===", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"FAILED: {failures}")
        return 1
    print("all benchmarks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
