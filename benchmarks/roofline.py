"""Roofline analysis over the dry-run artifacts (deliverable g).

Reads results/dryrun/*.json (produced by repro.launch.dryrun) and derives,
per (arch x shape x mesh):

    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = sum_k factor_k * collective_bytes_k / link_bw

(The dry-run's HLO analyzer reports per-device quantities with while-loop
trip scaling, so dividing by per-chip rates equals the spec's
"total / (chips x rate)".) Factors: all-reduce 2x (ring send+recv),
everything else 1x.

Also reports MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), N = active
params, D = global tokens per step; the ratio MODEL_FLOPS/HLO_FLOPs exposes
remat recompute + causal-rectangle waste; and the MFU bound
= model-flops-time / max(term) — the roofline fraction used by section Perf.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--tag TAG] [--csv]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

# TPU v5e hardware constants (per chip).
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
LINK_BW = 50e9  # bytes/s per ICI link

COLLECTIVE_FACTORS = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

RESULTS_DIR = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def model_flops(arch: str, shape_name: str) -> float:
    from repro.configs import get_config
    from repro.models.config import SHAPES

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.n_active_params()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    # decode: one token per sequence per step
    return 2.0 * n * shape.global_batch


def analyze_record(r: dict) -> dict | None:
    if r.get("status") != "ok":
        return None
    chips = r["n_devices"]
    flops_dev = r["hlo_flops"]
    bytes_dev = r["hlo_bytes_accessed"]
    coll = r.get("collective_bytes", {})

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = sum(
        COLLECTIVE_FACTORS.get(k, 1.0) * v for k, v in coll.items()
    ) / LINK_BW

    mf = model_flops(r["arch"], r["shape"])
    t_model = mf / (chips * PEAK_FLOPS)
    bound = max(t_compute, t_memory, t_coll, 1e-30)
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    return {
        "arch": r["arch"],
        "shape": r["shape"],
        "mesh": r["mesh"],
        "tag": r.get("tag", ""),
        "chips": chips,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / max(flops_dev * chips, 1e-30),
        "mfu_bound": t_model / bound,
        "per_device_argument_gib": r.get("per_device_argument_gib"),
    }


LEVERS = {
    "compute": "cut recompute (remat policy) / causal-triangular attention schedule",
    "memory": "larger fused blocks; keep weights resident (less re-streaming)",
    "collective": "re-shard to reduce per-layer gathers; overlap collectives with compute",
}


def load_all(tag: str | None = None) -> list[dict]:
    rows = []
    for f in sorted(RESULTS_DIR.glob("*.json")):
        r = json.loads(f.read_text())
        if tag is not None and r.get("tag", "") != tag:
            continue
        a = analyze_record(r)
        if a:
            rows.append(a)
    return rows


def fmt_table(rows: list[dict]) -> str:
    hdr = (
        f"{'arch':22s} {'shape':12s} {'mesh':8s} {'chips':5s} "
        f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
        f"{'dominant':>10s} {'useful':>7s} {'MFU_bound':>9s}  variant"
    )
    lines = [hdr, "-" * len(hdr)]
    for a in rows:
        lines.append(
            f"{a['arch']:22s} {a['shape']:12s} {a['mesh']:8s} {a['chips']:<5d} "
            f"{a['t_compute_s']:>10.4g} {a['t_memory_s']:>10.4g} "
            f"{a['t_collective_s']:>10.4g} {a['dominant']:>10s} "
            f"{a['useful_ratio']:>7.3f} {a['mfu_bound']:>9.3f}  "
            f"{a['tag'] or 'baseline'}"
        )
    return "\n".join(lines)


def run(print_fn=print) -> dict:
    """Benchmark-harness entry (benchmarks/run.py): print the roofline table
    derived from committed dry-run artifacts, or note their absence.

    Informational: missing or malformed artifacts are not a failure — the
    full dry-run matrix is generated offline (repro.launch.dryrun --all)."""
    try:
        rows = load_all()
    except Exception as e:
        print_fn(f"roofline,skipped: unreadable dry-run artifacts ({e})")
        return {"rows": 0}
    if not rows:
        print_fn("roofline,skipped: no dry-run artifacts under results/dryrun")
        return {"rows": 0}
    print_fn(fmt_table(rows))
    return {"rows": len(rows)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default=None)
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rows = load_all(args.tag)
    if not rows:
        print("no dry-run results found — run repro.launch.dryrun first")
        return 1
    if args.csv:
        keys = list(rows[0].keys())
        print(",".join(keys))
        for a in rows:
            print(",".join(str(a[k]) for k in keys))
    else:
        print(fmt_table(rows))
        print()
        for dom in ("compute", "memory", "collective"):
            n = sum(1 for a in rows if a["dominant"] == dom)
            if n:
                print(f"{n:3d} cells {dom}-bound -> lever: {LEVERS[dom]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
