"""Table I reproduction: scenario statistics (+ Fig. 3's heterogeneity).

Prints |V|, |E|, |A|, mean mu, mean nu, (L0, L1, L2), mean lambda for each
scenario — the configuration table the evaluation runs on."""
from __future__ import annotations

import json

import jax.numpy as jnp
import numpy as np

from repro.core import SCENARIOS


def run(print_fn=print) -> dict:
    out = {}
    for name, make in SCENARIOS.items():
        p = make()
        adj = np.asarray(p.net.adj)
        mu = np.asarray(p.net.mu)
        edges = int(adj.sum())
        mean_mu = float(mu[adj > 0].mean())
        mean_nu = float(np.asarray(p.net.nu).mean())
        L = np.asarray(p.apps.L).mean(axis=0)
        out[name] = {
            "V": int(adj.shape[0]),
            "E_directed": edges,
            "A": int(p.apps.n_apps),
            "mean_mu": round(mean_mu, 2),
            "mean_nu": round(mean_nu, 2),
            "L": [round(float(x), 2) for x in L],
            "mean_lambda": round(float(np.asarray(p.apps.lam).mean()), 2),
        }
        print_fn(f"table1,{name:10s} {out[name]}")
    # heterogeneity check (Fig. 3): IoT has strongly heterogeneous nu.
    iot_nu = np.asarray(SCENARIOS["iot"]().net.nu)
    assert iot_nu.max() / iot_nu.min() > 10
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
