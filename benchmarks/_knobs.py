"""Kernel-path knobs for the benchmark runner.

`benchmarks.run --use-pallas [--no-interpret]` exports the launch profile to
the individual benches through the environment (the bench modules are plain
`run()` functions), mirroring the `launch/fleet.py` CLI contract: benches
thread `**pallas_knobs()` into their solver calls, so a TPU/GPU deployment
benchmarks the real kernel path with the same one-flag flip as the launcher.
With the flags unset this returns {} and every bench keeps its default
(pure-XLA) path — committed BENCH baselines are XLA-path numbers.
"""
from __future__ import annotations

import os


def pallas_knobs() -> dict:
    """use_pallas/interpret kwargs from the runner environment (or {})."""
    if not os.environ.get("REPRO_BENCH_USE_PALLAS"):
        return {}
    return {
        "use_pallas": True,
        "interpret": os.environ.get("REPRO_BENCH_INTERPRET", "1") != "0",
    }
