"""Fig. 4 reproduction: J versus input-rate scaling factor (IoT scenario).

Validates: ALT lowest across the load range; the absolute gap to every
baseline widens as the system becomes more heavily loaded (the regime where
congestion awareness matters most)."""
from __future__ import annotations

import json

from repro.core import compare_all, iot

SCALES = (0.4, 0.6, 0.8, 1.0, 1.2)
METHODS = ("ALT", "OneShot", "CongUnaware", "CoLocated")


def run(print_fn=print) -> dict:
    out = {}
    for f in SCALES:
        res = compare_all(iot(load_scale=f))
        out[str(f)] = {m: res[m].J for m in METHODS}
        row = "  ".join(f"{m}={res[m].J:12.2f}" for m in METHODS)
        print_fn(f"fig4,scale={f:3.1f} {row}")
    # Gap (CongUnaware - ALT) widens with load across the sweep ends.
    lo, hi = str(SCALES[0]), str(SCALES[-1])
    gap_lo = out[lo]["CongUnaware"] - out[lo]["ALT"]
    gap_hi = out[hi]["CongUnaware"] - out[hi]["ALT"]
    assert gap_hi > gap_lo > 0, (gap_lo, gap_hi)
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
