"""Fig. 4 reproduction: J versus input-rate scaling factor (IoT scenario).

Validates: ALT lowest across the load range; the absolute gap to every
baseline widens as the system becomes more heavily loaded (the regime where
congestion awareness matters most).

The whole sweep runs on the shared round engine (core/engine.py): the five
load scales form one batched problem ensemble per method (4 batched solves
total) instead of the former 20 sequential `solve_*` calls, and each solve's
while_loop exits as soon as all five operating points have converged rather
than burning the full m_max=30 budget."""
from __future__ import annotations

import json

from repro.core import iot
from repro.fleet import load_grid, solve_fleet

SCALES = (0.4, 0.6, 0.8, 1.0, 1.2)
METHODS = ("ALT", "OneShot", "CongUnaware", "CoLocated")


def run(print_fn=print, n_parts: int | None = None) -> dict:
    """`n_parts` sweeps the same load grid at a different split depth
    (stage-generic core, DESIGN.md section 13); None = the paper's P = 2."""
    fleet = load_grid(iot, SCALES, n_parts=n_parts)
    per_method = {
        m: solve_fleet(fleet, method=m, m_max=30, t_phi=10) for m in METHODS
    }
    rounds = {m: r.rounds for m, r in per_method.items()}
    print_fn(f"fig4,engine rounds executed (of m_max=30): {rounds}")
    out = {}
    for i, f in enumerate(SCALES):
        out[str(f)] = {m: float(per_method[m].J[i]) for m in METHODS}
        row = "  ".join(f"{m}={out[str(f)][m]:12.2f}" for m in METHODS)
        print_fn(f"fig4,scale={f:3.1f} {row}")
    # Gap (CongUnaware - ALT) widens with load across the sweep ends.
    lo, hi = str(SCALES[0]), str(SCALES[-1])
    gap_lo = out[lo]["CongUnaware"] - out[lo]["ALT"]
    gap_hi = out[hi]["CongUnaware"] - out[hi]["ALT"]
    assert gap_hi > gap_lo > 0, (gap_lo, gap_hi)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--partitions", type=int, default=None,
                    help="DNN split depth P (default: the paper's 2)")
    print(json.dumps(run(n_parts=ap.parse_args().partitions), indent=1))
