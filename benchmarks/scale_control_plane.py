"""Beyond-paper: the control plane at fleet scale.

The paper evaluates V<=30 graphs. A production placement controller must
re-optimize routing for large edge fleets: here ALT runs on synthetic
irregular networks up to V=512, A=256 — all dense linear algebra
(vmapped solves + tropical APSP), i.e. the TPU-native formulation's payoff.
Reports per-outer-iteration wall time scaling on CPU."""
from __future__ import annotations

import time

from repro.core import objective, random_connected, solve_alt


def run(print_fn=print) -> dict:
    out = {}
    for v, a in ((64, 32), (128, 64), (256, 128)):
        p = random_connected(v, a, seed=1)
        t0 = time.time()
        r = solve_alt(p, m_max=4, t_phi=4)
        dt = time.time() - t0
        per_iter = dt / max(r.iters, 1)
        out[f"v{v}_a{a}"] = {"J": r.J, "s_per_outer_iter": round(per_iter, 3)}
        print_fn(
            f"scale,V={v:4d} A={a:4d}  J={r.J:12.2f}  "
            f"{per_iter:7.3f} s/outer-iter (CPU)"
        )
        assert r.J < r.history[0], "ALT must improve on init at scale"
    return out


if __name__ == "__main__":
    run()
