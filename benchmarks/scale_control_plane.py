"""Beyond-paper: the control plane at fleet scale.

The paper evaluates V<=30 graphs. A production placement controller must
re-optimize routing for large edge fleets: here batches of synthetic
irregular networks up to V=256, A=128 are solved on the fleet engine — one
jitted computation per (V, A) tier, vmapped over the instance axis — and we
report instances/s per tier. All dense linear algebra (vmapped solves +
tropical APSP), i.e. the TPU-native formulation's payoff.

Set SCALE_SMALL=1 (CI smoke) to shrink the tiers so the bench finishes in
about a minute on two cores."""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import random_connected
from repro.fleet import solve_fleet

FULL_TIERS = ((64, 32, 4), (128, 64, 4), (256, 128, 2))  # (V, A, batch)
SMALL_TIERS = ((32, 16, 4), (48, 24, 2))


def run(print_fn=print) -> dict:
    tiers = SMALL_TIERS if os.environ.get("SCALE_SMALL") else FULL_TIERS
    out = {}
    for v, a, batch in tiers:
        fleet = [random_connected(v, a, seed=1 + b) for b in range(batch)]
        t0 = time.time()
        res = solve_fleet(fleet, m_max=4, t_phi=4)
        dt = time.time() - t0
        inst_per_s = batch / dt
        out[f"v{v}_a{a}"] = {
            "batch": batch,
            "J_med": float(np.median(res.J)),
            "s_total": round(dt, 3),
            "inst_per_s": round(inst_per_s, 4),
        }
        print_fn(
            f"scale,V={v:4d} A={a:4d} B={batch}  J_med={out[f'v{v}_a{a}']['J_med']:12.2f}  "
            f"{dt:7.2f} s total  {inst_per_s:7.3f} inst/s (CPU, incl. compile)"
        )
        # Every instance must improve on its structured init at scale.
        first = res.history[:, 0]
        assert (res.J < first).all(), "ALT must improve on init at scale"
    return out


if __name__ == "__main__":
    run()
