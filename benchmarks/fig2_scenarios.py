"""Fig. 2 reproduction: normalized objective J, four scenarios x four methods.

Validates the paper's headline ordering: ALT lowest everywhere; CongUnaware
far worse (congestion-blind placement overloads); OneShot between; CoLocated
poor — worst in the hierarchical IoT setting (split flexibility matters most
there).

Runs on the batched fleet engine like fig4/fig5: the four scenarios form ONE
problem ensemble per method (4 batched solves total) instead of the former 16
sequential `solve_*` calls — the last sequential-only compile path in the
benchmarks, deleted now that B=1 and B>1 share the engine (DESIGN.md §11).
Per-scenario numbers match the sequential path to the fleet padding contract
(rtol 1e-3, pinned by tests/test_fleet.py); the assertions here are ordering
claims with far wider margins than that.
"""
from __future__ import annotations

import json
import time

from repro.core import SCENARIOS
from repro.fleet import solve_fleet

METHODS = ("ALT", "OneShot", "CongUnaware", "CoLocated")


def run(print_fn=print) -> dict:
    names = list(SCENARIOS)
    fleet = [SCENARIOS[name]() for name in names]
    per_method = {}
    for m in METHODS:
        t0 = time.time()
        per_method[m] = solve_fleet(fleet, method=m, m_max=30, t_phi=10)
        print_fn(
            f"fig2,method={m:12s} rounds={per_method[m].rounds}/30 "
            f"({time.time() - t0:.1f}s, one batched solve)"
        )
    out = {}
    for i, name in enumerate(names):
        js = {m: float(per_method[m].J[i]) for m in METHODS}
        worst = max(js.values())
        out[name] = {
            m: {
                "J": js[m],
                "J_norm": js[m] / worst,
                "iters": int(per_method[m].iters[i]),
            }
            for m in METHODS
        }
        row = "  ".join(f"{m}={js[m] / worst:6.3f}" for m in METHODS)
        print_fn(f"fig2,{name:10s} {row}")
    # Paper claims (assertions double as validation):
    for name in out:
        js = {m: out[name][m]["J"] for m in METHODS}
        assert js["ALT"] <= min(js.values()) * 1.001, (name, js)
    assert (
        out["iot"]["CoLocated"]["J"] / out["iot"]["ALT"]["J"]
        > out["geant"]["CoLocated"]["J"] / out["geant"]["ALT"]["J"]
    ), "split flexibility should matter most in IoT"
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
