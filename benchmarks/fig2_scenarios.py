"""Fig. 2 reproduction: normalized objective J, four scenarios x four methods.

Validates the paper's headline ordering: ALT lowest everywhere; CongUnaware
far worse (congestion-blind placement overloads); OneShot between; CoLocated
poor — worst in the hierarchical IoT setting (split flexibility matters most
there)."""
from __future__ import annotations

import json
import time

from repro.core import SCENARIOS, compare_all

METHODS = ("ALT", "OneShot", "CongUnaware", "CoLocated")


def run(print_fn=print) -> dict:
    out = {}
    for name, make in SCENARIOS.items():
        t0 = time.time()
        res = compare_all(make())
        worst = max(r.J for r in res.values())
        out[name] = {
            m: {"J": res[m].J, "J_norm": res[m].J / worst, "iters": res[m].iters}
            for m in METHODS
        }
        row = "  ".join(f"{m}={res[m].J / worst:6.3f}" for m in METHODS)
        print_fn(f"fig2,{name:10s} {row}   ({time.time() - t0:.1f}s)")
    # Paper claims (assertions double as validation):
    for name in out:
        js = {m: out[name][m]["J"] for m in METHODS}
        assert js["ALT"] <= min(js.values()) * 1.001, (name, js)
    assert (
        out["iot"]["CoLocated"]["J"] / out["iot"]["ALT"]["J"]
        > out["geant"]["CoLocated"]["J"] / out["geant"]["ALT"]["J"]
    ), "split flexibility should matter most in IoT"
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
