"""Kernel micro-benchmarks: jnp oracle paths timed on CPU; Pallas kernels
validated in interpret mode (wall-clock on CPU interpret is meaningless —
the TPU perf argument lives in the roofline analysis)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ref import attention_chunked, attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.minplus.kernel import minplus_matmul_pallas
from repro.kernels.minplus.ops import apsp
from repro.kernels.neumann import lu_solve_ref, neumann_solve
from repro.kernels.neumann.kernel import neumann_solve_pallas


def _time(fn, *args, reps=5):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(print_fn=print) -> dict:
    out = {}
    rng = np.random.RandomState(0)

    # APSP (jnp path) across graph sizes — the placement step's inner loop.
    for v in (32, 128, 512):
        w = rng.uniform(0.1, 5.0, (v, v)).astype(np.float32)
        w[rng.rand(v, v) < 0.7] = 1e18
        us = _time(jax.jit(apsp), jnp.asarray(w))
        out[f"apsp_v{v}_us"] = us
        print_fn(f"kernel,apsp v={v:4d}  {us:10.1f} us/call")

    # neumann propagation solve vs dense LU — the ALT hot-loop fixed point.
    # Workload shape: [A, V, V] nilpotent operators (SP-tree-like support,
    # longest chain ~ diameter), one RHS per app.
    for v in (64, 128):
        a_apps, hops = 12, 10
        m = np.triu(rng.uniform(0.0, 1.0, (a_apps, v, v)).astype(np.float32), 1)
        m *= rng.rand(a_apps, v, v) < (2.0 / v)  # sparse loop-free support
        rhs = rng.uniform(0.0, 2.0, (a_apps, v)).astype(np.float32)
        m_j, rhs_j = jnp.asarray(m), jnp.asarray(rhs)
        ne = jax.jit(lambda mm, bb: neumann_solve(mm, bb, hops=hops))
        lu = jax.jit(lu_solve_ref)
        us_ne = _time(ne, m_j, rhs_j)
        us_lu = _time(lu, m_j, rhs_j)
        err = float(jnp.max(jnp.abs(ne(m_j, rhs_j) - lu(m_j, rhs_j))))
        out[f"neumann_v{v}_us"] = us_ne
        out[f"lu_v{v}_us"] = us_lu
        out[f"neumann_v{v}_speedup"] = us_lu / us_ne
        print_fn(
            f"kernel,neumann v={v:4d} A={a_apps} hops<={hops}  "
            f"neumann={us_ne:8.1f}us lu={us_lu:8.1f}us "
            f"speedup={us_lu / us_ne:.2f}x err={err:.2e}"
        )
        assert err < 1e-3

    # neumann Pallas (interpret) vs LU oracle: correctness of the fused hops.
    m = np.triu(rng.uniform(0.0, 1.0, (4, 48, 48)).astype(np.float32), 1)
    m *= rng.rand(4, 48, 48) < 0.2
    rhs = rng.uniform(0.0, 2.0, (4, 48)).astype(np.float32)
    got = neumann_solve_pallas(jnp.asarray(m), jnp.asarray(rhs), hops=49, interpret=True)
    want = lu_solve_ref(jnp.asarray(m), jnp.asarray(rhs))
    err = float(jnp.max(jnp.abs(got - want)) / (jnp.max(jnp.abs(want)) + 1e-30))
    out["neumann_interpret_err"] = err
    print_fn(f"kernel,neumann_pallas interpret rel err={err:.2e}")
    assert err < 1e-5

    # minplus Pallas (interpret) vs oracle: correctness + relative cost.
    a = jnp.asarray(rng.uniform(0, 5, (256, 256)).astype(np.float32))
    got = minplus_matmul_pallas(a, a, interpret=True)
    from repro.kernels.minplus.ref import minplus_matmul_ref

    err = float(jnp.max(jnp.abs(got - minplus_matmul_ref(a, a))))
    out["minplus_interpret_err"] = err
    print_fn(f"kernel,minplus_pallas interpret err={err:.2e}")

    # attention: chunked-flash jnp vs naive ref (the memory-bound fix).
    q = jnp.asarray(rng.randn(1, 8, 1024, 64), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 1024, 64), jnp.float32)
    v_ = jnp.asarray(rng.randn(1, 2, 1024, 64), jnp.float32)
    us_ref = _time(jax.jit(lambda *x: attention_ref(*x)), q, k, v_)
    us_chk = _time(jax.jit(lambda *x: attention_chunked(*x)), q, k, v_)
    out["attn_ref_us"] = us_ref
    out["attn_chunked_us"] = us_chk
    print_fn(f"kernel,attention S=1024 ref={us_ref:.0f}us chunked={us_chk:.0f}us")

    got = flash_attention_pallas(q, k, v_, interpret=True)
    err = float(
        jnp.max(jnp.abs(got - attention_ref(q, k, v_)))
    )
    out["flash_interpret_err"] = err
    print_fn(f"kernel,flash_pallas interpret err={err:.2e}")
    assert err < 5e-3
    return out


if __name__ == "__main__":
    run()
