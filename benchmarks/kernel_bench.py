"""Kernel micro-benchmarks: jnp oracle paths timed on CPU; Pallas kernels
validated in interpret mode (wall-clock on CPU interpret is meaningless —
the TPU perf argument lives in the roofline analysis).

`benchmarks.run --use-pallas [--no-interpret]` routes the apsp section (and
the fleet benches) through the Pallas kernels instead — see _knobs.py."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._knobs import pallas_knobs
from repro.kernels.flash_attention.ref import attention_chunked, attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.minplus.kernel import minplus_matmul_pallas
from repro.kernels.minplus.ops import apsp, apsp_with_nexthop
from repro.kernels.minplus.ref import apsp_ref
from repro.kernels.neumann import lu_solve_ref, neumann_solve
from repro.kernels.neumann.kernel import neumann_solve_pallas


def _time(fn, *args, reps=5):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _bench_apsp(out, print_fn, knobs) -> None:
    """APSP — the placement step's inner loop and PR 8's scaling cliff.

    Default path vs the dense one-broadcast squaring (`apsp_ref`): the dense
    path materializes a [V, V, V] candidate tensor per squaring, 512 MiB at
    V=512 and 4 GiB at V=1024 — which is why V=1024 only runs the O(V^2)
    paths, and why this section exists. `apsp_*_us` keys are trend-linted
    (lower is better); the `_speedup` ratios are the portable claim.
    """
    small = bool(os.environ.get("SCALE_SMALL"))
    rng = np.random.RandomState(0)
    sizes = (32, 128, 256) if small else (32, 128, 512, 1024)
    dense_cap = 256 if small else 512
    for v in sizes:
        w = rng.uniform(0.1, 5.0, (v, v)).astype(np.float32)
        w[rng.rand(v, v) < 0.7] = 1e18
        wj = jnp.asarray(w)
        reps = 2 if v >= 512 else 5
        us = _time(jax.jit(lambda x: apsp(x, **knobs)), wj, reps=reps)
        out[f"apsp_v{v}_us"] = us
        line = f"kernel,apsp v={v:4d}  {us:10.1f} us/call"
        if v >= 128:
            us_nh = _time(
                jax.jit(lambda x: apsp_with_nexthop(x, **knobs)[1]),
                wj,
                reps=reps,
            )
            out[f"apsp_nexthop_v{v}_us"] = us_nh
            line += f"  nexthop {us_nh:10.1f} us"
        if 128 <= v <= dense_cap:
            d0 = jnp.where(jnp.eye(v, dtype=bool), 0.0, wj)
            us_dense = _time(jax.jit(apsp_ref), d0, reps=2)
            out[f"apsp_dense_v{v}_us"] = us_dense
            out[f"apsp_v{v}_speedup"] = us_dense / us
            line += f"  dense {us_dense:10.1f} us ({us_dense / us:.1f}x)"
        elif v > dense_cap:
            line += "  dense skipped (O(V^3) broadcast)"
        print_fn(line)


def _bench_fleet_round(out, print_fn, knobs) -> None:
    """End-to-end ALT round wall-clock across V — the ROADMAP success
    metric behind PR 8: a V=1024 round on the O(V^2) APSP paths vs the
    small-V rounds the dense path used to cap the stack at."""
    from repro.core import random_connected, solve_alt

    small = bool(os.environ.get("SCALE_SMALL"))
    sizes = ((64, 3), (256, 4)) if small else ((256, 4), (1024, 4))
    ms = {}
    for v, a in sizes:
        p = random_connected(v, a, seed=1)
        kw = dict(m_max=1, t_phi=2, **knobs)
        float(solve_alt(p, **kw).J)  # compile + warm
        reps = 2
        t0 = time.perf_counter()
        for _ in range(reps):
            float(solve_alt(p, **kw).J)
        ms[v] = (time.perf_counter() - t0) / reps * 1e3
        out[f"fleet_round_v{v}_ms"] = ms[v]
        print_fn(f"kernel,fleet_round v={v:4d}  {ms[v]:8.1f} ms/round")
    lo, hi = min(ms), max(ms)
    out["fleet_round_small_over_big_ratio"] = ms[lo] / ms[hi]


def run(print_fn=print) -> dict:
    out = {}
    rng = np.random.RandomState(0)
    knobs = pallas_knobs()

    _bench_apsp(out, print_fn, knobs)
    _bench_fleet_round(out, print_fn, knobs)

    # neumann propagation solve vs dense LU — the ALT hot-loop fixed point.
    # Workload shape: [A, V, V] nilpotent operators (SP-tree-like support,
    # longest chain ~ diameter), one RHS per app.
    for v in (64, 128):
        a_apps, hops = 12, 10
        m = np.triu(rng.uniform(0.0, 1.0, (a_apps, v, v)).astype(np.float32), 1)
        m *= rng.rand(a_apps, v, v) < (2.0 / v)  # sparse loop-free support
        rhs = rng.uniform(0.0, 2.0, (a_apps, v)).astype(np.float32)
        m_j, rhs_j = jnp.asarray(m), jnp.asarray(rhs)
        ne = jax.jit(lambda mm, bb: neumann_solve(mm, bb, hops=hops))
        lu = jax.jit(lu_solve_ref)
        us_ne = _time(ne, m_j, rhs_j)
        us_lu = _time(lu, m_j, rhs_j)
        err = float(jnp.max(jnp.abs(ne(m_j, rhs_j) - lu(m_j, rhs_j))))
        out[f"neumann_v{v}_us"] = us_ne
        out[f"lu_v{v}_us"] = us_lu
        out[f"neumann_v{v}_speedup"] = us_lu / us_ne
        print_fn(
            f"kernel,neumann v={v:4d} A={a_apps} hops<={hops}  "
            f"neumann={us_ne:8.1f}us lu={us_lu:8.1f}us "
            f"speedup={us_lu / us_ne:.2f}x err={err:.2e}"
        )
        assert err < 1e-3

    # neumann Pallas (interpret) vs LU oracle: correctness of the fused hops.
    m = np.triu(rng.uniform(0.0, 1.0, (4, 48, 48)).astype(np.float32), 1)
    m *= rng.rand(4, 48, 48) < 0.2
    rhs = rng.uniform(0.0, 2.0, (4, 48)).astype(np.float32)
    got = neumann_solve_pallas(jnp.asarray(m), jnp.asarray(rhs), hops=49, interpret=True)
    want = lu_solve_ref(jnp.asarray(m), jnp.asarray(rhs))
    err = float(jnp.max(jnp.abs(got - want)) / (jnp.max(jnp.abs(want)) + 1e-30))
    out["neumann_interpret_err"] = err
    print_fn(f"kernel,neumann_pallas interpret rel err={err:.2e}")
    assert err < 1e-5

    # minplus Pallas (interpret) vs oracle: correctness + relative cost.
    a = jnp.asarray(rng.uniform(0, 5, (256, 256)).astype(np.float32))
    got = minplus_matmul_pallas(a, a, interpret=True)
    from repro.kernels.minplus.ref import minplus_matmul_ref

    err = float(jnp.max(jnp.abs(got - minplus_matmul_ref(a, a))))
    out["minplus_interpret_err"] = err
    print_fn(f"kernel,minplus_pallas interpret err={err:.2e}")

    # attention: chunked-flash jnp vs naive ref (the memory-bound fix).
    q = jnp.asarray(rng.randn(1, 8, 1024, 64), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 1024, 64), jnp.float32)
    v_ = jnp.asarray(rng.randn(1, 2, 1024, 64), jnp.float32)
    us_ref = _time(jax.jit(lambda *x: attention_ref(*x)), q, k, v_)
    us_chk = _time(jax.jit(lambda *x: attention_chunked(*x)), q, k, v_)
    out["attn_ref_us"] = us_ref
    out["attn_chunked_us"] = us_chk
    print_fn(f"kernel,attention S=1024 ref={us_ref:.0f}us chunked={us_chk:.0f}us")

    got = flash_attention_pallas(q, k, v_, interpret=True)
    err = float(
        jnp.max(jnp.abs(got - attention_ref(q, k, v_)))
    )
    out["flash_interpret_err"] = err
    print_fn(f"kernel,flash_pallas interpret err={err:.2e}")
    assert err < 5e-3
    return out


if __name__ == "__main__":
    run()
