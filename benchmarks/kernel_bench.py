"""Kernel micro-benchmarks: jnp oracle paths timed on CPU; Pallas kernels
validated in interpret mode (wall-clock on CPU interpret is meaningless —
the TPU perf argument lives in the roofline analysis)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ref import attention_chunked, attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.minplus.kernel import minplus_matmul_pallas
from repro.kernels.minplus.ops import apsp


def _time(fn, *args, reps=5):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6  # us


def run(print_fn=print) -> dict:
    out = {}
    rng = np.random.RandomState(0)

    # APSP (jnp path) across graph sizes — the placement step's inner loop.
    for v in (32, 128, 512):
        w = rng.uniform(0.1, 5.0, (v, v)).astype(np.float32)
        w[rng.rand(v, v) < 0.7] = 1e18
        us = _time(jax.jit(apsp), jnp.asarray(w))
        out[f"apsp_v{v}_us"] = us
        print_fn(f"kernel,apsp v={v:4d}  {us:10.1f} us/call")

    # minplus Pallas (interpret) vs oracle: correctness + relative cost.
    a = jnp.asarray(rng.uniform(0, 5, (256, 256)).astype(np.float32))
    got = minplus_matmul_pallas(a, a, interpret=True)
    from repro.kernels.minplus.ref import minplus_matmul_ref

    err = float(jnp.max(jnp.abs(got - minplus_matmul_ref(a, a))))
    out["minplus_interpret_err"] = err
    print_fn(f"kernel,minplus_pallas interpret err={err:.2e}")

    # attention: chunked-flash jnp vs naive ref (the memory-bound fix).
    q = jnp.asarray(rng.randn(1, 8, 1024, 64), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 1024, 64), jnp.float32)
    v_ = jnp.asarray(rng.randn(1, 2, 1024, 64), jnp.float32)
    us_ref = _time(jax.jit(lambda *x: attention_ref(*x)), q, k, v_)
    us_chk = _time(jax.jit(lambda *x: attention_chunked(*x)), q, k, v_)
    out["attn_ref_us"] = us_ref
    out["attn_chunked_us"] = us_chk
    print_fn(f"kernel,attention S=1024 ref={us_ref:.0f}us chunked={us_chk:.0f}us")

    got = flash_attention_pallas(q, k, v_, interpret=True)
    err = float(
        jnp.max(jnp.abs(got - attention_ref(q, k, v_)))
    )
    out["flash_interpret_err"] = err
    print_fn(f"kernel,flash_pallas interpret err={err:.2e}")
    assert err < 5e-3
    return out


if __name__ == "__main__":
    run()
