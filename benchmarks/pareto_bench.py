"""Split-point Pareto search benchmark (DESIGN.md section 17).

Section 1 (enumeration): the candidate generator over the full 12-config
zoo — every architecture must emit cut sets at all depths P = 1..4 with the
full enumeration space accounted (subsampling is reported, never silent),
and the zoo must include interleaved hybrids (the per-layer-type FLOPs
accounting that PR 9's bugfix introduced).

Section 2 (sweep throughput): the end-to-end search — enumerate, normalize,
build one problem per candidate x (topology, load, eta), solve ALL of them
as ONE batched `solve_fleet` call through mixed-P phantom-stage padding,
and extract dominated-point-filtered latency/compute/egress fronts. This is
the first consumer that actually demands the fleet engine's batch
throughput at scale; `candidates_per_s` (trend-linted, higher is better on
comparable hardware) is the sustained candidate-evaluation rate including
enumeration, padding, solving, and front extraction.

Checks enforced:
  * all 12 zoo configs enumerate candidates at every depth P = 1..4
  * >= 100 mixed-P candidates solved per (topology, load) cell at full
    scale (>= 20 under SCALE_SMALL) in one solve_fleet call
  * every (arch, topology, load) cell has a non-empty finite front and
    dominated-point filtering actually filtered (`check_fronts`)
"""
from __future__ import annotations

import os
import time

from repro.configs import ZOO, get_config
from repro.partition.pareto import check_fronts, sweep_zoo
from repro.partition.profile import enumerate_candidates

_SMALL = bool(os.environ.get("SCALE_SMALL"))


def _bench_enumeration(print_fn) -> dict:
    per_arch = {}
    interleaved = 0
    for arch in ZOO:
        cfg = get_config(arch)
        cands, possible = enumerate_candidates(
            cfg, seq_len=256, max_per_p=16
        )
        depths = sorted({c.n_parts for c in cands})
        assert depths == [1, 2, 3, 4], (arch, depths)
        if cfg.family == "hybrid" and cfg.hybrid_attn_period >= 1:
            interleaved += 1
        per_arch[arch] = {"candidates": len(cands), "possible": possible}
    assert len(per_arch) == 12, f"zoo is {len(per_arch)} configs, want 12"
    assert interleaved >= 2, "zoo lost its interleaved hybrids"
    total = sum(v["candidates"] for v in per_arch.values())
    possible = sum(v["possible"] for v in per_arch.values())
    print_fn(
        f"pareto,enumeration archs={len(per_arch)} candidates={total} "
        f"of {possible} cut sets (interleaved hybrids: {interleaved})"
    )
    return {
        "archs": len(per_arch),
        "candidates": total,
        "possible": possible,
        "interleaved_hybrids": interleaved,
        "per_arch": per_arch,
    }


def sweep_section(
    print_fn,
    *,
    archs,
    topologies,
    loads=(1.0,),
    etas=(0.5,),
    max_per_p,
    m_max,
    t_phi,
    seq_len=128,
    min_per_cell,
    shard=False,
) -> dict:
    """One timed end-to-end sweep + the front hard gates. Shared with
    fleet_bench's pareto section so both persist the same shape of record."""
    t0 = time.time()
    report = sweep_zoo(
        archs=archs,
        topologies=topologies,
        loads=loads,
        etas=etas,
        max_per_p=max_per_p,
        m_max=m_max,
        t_phi=t_phi,
        seq_len=seq_len,
        round_to=8,
        shard=shard,
    )
    wall = time.time() - t0
    check_fronts(report)
    per_cell = report["candidates_per_topo_load"]
    assert per_cell >= min_per_cell, (
        f"pareto: {per_cell} candidates per (topology, load) cell "
        f"< required {min_per_cell}"
    )
    fronts = [c["front_size"] for c in report["cells"]]
    dominated = sum(c["n_dominated"] for c in report["cells"])
    rate = report["n_instances"] / wall
    print_fn(
        f"pareto,sweep B={report['n_instances']} "
        f"({per_cell}/cell over {len(report['cells'])} fronts) "
        f"rounds={report['rounds']} wall={wall:.1f}s "
        f"{rate:.1f} cand/s front_sizes={min(fronts)}-{max(fronts)} "
        f"dominated={dominated}"
    )
    return {
        "instances": report["n_instances"],
        "candidates_per_topo_load": per_cell,
        "cells": len(report["cells"]),
        "rounds_executed": report["rounds"],
        "front_size_min": min(fronts),
        "front_size_max": max(fronts),
        "dominated_filtered": dominated,
        "cut_sets_possible": report["cut_sets_possible"],
        "cut_sets_dropped": report["cut_sets_dropped"],
        "pad_overhead": report["pad_overhead_fraction"],
        "candidates_per_s": round(rate, 3),
    }


def run(print_fn=print) -> dict:
    out = {"enumeration": _bench_enumeration(print_fn)}
    if _SMALL:
        out["sweep"] = sweep_section(
            print_fn,
            archs=("qwen1.5-0.5b", "mamba2-370m", "nemotron-h-8b"),
            topologies=("iot",),
            max_per_p=8,
            m_max=3,
            t_phi=3,
            min_per_cell=20,
        )
    else:
        out["sweep"] = sweep_section(
            print_fn,
            archs=None,  # the full 12-config zoo
            topologies=("iot", "mesh"),
            max_per_p=8,
            m_max=6,
            t_phi=5,
            min_per_cell=100,
        )
    return out


def main() -> int:
    run()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
