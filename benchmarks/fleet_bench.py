"""Fleet engine benchmark: batched multi-scenario solving vs the sequential
per-instance loop (the repo's pre-fleet path).

Workload: a fresh heterogeneous scenario ensemble (mixed ER / BA / IoT-tree /
perturbed-GEANT topologies, varied sizes and loads) — the control-plane
situation where shapes have not been seen before. The sequential loop pays a
retrace + compile for every distinct (V, A) shape plus per-iteration dispatch;
the fleet engine pads to one envelope and compiles ONE batched program.
Both paths are timed end-to-end from cold caches (symmetric: each gets
`jax.clear_caches()` first), then re-timed warm for the steady-state
re-optimization rate.

Checks enforced:
  * per-instance J equivalence between the two paths (rtol 1e-3)
  * >= 2x cold end-to-end speedup at batch >= 8 on CPU
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.fleet import sample_fleet, solve_fleet, solve_sequential

BATCH = 12
SOLVE_KW = dict(m_max=6, t_phi=5)


def run(print_fn=print) -> dict:
    fleet = sample_fleet(BATCH, seed=2026)
    shapes = {(p.net.n_nodes, p.apps.n_apps) for p in fleet}

    # --- fresh-ensemble (cold) end-to-end, sequential then batched ---------
    jax.clear_caches()
    t0 = time.time()
    seq = solve_sequential(fleet, **SOLVE_KW)
    t_seq_cold = time.time() - t0
    t0 = time.time()
    seq2 = solve_sequential(fleet, **SOLVE_KW)
    t_seq_warm = time.time() - t0
    del seq2

    jax.clear_caches()
    t0 = time.time()
    res = solve_fleet(fleet, **SOLVE_KW)
    t_fleet_cold = time.time() - t0
    t0 = time.time()
    res2 = solve_fleet(fleet, **SOLVE_KW)
    t_fleet_warm = time.time() - t0

    # --- equivalence guarantee --------------------------------------------
    for b, r in enumerate(seq):
        np.testing.assert_allclose(res.J[b], r.J, rtol=1e-3)
        np.testing.assert_allclose(res2.J[b], r.J, rtol=1e-3)

    cold_speedup = t_seq_cold / t_fleet_cold
    warm_speedup = t_seq_warm / t_fleet_warm
    out = {
        "batch": BATCH,
        "distinct_shapes": len(shapes),
        "cold": {
            "sequential_s": round(t_seq_cold, 2),
            "fleet_s": round(t_fleet_cold, 2),
            "sequential_inst_per_s": round(BATCH / t_seq_cold, 3),
            "fleet_inst_per_s": round(BATCH / t_fleet_cold, 3),
            "speedup": round(cold_speedup, 2),
        },
        "warm": {
            "sequential_s": round(t_seq_warm, 2),
            "fleet_s": round(t_fleet_warm, 2),
            "sequential_inst_per_s": round(BATCH / t_seq_warm, 3),
            "fleet_inst_per_s": round(BATCH / t_fleet_warm, 3),
            "speedup": round(warm_speedup, 2),
        },
    }
    print_fn(
        f"fleet,B={BATCH} shapes={len(shapes)} "
        f"cold: seq={t_seq_cold:6.1f}s fleet={t_fleet_cold:6.1f}s "
        f"({out['cold']['fleet_inst_per_s']:.2f} inst/s) speedup={cold_speedup:.2f}x"
    )
    print_fn(
        f"fleet,B={BATCH} warm: seq={t_seq_warm:6.2f}s fleet={t_fleet_warm:6.2f}s "
        f"({out['warm']['fleet_inst_per_s']:.2f} inst/s) speedup={warm_speedup:.2f}x"
    )
    assert BATCH >= 8
    assert cold_speedup >= 2.0, (
        f"fleet engine must be >= 2x faster end-to-end on a fresh ensemble "
        f"(got {cold_speedup:.2f}x)"
    )
    return out


if __name__ == "__main__":
    run()
