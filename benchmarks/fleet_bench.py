"""Fleet engine benchmark: batched multi-scenario solving vs the sequential
per-instance loop, plus the nilpotent-propagation solver axis.

Section 1 (batched-vs-sequential): a fresh ER/BA ensemble at the acceptance
regime — B=12 instances at the native envelope (V=64, A=24), so the warm
comparison isolates the engine's round-body layout from envelope padding
(see the ENGINE_FLEET_KW comment). Both paths are timed end-to-end from
cold caches (symmetric: each gets `jax.clear_caches()` first), then warm
as a paired median of `WARM_REPS` interleaved repeats (see `_paired_warm`)
for the steady-state re-optimization rate. The engine runs its default
round-body layout (`lane_chunk` auto ->
lax.map lane chunks when unsharded, DESIGN.md section 18), which is what
closed the historical ~0.65x warm gap; the full tier asserts
`warm_batched_vs_sequential_ratio >= 1.0`.

Section 2 (early exit): both paths now run the shared round engine
(core/engine.py) whose while_loop predicate is "any live instance below
m_max" — a converged fleet at the default tol/patience must exit before its
m_max budget instead of burning fixed-length-scan rounds.

Section 3 (--solver axis): the ALT hot loop's linear fixed points on the
propagation path (`neumann`, O(H V^2) hops) vs dense LU (O(V^3)), measured
as warm per-outer-round wall time on a V >= 64 fleet — the regime where the
LU cost dominates the control plane (ISSUE 2 / DESIGN.md section 10).

Section 4 (parity): Neumann-vs-LU objective agreement across all four
methods on the paper's four topologies.

Section 5 (partition axis): the stage-generic P sweep (DESIGN.md section
13) — the same IoT-tree control-plane workload at split depths P = 1..4
(each its own compiled K envelope), plus a mixed-P fleet padded with
phantom stages and solved as ONE compiled batch, verified against the
per-instance sequential path.

Section 6 (--shard axis): the engine over a real instance-axis mesh. Runs
whenever >= 2 devices are visible (CI simulates 8 CPU devices via
XLA_FLAGS=--xla_force_host_platform_device_count=8); measures warm
sharded-vs-unsharded throughput on a non-divisible batch (exercising the
pad-and-trim path) and enforces rtol 1e-5 parity plus the
`ShardPlan.output_sharded` guarantee. The throughput ratio is recorded for
trend visibility but not asserted: simulated host devices oversubscribe the
same cores, so the ratio only means something on real multi-chip hardware.

Section 7 (obs): the round-trace overhead budget (ISSUE 6). The engine's
trace buffers ride in the while_loop carry; this section measures warm
per-round wall time with tracing on vs off (interleaved best-of-N) and
asserts the traced solve stays within 5% of the untraced one, plus bitwise
identity of every solved output across the two settings.

Section 10 (phases): the per-phase round profile (`obs.profile_round_phases`)
over the section-1 fleet — placement sweep vs T_phi forwarding sweeps vs
round_eval, persisted so BENCH_fleet.json records where the round budget
actually goes (placement is a few percent; forwarding dominates).

`REPRO_FLEET_SECTIONS=engine,phases` (comma list of section names) runs a
subset; skipped sections are recorded as `{"skipped": true}` so
`benchmarks/run.py --check-trend` can refuse a partial baseline.

Checks enforced:
  * per-instance J equivalence between batched and sequential (rtol 1e-3)
  * >= 2x cold end-to-end batched speedup at batch >= 6 on CPU
  * warm batched/sequential ratio >= 1.0 at (B=12, V=64) (full tier only;
    the small tier records the ratio without asserting — B=6 at reduced
    round budgets is too noisy for a hard gate)
  * converged-fleet while_loop early exit (rounds executed < m_max)
  * >= 2x warm per-outer-round Neumann speedup over LU at V >= 64 on CPU
  * Neumann == LU objectives to rtol 1e-3 for all methods x topologies
  * mixed-P batched == sequential objectives to rtol 1e-3 (P in {1,2,3,4})
  * sharded == unsharded objectives to rtol 1e-5 with sharded outputs
    (when >= 2 devices are visible)
  * trace=True warm per-round wall time within 5% of trace=False, with
    bitwise-identical J/history/hosts/iters

The warm batched-vs-sequential throughput ratio — the ROADMAP item tracked
at ~0.65x through PR 9 and closed by the lane-chunked round layout — is
persisted as `warm_batched_vs_sequential_ratio` in BENCH_fleet.json.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from benchmarks._knobs import pallas_knobs
from repro.core import SCENARIOS
from repro.fleet import METHODS, sample_fleet, solve_fleet, solve_sequential
from repro.fleet.generator import erdos_renyi, iot_hierarchy

_SMALL = bool(os.environ.get("SCALE_SMALL"))

BATCH = 6 if _SMALL else 12
SOLVE_KW = dict(m_max=3, t_phi=3) if _SMALL else dict(m_max=6, t_phi=5)
WARM_REPS = 3 if _SMALL else 7

# The headline batched-vs-sequential fleet (ISSUE 10): the acceptance regime
# (B=12, V=64). BOTH envelope axes are pinned to the native sizes (V=64,
# A=24) so the warm comparison measures the ENGINE LAYOUT and nothing else:
# with a heterogeneous fleet the batched side pays envelope padding the
# sequential side never sees (measured ~1.3x at apps 20-28 under an A=28
# envelope), which is a property of padding — covered by the inertness
# contract and envelope caps — not of the round body this section gates.
# The cold comparison still favors the fleet on compile count alone (one
# 12-lane program vs a compile plus twelve dispatch-heavy runs).
ENGINE_FLEET_KW = dict(
    seed=2026, n_range=(64, 64), apps_range=(24, 24),
    families=("erdos_renyi", "barabasi_albert"),
)

# Solver-axis workload: the acceptance regime (V >= 64).
SOLVER_V = 64
SOLVER_BATCH = 2 if _SMALL else 4
SOLVER_KW = dict(m_max=2 if _SMALL else 4, t_phi=5, patience=10)
SOLVER_REPS = 2 if _SMALL else 3


def _paired_warm(fn_a, fn_b) -> tuple[float, float]:
    """Medians of WARM_REPS warm wall times with the two sides interleaved.

    The sides alternate inside ONE measurement window: warm batched vs
    sequential sits near 1.0x, and on a shared host the slow drift between
    two back-to-back windows can exceed the margin under test, so timing
    side A's reps and then side B's reps skews the ratio by whatever the
    load did in between. Interleaving lands the drift on both medians.
    """
    times_a, times_b = [], []
    for _ in range(WARM_REPS):
        t0 = time.perf_counter()
        fn_a()
        times_a.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        times_b.append(time.perf_counter() - t0)
    return float(np.median(times_a)), float(np.median(times_b))


def _bench_batched_vs_sequential(print_fn, solver: str) -> dict:
    fleet = sample_fleet(BATCH, **ENGINE_FLEET_KW)
    shapes = {(p.net.n_nodes, p.apps.n_apps) for p in fleet}
    kw = dict(solver=solver, **SOLVE_KW, **pallas_knobs())

    # --- fresh-ensemble (cold) end-to-end, sequential then batched ---------
    jax.clear_caches()
    t0 = time.time()
    seq = solve_sequential(fleet, **kw)
    t_seq_cold = time.time() - t0

    jax.clear_caches()
    t0 = time.time()
    res = solve_fleet(fleet, **kw)
    t_fleet_cold = time.time() - t0
    res2 = solve_fleet(fleet, **kw)

    # clear_caches before the fleet cold run also dropped the sequential
    # side's compiled programs — re-warm it (untimed) so both sides enter
    # the paired warm loop compiled.
    solve_sequential(fleet, **kw)
    t_seq_warm, t_fleet_warm = _paired_warm(
        lambda: solve_sequential(fleet, **kw),
        lambda: solve_fleet(fleet, **kw),
    )

    # --- equivalence guarantee --------------------------------------------
    for b, r in enumerate(seq):
        np.testing.assert_allclose(res.J[b], r.J, rtol=1e-3)
        np.testing.assert_allclose(res2.J[b], r.J, rtol=1e-3)

    cold_speedup = t_seq_cold / t_fleet_cold
    warm_speedup = t_seq_warm / t_fleet_warm
    out = {
        "batch": BATCH,
        "V": ENGINE_FLEET_KW["n_range"][1],
        "solver": solver,
        "block_apps": 1,
        "lane_chunk": "auto",
        "warm_reps": WARM_REPS,
        "distinct_shapes": len(shapes),
        # Through PR 9 this ratio tracked a ~0.65x warm gap (ROADMAP item);
        # the lane-chunked round layout closed it. Persisted as an explicit
        # top-level field so BENCH_fleet.json shows the trajectory
        # PR-over-PR instead of burying it in `warm.speedup`.
        "warm_batched_vs_sequential_ratio": round(warm_speedup, 3),
        # while_loop trips executed vs the m_max budget (engine early exit).
        "rounds_executed": int(res.rounds),
        "m_max": SOLVE_KW["m_max"],
        "cold": {
            "sequential_s": round(t_seq_cold, 2),
            "fleet_s": round(t_fleet_cold, 2),
            "sequential_inst_per_s": round(BATCH / t_seq_cold, 3),
            "fleet_inst_per_s": round(BATCH / t_fleet_cold, 3),
            "speedup": round(cold_speedup, 2),
        },
        "warm": {
            "sequential_s": round(t_seq_warm, 2),
            "fleet_s": round(t_fleet_warm, 2),
            "sequential_inst_per_s": round(BATCH / t_seq_warm, 3),
            "fleet_inst_per_s": round(BATCH / t_fleet_warm, 3),
            "speedup": round(warm_speedup, 2),
        },
    }
    print_fn(
        f"fleet,B={BATCH} V={out['V']} shapes={len(shapes)} solver={solver} "
        f"cold: seq={t_seq_cold:6.1f}s fleet={t_fleet_cold:6.1f}s "
        f"({out['cold']['fleet_inst_per_s']:.2f} inst/s) speedup={cold_speedup:.2f}x"
    )
    print_fn(
        f"fleet,B={BATCH} warm (paired median of {WARM_REPS}): seq={t_seq_warm:6.2f}s "
        f"fleet={t_fleet_warm:6.2f}s "
        f"({out['warm']['fleet_inst_per_s']:.2f} inst/s) ratio={warm_speedup:.2f}x"
    )
    print_fn(
        f"fleet,B={BATCH} engine rounds={res.rounds}/{SOLVE_KW['m_max']} "
        f"(while_loop early exit saves {SOLVE_KW['m_max'] - res.rounds} rounds)"
    )
    assert BATCH >= 6
    assert cold_speedup >= 2.0, (
        f"fleet engine must be >= 2x faster end-to-end on a fresh ensemble "
        f"(got {cold_speedup:.2f}x)"
    )
    if not _SMALL:
        assert warm_speedup >= 1.0, (
            f"warm batched/sequential ratio regressed below parity at "
            f"(B={BATCH}, V={out['V']}): {warm_speedup:.3f}x — the "
            f"lane-chunked round layout (lane_chunk auto) is supposed to "
            f"keep the batched engine at least sequential-rate warm"
        )
    return out


def _bench_early_exit(print_fn) -> dict:
    """Engine while_loop early exit: a converged B=12 fleet at the default
    tol/patience must execute fewer outer rounds than its m_max budget
    (the old fixed-length scan always burned all m_max rounds)."""
    batch = 6 if _SMALL else 12
    m_max = 30
    fleet = sample_fleet(batch, seed=7)
    res = solve_fleet(fleet, m_max=m_max, t_phi=5)  # default tol/patience
    print_fn(
        f"fleet,early-exit B={batch} m_max={m_max} rounds={res.rounds} "
        f"iters={res.iters.min()}-{res.iters.max()}"
    )
    assert res.rounds < m_max, (
        f"converged fleet must exit the while_loop before m_max "
        f"({res.rounds} vs {m_max})"
    )
    assert res.rounds == int(res.iters.max())
    return {"batch": batch, "m_max": m_max, "rounds_executed": int(res.rounds)}


def _bench_solver_axis(print_fn) -> dict:
    """Warm per-outer-round cost of the two fixed-point solvers at V >= 64."""
    fleet = [erdos_renyi(SOLVER_V, 12, seed=s) for s in range(SOLVER_BATCH)]
    rounds = SOLVER_KW["m_max"]
    skw = dict(**SOLVER_KW, **pallas_knobs())
    per_round = {}
    J = {}
    for solver in ("neumann", "lu"):
        solve_fleet(fleet, solver=solver, **skw)  # compile + warm
        best = np.inf
        for _ in range(SOLVER_REPS):
            t0 = time.time()
            res = solve_fleet(fleet, solver=solver, **skw)
            best = min(best, time.time() - t0)
        per_round[solver] = best / rounds
        J[solver] = np.asarray(res.J)
        print_fn(
            f"fleet,solver={solver:8s} V={SOLVER_V} B={SOLVER_BATCH} "
            f"warm={best:.3f}s  per-round={per_round[solver] * 1e3:.1f}ms"
        )
    speedup = per_round["lu"] / per_round["neumann"]
    np.testing.assert_allclose(J["neumann"], J["lu"], rtol=1e-3)
    hop_bound = fleet[0].hop_bound
    print_fn(
        f"fleet,solver-axis V={SOLVER_V} hop_bound={hop_bound} "
        f"warm per-round speedup neumann/lu = {speedup:.2f}x"
    )
    assert speedup >= 2.0, (
        f"neumann must be >= 2x faster per warm outer round than LU at "
        f"V={SOLVER_V} (got {speedup:.2f}x)"
    )
    return {
        "V": SOLVER_V,
        "batch": SOLVER_BATCH,
        "hop_bound": hop_bound,
        "per_round_ms": {k: round(v * 1e3, 2) for k, v in per_round.items()},
        "warm_per_round_speedup": round(speedup, 2),
    }


def _bench_solver_parity(print_fn) -> dict:
    """Neumann-vs-LU objective parity: 4 methods x 4 paper topologies."""
    fleet = [make() for make in SCENARIOS.values()]
    kw = dict(m_max=3 if _SMALL else 6, t_phi=5, **pallas_knobs())
    out = {}
    for method in METHODS:
        Js = {}
        for solver in ("neumann", "lu"):
            res = solve_fleet(fleet, method=method, solver=solver, **kw)
            Js[solver] = np.asarray(res.J)
        np.testing.assert_allclose(Js["neumann"], Js["lu"], rtol=1e-3)
        rel = np.max(
            np.abs(Js["neumann"] - Js["lu"]) / np.maximum(np.abs(Js["lu"]), 1e-30)
        )
        out[method] = {"max_rel_diff": float(rel)}
        print_fn(
            f"fleet,parity method={method:12s} scenarios={list(SCENARIOS)} "
            f"max|J_ne - J_lu|/J_lu = {rel:.2e}  (rtol 1e-3 OK)"
        )
    return out


def _bench_partition_axis(print_fn) -> dict:
    """The new P axis: per-depth warm solve cost and the mixed-P padded
    batch (the ISSUE 5 tentpole's user-visible payoff)."""
    p_set = (1, 2, 3, 4)
    batch = 3 if _SMALL else 6
    kw = dict(m_max=2 if _SMALL else 4, t_phi=4, **pallas_knobs())

    def depth_fleet(p):
        return [
            iot_hierarchy(seed=s, n_edge=4, devices_per_edge=3, n_apps=8,
                          n_parts=p)
            for s in range(batch)
        ]

    per_p = {}
    for p in p_set:
        fleet = depth_fleet(p)
        solve_fleet(fleet, **kw)  # compile + warm
        t0 = time.time()
        res = solve_fleet(fleet, **kw)
        warm = time.time() - t0
        per_p[str(p)] = {
            "warm_s": round(warm, 3),
            "J_med": round(float(np.median(res.J)), 3),
            "rounds_executed": int(res.rounds),
        }
        print_fn(
            f"fleet,partitions P={p} K={p + 1} B={batch} warm={warm:.2f}s "
            f"J_med={per_p[str(p)]['J_med']:.2f}"
        )

    # Mixed-P fleet: one padded batch vs the per-instance sequential path.
    mixed = sample_fleet(batch * 2, seed=2028, partitions=(1, 2, 3, 4))
    res = solve_fleet(mixed, **kw)
    seq = solve_sequential(mixed, **kw)
    for b, r in enumerate(seq):
        np.testing.assert_allclose(res.J[b], r.J, rtol=1e-3)
    k_env = res.hosts.shape[-1] + 1
    print_fn(
        f"fleet,partitions mixed-P B={len(mixed)} K_env={k_env} "
        f"rounds={res.rounds} (one compiled padded batch; == sequential "
        f"rtol 1e-3)"
    )
    return {
        "per_p": per_p,
        "mixed": {
            "batch": len(mixed),
            "k_envelope": k_env,
            "rounds_executed": int(res.rounds),
            "matches_sequential": True,
        },
    }


def _bench_shard_axis(print_fn) -> dict:
    """The engine over a real instance-axis mesh: parity + layout guarantees
    on a non-divisible batch, warm throughput recorded for trend context."""
    n_dev = len(jax.devices())
    if n_dev < 2:
        print_fn(
            "fleet,shard skipped: 1 device visible (set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8)"
        )
        return {"n_devices": 1, "skipped": True}
    batch = BATCH if BATCH % n_dev else BATCH + 1  # force pad-and-trim
    fleet = sample_fleet(batch, seed=2027)
    kw = dict(**SOLVE_KW)

    res_u = solve_fleet(fleet, **kw)  # compile + warm, unsharded
    res_s = solve_fleet(fleet, shard=True, **kw)
    t0 = time.time()
    res_u = solve_fleet(fleet, **kw)
    t_warm_u = time.time() - t0
    t0 = time.time()
    res_s = solve_fleet(fleet, shard=True, **kw)
    t_warm_s = time.time() - t0

    np.testing.assert_allclose(res_s.J, res_u.J, rtol=1e-5)
    assert res_s.shard.sharded and res_s.shard.output_sharded, res_s.shard
    assert res_s.shard.padded_batch % n_dev == 0
    assert res_s.n_instances == batch

    ratio = t_warm_u / t_warm_s
    out = {
        "n_devices": n_dev,
        "batch": batch,
        "padded_batch": res_s.shard.padded_batch,
        "warm_unsharded_s": round(t_warm_u, 3),
        "warm_sharded_s": round(t_warm_s, 3),
        # NOT trend-linted (key avoids 'ratio'/'speedup'): on a simulated
        # host-device mesh all shards share the same cores, so this is a
        # sanity readout, not a performance claim.
        "warm_sharded_vs_unsharded_x": round(ratio, 3),
    }
    print_fn(
        f"fleet,shard n_dev={n_dev} B={batch}->"
        f"{res_s.shard.padded_batch} warm: unsharded={t_warm_u:.2f}s "
        f"sharded={t_warm_s:.2f}s ({ratio:.2f}x)  parity rtol 1e-5 OK"
    )
    return out


def _bench_obs(print_fn) -> dict:
    """Round-trace overhead budget: tracing must be (close to) free.

    The trace buffers are written by the same masked dynamic-column updates
    as the J history and never read inside the loop, so the compiled-loop
    cost is a handful of [B] stores per round. Warm per-round wall times are
    measured interleaved (best-of-N each) to cancel drift; the acceptance
    bound is 5% relative plus a 1 ms/round absolute grace so CPU timer noise
    on a fast loop cannot flake the bench."""
    fleet = [erdos_renyi(SOLVER_V, 12, seed=100 + s) for s in range(SOLVER_BATCH)]
    kw = dict(**SOLVER_KW)
    rounds = kw["m_max"]
    reps = 5

    res_on = solve_fleet(fleet, trace=True, **kw)  # compile both variants
    res_off = solve_fleet(fleet, trace=False, **kw)

    # --- tracing must not change a single bit of the solved result --------
    assert res_off.trace is None and res_on.trace is not None
    assert np.array_equal(res_on.J, res_off.J)
    assert np.array_equal(res_on.history, res_off.history, equal_nan=True)
    assert np.array_equal(res_on.hosts, res_off.hosts)
    assert np.array_equal(res_on.iters, res_off.iters)
    # The trace's NaN mask IS the history's freeze mask.
    assert np.array_equal(
        np.isnan(res_on.trace.J_comm), np.isnan(res_on.history)
    )

    best = {True: np.inf, False: np.inf}
    for _ in range(reps):
        for traced in (True, False):
            t0 = time.time()
            solve_fleet(fleet, trace=traced, **kw)
            best[traced] = min(best[traced], time.time() - t0)
    per_round_on = best[True] / rounds
    per_round_off = best[False] / rounds
    overhead = per_round_on / per_round_off - 1.0
    print_fn(
        f"fleet,obs V={SOLVER_V} B={SOLVER_BATCH} warm per-round: "
        f"traced={per_round_on * 1e3:.1f}ms untraced={per_round_off * 1e3:.1f}ms "
        f"overhead={overhead * 100:+.1f}%  bitwise-identical OK"
    )
    assert per_round_on <= per_round_off * 1.05 + 1e-3, (
        f"round-trace overhead budget blown: traced {per_round_on * 1e3:.2f}"
        f"ms/round vs untraced {per_round_off * 1e3:.2f}ms/round "
        f"({overhead * 100:+.1f}%, budget 5%)"
    )
    return {
        "V": SOLVER_V,
        "batch": SOLVER_BATCH,
        "per_round_traced_ms": round(per_round_on * 1e3, 2),
        "per_round_untraced_ms": round(per_round_off * 1e3, 2),
        # Keep the key clear of 'ratio'/'speedup'/'per_round' so the trend
        # lint never flags timer noise on a bounded-by-assert quantity.
        "trace_overhead_frac": round(max(overhead, 0.0), 4),
        "mean_churn_per_round": round(res_on.trace.mean_churn(), 3),
        "bitwise_identical": True,
    }


def _bench_chaos(print_fn) -> dict:
    """Section 8 (fault-injection control plane, DESIGN.md section 15).

    A seeded fault trace (>= 5 node failures, >= 3 link degradations, 1
    flash crowd at full scale) over the IoT-tree fleet, driven through
    `launch.control.run_control` with warm-started re-solves. Asserted:
    every epoch feasible (no live partition on a dead node, finite J), zero
    non-finite epochs, and warm event-epochs re-solve in <= 0.5x the engine
    rounds of the matching solve-from-scratch (compare_cold) — the
    warm-start carry + freeze-mask machinery actually earning its keep
    under adversity. `warm/cold_rounds_executed` are trend-linted as
    machine-portable convergence metrics (lower is better)."""
    from repro.chaos import generate_trace
    from repro.launch.control import run_control

    epochs = 16 if _SMALL else 50
    batch = 6
    fleet = [
        iot_hierarchy(seed=40 + s, n_edge=4, devices_per_edge=3, n_apps=8)
        for s in range(batch)
    ]
    n_fail, n_deg, n_crowd = (3, 2, 1) if _SMALL else (5, 3, 1)
    trace = generate_trace(
        fleet, epochs, seed=4096, node_failures=n_fail,
        link_degradations=n_deg, flash_crowds=n_crowd,
    )
    counts = trace.counts()
    assert counts["node_down"] >= n_fail
    assert counts["link_degrade"] >= n_deg
    assert counts["flash_crowd"] >= n_crowd

    t0 = time.time()
    ctl = run_control(
        fleet, trace=trace, m_max=20, t_phi=5, round_to=8,
        compare_cold=True,
    )
    wall = time.time() - t0
    s = ctl.summary()

    assert s["feasible_fraction"] == 1.0, (
        f"chaos: {s['infeasible_epochs']} infeasible epochs"
    )
    assert s["nonfinite_epochs"] == 0, (
        f"chaos: {s['nonfinite_epochs']} epochs with non-finite J"
    )
    warm_r = s["warm_rounds_executed"]
    cold_r = s.get("cold_rounds_executed", 0.0)
    assert cold_r > 0, "chaos: compare_cold produced no baseline epochs"
    frac = warm_r / cold_r
    assert frac <= 0.5, (
        f"chaos: warm event-epochs averaged {warm_r:.2f} engine rounds vs "
        f"{cold_r:.2f} from scratch ({frac:.2f}x > 0.50x budget)"
    )
    print_fn(
        f"fleet,chaos B={batch} epochs={epochs} "
        f"events[down={counts['node_down']} degrade={counts['link_degrade']} "
        f"crowd={counts['flash_crowd']}] feasible=100% "
        f"warm={warm_r:.1f} vs cold={cold_r:.1f} rounds ({frac:.2f}x) "
        f"fallback={s['fallback_rate']:.0%} "
        f"p95-recovery={s['p95_recovery_latency_s'] * 1e3:.0f}ms"
    )
    return {
        "batch": batch,
        "epochs": epochs,
        "event_counts": counts,
        "feasible_fraction": s["feasible_fraction"],
        "nonfinite_epochs": s["nonfinite_epochs"],
        "fallback_rate": s["fallback_rate"],
        "warm_rounds_executed": warm_r,
        "cold_rounds_executed": cold_r,
        # Bounded by the assert above; key avoids 'ratio' so the trend lint
        # does not treat lower-is-better as a regression direction error.
        "warm_vs_cold_rounds_frac": round(frac, 3),
        "p95_recovery_latency_s": s["p95_recovery_latency_s"],
        "epochs_per_s": round(epochs / wall, 3),
    }


def _bench_pareto(print_fn) -> dict:
    """Section 9 (split-point Pareto search, DESIGN.md section 17).

    A compact end-to-end split search — candidate enumeration, mixed-P
    phantom padding, ONE batched solve, front extraction with dominance
    hard gates — so BENCH_fleet.json tracks the fleet engine's first
    at-scale batch consumer (`candidates_per_s`, higher is better) next to
    the engine sections it stresses. The full-scale search over the whole
    zoo lives in BENCH_pareto.json (benchmarks/pareto_bench.py)."""
    from benchmarks.pareto_bench import sweep_section

    return sweep_section(
        print_fn,
        archs=("qwen1.5-0.5b", "hymba-1.5b"),
        topologies=("iot",),
        max_per_p=4 if _SMALL else 8,
        m_max=SOLVE_KW["m_max"],
        t_phi=SOLVE_KW["t_phi"],
        min_per_cell=20 if _SMALL else 50,
    )


def _bench_phases(print_fn) -> dict:
    """Section 10: per-phase round profile over the section-1 fleet.

    Persists where one engine round's budget actually goes. The measured
    split (placement a few percent, forwarding dominant) is the datum
    behind the lane-chunk layout decision in DESIGN.md section 18 — keep it
    in BENCH_fleet.json so a future shift (e.g. a placement regression
    making the sweep dominant again) is visible in the trend."""
    from repro.obs import profile_round_phases

    fleet = sample_fleet(BATCH, **ENGINE_FLEET_KW)
    prof = profile_round_phases(
        fleet, t_phi=SOLVE_KW["t_phi"], reps=WARM_REPS, **pallas_knobs()
    )
    prof["placement_sweep_ms"] = prof["placement_ms"]
    print_fn(
        f"fleet,phases B={prof['batch']} t_phi={prof['t_phi']} "
        f"placement={prof['placement_ms']:.1f}ms "
        f"({prof['placement_share']:.1%}) "
        f"forwarding={prof['forwarding_ms']:.1f}ms "
        f"({prof['forwarding_share']:.1%}) "
        f"round_eval={prof['round_eval_ms']:.1f}ms "
        f"({prof['round_eval_share']:.1%})"
    )
    return prof


SECTIONS_ENV = "REPRO_FLEET_SECTIONS"


def run(print_fn=print, solver: str = "neumann") -> dict:
    sections = {
        "engine": lambda: _bench_batched_vs_sequential(print_fn, solver),
        "early_exit": lambda: _bench_early_exit(print_fn),
        "solver_axis": lambda: _bench_solver_axis(print_fn),
        "solver_parity": lambda: _bench_solver_parity(print_fn),
        "partition_axis": lambda: _bench_partition_axis(print_fn),
        "shard_axis": lambda: _bench_shard_axis(print_fn),
        "obs": lambda: _bench_obs(print_fn),
        "chaos": lambda: _bench_chaos(print_fn),
        "pareto": lambda: _bench_pareto(print_fn),
        "phases": lambda: _bench_phases(print_fn),
    }
    requested = os.environ.get(SECTIONS_ENV)
    if requested:
        want = {s.strip() for s in requested.split(",") if s.strip()}
        unknown = want - sections.keys()
        if unknown:
            raise ValueError(
                f"{SECTIONS_ENV} names unknown sections {sorted(unknown)}; "
                f"known: {sorted(sections)}"
            )
    else:
        want = set(sections)
    out = {}
    for name, fn in sections.items():
        if name in want:
            out[name] = fn()
        else:
            # An explicit marker, not an omission: --check-trend refuses to
            # baseline against a section that never ran.
            out[name] = {"skipped": True}
            print_fn(f"fleet,{name} skipped ({SECTIONS_ENV})")
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--solver",
        choices=("neumann", "lu"),
        default="neumann",
        help="fixed-point solver for the batched-vs-sequential section "
        "(the solver-axis section always measures both)",
    )
    ap.add_argument(
        "--shard",
        action="store_true",
        help="run ONLY the shard-axis section (multi-device smoke)",
    )
    args = ap.parse_args()
    if args.shard:
        _bench_shard_axis(print)
        return 0
    run(solver=args.solver)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
