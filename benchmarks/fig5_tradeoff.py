"""Fig. 5 reproduction: communication-computation tradeoff (IoT).

J_eta = eta * J_comm + (1-eta) * J_comp. Validates: the optimized solution
adapts to the weighting (comm-heavy eta gives lower comm, comp-heavy gives
lower comp), and the weighted total has an interior minimum — neither
extreme is universally optimal.

The eta grid is solved as ONE batched fleet: per-instance cost-model weights
are pytree data (structs.CostModel), so all seven operating points share a
single jitted ALT computation — the shared round engine's while_loop, which
exits once every eta has stalled instead of padding to m_max."""
from __future__ import annotations

import json

from repro.core import iot
from repro.fleet import eta_grid, solve_fleet

ETAS = (0.05, 0.2, 0.35, 0.5, 0.65, 0.8, 0.95)


def run(print_fn=print, n_parts: int | None = None) -> dict:
    """`n_parts` sweeps the same eta grid at a different split depth
    (stage-generic core, DESIGN.md section 13); None = the paper's P = 2."""
    fleet = eta_grid(iot, ETAS, n_parts=n_parts)
    res = solve_fleet(fleet, m_max=30, t_phi=10)
    print_fn(f"fig5,engine rounds executed: {res.rounds}/30")
    out = {}
    for i, eta in enumerate(ETAS):
        out[str(eta)] = {
            "J_eta": float(res.J[i]),
            "J_comm": float(res.J_comm[i]),
            "J_comp": float(res.J_comp[i]),
        }
        print_fn(
            f"fig5,eta={eta:4.2f} J_eta={res.J[i]:12.3f} "
            f"comm={res.J_comm[i]:12.2f} comp={res.J_comp[i]:12.2f}"
        )
    js = [out[str(e)]["J_eta"] for e in ETAS]
    interior_min = min(js[1:-1])
    assert interior_min <= js[0] and interior_min <= js[-1], js
    # Solutions adapt: comm-heavy weighting yields lower comm cost than
    # comp-heavy weighting, and vice versa.
    assert out[str(ETAS[-1])]["J_comm"] < out[str(ETAS[0])]["J_comm"]
    assert out[str(ETAS[0])]["J_comp"] < out[str(ETAS[-1])]["J_comp"]
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--partitions", type=int, default=None,
                    help="DNN split depth P (default: the paper's 2)")
    print(json.dumps(run(n_parts=ap.parse_args().partitions), indent=1))
