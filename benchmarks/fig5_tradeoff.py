"""Fig. 5 reproduction: communication-computation tradeoff (IoT).

J_eta = eta * J_comm + (1-eta) * J_comp. Validates: the optimized solution
adapts to the weighting (comm-heavy eta gives lower comm, comp-heavy gives
lower comp), and the weighted total has an interior minimum — neither
extreme is universally optimal."""
from __future__ import annotations

import json

from repro.core import CostModel, iot, solve_alt

ETAS = (0.05, 0.2, 0.35, 0.5, 0.65, 0.8, 0.95)


def run(print_fn=print) -> dict:
    out = {}
    for eta in ETAS:
        r = solve_alt(iot(cost=CostModel(w_comm=eta, w_comp=1.0 - eta)))
        out[str(eta)] = {"J_eta": r.J, "J_comm": r.J_comm, "J_comp": r.J_comp}
        print_fn(
            f"fig5,eta={eta:4.2f} J_eta={r.J:12.3f} "
            f"comm={r.J_comm:12.2f} comp={r.J_comp:12.2f}"
        )
    js = [out[str(e)]["J_eta"] for e in ETAS]
    interior_min = min(js[1:-1])
    assert interior_min <= js[0] and interior_min <= js[-1], js
    # Solutions adapt: comm-heavy weighting yields lower comm cost than
    # comp-heavy weighting, and vice versa.
    assert out[str(ETAS[-1])]["J_comm"] < out[str(ETAS[0])]["J_comm"]
    assert out[str(ETAS[0])]["J_comp"] < out[str(ETAS[-1])]["J_comp"]
    return out


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
