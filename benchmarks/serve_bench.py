"""Serving control-plane benchmark: the fault-injection epoch loop at
steady state, persisted as BENCH_serve.json (ROADMAP "online control
plane" item; DESIGN.md section 15).

Where fleet_bench's chaos section asserts the warm-start efficiency claim
(with a per-epoch solve-from-scratch comparison), this bench measures what
production cares about: sustained epochs/sec over a mixed sampled fleet
under continuous chaos, recovery-latency percentiles (wall time from fault
to accepted placement), and the degradation-ladder fallback rate. Every
epoch must end servable: feasible_fraction == 1.0 and zero non-finite J
are hard assertions, not metrics.

`warm_rounds_executed` is trend-linted (machine-portable, lower is
better): warm event-epochs needing more engine rounds to re-converge at
the same tolerance is a convergence regression no matter the hardware.
"""
from __future__ import annotations

import os
import time

from repro.chaos import generate_trace
from repro.fleet import sample_fleet
from repro.launch.control import run_control

_SMALL = bool(os.environ.get("SCALE_SMALL"))


def run(print_fn=print) -> dict:
    epochs = 12 if _SMALL else 50
    instances = 4 if _SMALL else 8
    n_fail, n_deg, n_crowd = (3, 2, 1) if _SMALL else (5, 3, 1)
    fleet = sample_fleet(
        instances, families=["iot_hierarchy"], seed=2030
    )
    trace = generate_trace(
        fleet, epochs, seed=2031, node_failures=n_fail,
        link_degradations=n_deg, flash_crowds=n_crowd,
    )
    t0 = time.time()
    ctl = run_control(
        fleet, trace=trace, m_max=20, t_phi=5, round_to=8,
    )
    wall = time.time() - t0
    s = ctl.summary()
    assert s["feasible_fraction"] == 1.0, (
        f"serve: {s['infeasible_epochs']} infeasible epochs"
    )
    assert s["nonfinite_epochs"] == 0, (
        f"serve: {s['nonfinite_epochs']} epochs with non-finite J"
    )
    print_fn(
        f"serve,control B={instances} epochs={epochs} "
        f"{s['epochs_per_s']:.2f} epochs/s "
        f"p95-recovery={s['p95_recovery_latency_s'] * 1e3:.0f}ms "
        f"fallback={s['fallback_rate']:.0%} feasible=100% "
        f"warm-rounds={s['warm_rounds_executed']:.1f} wall={wall:.1f}s"
    )
    return {
        "instances": instances,
        "epochs": epochs,
        "epochs_per_s": s["epochs_per_s"],
        "p50_recovery_latency_s": s["p50_recovery_latency_s"],
        "p95_recovery_latency_s": s["p95_recovery_latency_s"],
        "fallback_rate": s["fallback_rate"],
        "fallback_epochs": s["fallback_epochs"],
        "feasible_fraction": s["feasible_fraction"],
        "nonfinite_epochs": s["nonfinite_epochs"],
        "warm_epochs": s["warm_epochs"],
        "warm_rounds_executed": s["warm_rounds_executed"],
        "event_counts": s["events"],
    }


if __name__ == "__main__":
    run()
