from .registry import ARCHS, ZOO, get_config, reduced_config  # noqa: F401
