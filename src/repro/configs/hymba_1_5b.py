"""hymba-1.5b [hybrid]: parallel attention + Mamba heads per block, sliding
window on the attention branch [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 ssm_state=16 vocab=32001."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    vocab=32_001,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    mlp_act="swiglu",
    sliding_window=1024,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    tie_embeddings=True,
)
