"""mixtral-8x22b [moe]: 8 experts top-2, sliding-window attention
[arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8) expert d_ff=16384 vocab=32768."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    vocab=32_768,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    n_experts=8,
    top_k=2,
    moe_d_ff=16_384,
    mlp_act="swiglu",
    sliding_window=4096,
    tie_embeddings=False,
)
