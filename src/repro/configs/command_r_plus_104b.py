"""command-r-plus-104b [dense]: GQA, no-bias, parallel attn/MLP blocks, tied
embeddings [hf:CohereForAI/c4ai-command-r-v01].

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12_288,
    vocab=256_000,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33_792,
    mlp_act="swiglu",
    parallel_block=True,
    tie_embeddings=True,
)
