"""zamba2-2.7b [hybrid, interleaved]: Mamba-2 backbone with a (shared)
attention block applied every 6th layer [arXiv:2411.15242].

54L d_model=2560 32H d_ff=10240 ssm_state=64 vocab=32000. Profile-only:
interleaved stacks are not implemented by the executable substrate
(init_params raises); the partition bridge costs attention vs SSM layers
from hybrid_attn_period."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    vocab=32_000,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10_240,
    mlp_act="gelu",
    hybrid_attn_period=6,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    tie_embeddings=True,
)
