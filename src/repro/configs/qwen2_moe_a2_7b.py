"""qwen2-moe-a2.7b [moe]: 60 routed experts top-4 + shared expert, QKV bias
[hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) expert d_ff=1408 shared d_ff=5632 vocab=151936."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    vocab=151_936,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    n_experts=60,
    top_k=4,
    moe_d_ff=1408,
    shared_d_ff=5632,
    mlp_act="swiglu",
    qkv_bias=True,
    tie_embeddings=True,
)
