"""mamba2-370m [ssm]: SSD / state-space duality, attention-free
[arXiv:2405.21060].

48L d_model=1024 vocab=50280 ssm_state=128 (d_inner=2048, 32 heads of 64)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    vocab=50_280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    tie_embeddings=True,
)
