"""nemotron-h-8b [hybrid, interleaved]: Mamba-2 backbone with an attention
block every 13th layer (4 of 52), relu2 MLPs [arXiv:2504.03624].

52L d_model=4096 32H (GQA kv=8) d_ff=21504 ssm_state=128 vocab=131072.
Profile-only: the executable substrate implements parallel hybrid blocks,
not interleaved stacks (init_params raises), but the partition bridge costs
every layer by its declared type (hybrid_attn_period)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-h-8b",
    family="hybrid",
    n_layers=52,
    d_model=4096,
    vocab=131_072,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=21_504,
    mlp_act="relu2",
    hybrid_attn_period=13,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    tie_embeddings=False,
)
