"""seamless-m4t-medium [audio]: encoder-decoder multimodal backbone
[arXiv:2308.11596].

12L encoder + 12L decoder, d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.
The speech frontend is a STUB per the assignment: input_specs() provides
precomputed 80-dim filterbank frame embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    n_dec_layers=12,
    d_model=1024,
    vocab=256_206,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    mlp_act="gelu",
    frontend="frames",
    frontend_dim=80,
    tie_embeddings=True,
)
