"""internvl2-76b [vlm]: InternViT + InternLM2 backbone [arXiv:2404.16821].

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. The ViT frontend is
a STUB per the assignment: input_specs() provides precomputed patch
embeddings (InternViT-6B output dim 3200) which enter through a learned
projector; the transformer backbone is exercised in full.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="dense",
    n_layers=80,
    d_model=8192,
    vocab=128_256,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    mlp_act="swiglu",
    tie_embeddings=False,
    frontend="patch",
    frontend_dim=3200,
)
