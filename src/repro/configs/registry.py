"""Architecture registry: --arch <id> resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

_MODULES = {
    "internvl2-76b": "repro.configs.internvl2_76b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "gemma-2b": "repro.configs.gemma_2b",
    "qwen1.5-0.5b": "repro.configs.qwen1_5_0_5b",
    "mamba2-370m": "repro.configs.mamba2_370m",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
}

# Profile-only additions: interleaved hybrids whose layer mix the partition
# bridge can cost (hybrid_attn_period) but the executable substrate does not
# implement (init_params raises). They complete the 12-config zoo the
# split-point Pareto search sweeps (DESIGN.md section 17) without entering
# ARCHS — the dry-run / smoke matrices iterate executable archs only.
_PROFILE_ONLY = {
    "nemotron-h-8b": "repro.configs.nemotron_h_8b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
}

ARCHS = tuple(_MODULES)
ZOO = ARCHS + tuple(_PROFILE_ONLY)


def get_config(name: str) -> ModelConfig:
    module = _MODULES.get(name) or _PROFILE_ONLY.get(name)
    if module is None:
        raise KeyError(f"unknown arch {name!r}; available: {ZOO}")
    return importlib.import_module(module).CONFIG


def reduced_config(name: str) -> ModelConfig:
    """Same-family tiny config for CPU smoke tests: few layers, narrow width,
    few experts, tiny vocab — exercises the identical code paths."""
    cfg = get_config(name)
    kv = min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0
    heads = 4 if cfg.n_heads else 0
    updates = dict(
        name=cfg.name + "-reduced",
        n_layers=2,
        d_model=128,
        vocab=512,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=32 if cfg.n_heads else 0,
        d_ff=min(cfg.d_ff, 256) if cfg.d_ff else 0,
        frontend_dim=48 if cfg.frontend != "none" else 0,
        remat=False,
    )
    if cfg.family == "moe":
        updates.update(
            n_experts=min(cfg.n_experts, 8),
            top_k=min(cfg.top_k, 2),
            moe_d_ff=64,
            shared_d_ff=64 if cfg.shared_d_ff else 0,
        )
    if cfg.family in ("ssm", "hybrid"):
        updates.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
    if cfg.family == "encdec":
        updates.update(n_dec_layers=2)
    if cfg.sliding_window is not None:
        updates.update(sliding_window=32)
    return dataclasses.replace(cfg, **updates)
