"""GPipe-style pipeline parallelism over a mesh axis (the multi-pod story).

Across pods the DCN is ~10x slower than ICI, so all-reducing gradients every
step (pure cross-pod DP, the dry-run default) pays a large collective. The
alternative at 1000+-node scale is to map PIPELINE STAGES onto the pod axis:
each pod holds a contiguous block of layers and only ships microbatch
activations (B_mb x S x d) to its successor — point-to-point, overlappable.

Implementation: shard_map over the chosen axis; the classic skewed schedule
runs M + P - 1 ticks; at tick t, stage s processes microbatch (t - s), and
activations move one hop per tick via collective-permute:

    tick:       0    1    2    3    4   (M=3, P=3)
    stage 0:   mb0  mb1  mb2   -    -
    stage 1:    -   mb0  mb1  mb2   -
    stage 2:    -    -   mb0  mb1  mb2

The wrapper is model-agnostic: `stage_fn(stage_params, x) -> x` applies one
stage's layer block (e.g. a scan over L/P layers). Tested against the
sequential application of all stages (tests/test_pipeline.py)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def pipeline_apply(stage_fn, stage_params, x_microbatches, *, mesh: Mesh,
                   axis: str = "pod"):
    """Run a P-stage pipeline over `axis`.

    stage_params: pytree with leading dim P (stage-major), sharded over axis.
    x_microbatches: [M, mb, ...] microbatched inputs (replicated).
    Returns [M, mb, ...] outputs of the last stage.
    """
    n_stages = mesh.shape[axis]
    m = x_microbatches.shape[0]
    ticks = m + n_stages - 1

    def per_stage(params_local, xs):
        # params_local: [1, ...] this stage's slice; xs: [M, mb, ...] (full).
        stage = jax.lax.axis_index(axis)
        p_local = jax.tree.map(lambda a: a[0], params_local)
        mb_shape = xs.shape[1:]
        carry_in = jnp.zeros(mb_shape, xs.dtype)
        outputs = jnp.zeros_like(xs)

        def tick(state, t):
            carry, outputs = state
            # Stage 0 ingests microbatch t; others consume the carry.
            mb_idx = jnp.clip(t, 0, m - 1)
            x_in = jnp.where(stage == 0, xs[mb_idx], carry)
            y = stage_fn(p_local, x_in)
            # Valid iff this stage holds microbatch (t - stage) in [0, M).
            active = (t - stage >= 0) & (t - stage < m)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # Last stage records its finished microbatch.
            out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            record = active & (stage == n_stages - 1)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(record, y, outputs[out_idx]),
                out_idx,
                axis=0,
            )
            # Ship activations one stage forward (ring permute; the wrap-
            # around value into stage 0 is ignored).
            carry = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return (carry, outputs), None

        (carry, outputs), _ = jax.lax.scan(
            tick, (carry_in, outputs), jnp.arange(ticks)
        )
        # Only the last stage holds real outputs (others are zeros); the
        # psum broadcasts them so the replicated out_spec is truthful.
        return jax.lax.psum(outputs, axis)

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    if hasattr(jax, "shard_map"):  # jax >= 0.6 top-level API
        fn = jax.shard_map(
            per_stage,
            mesh=mesh,
            in_specs=(spec_params, P()),
            out_specs=P(),
            check_vma=False,
        )
    else:  # older releases: experimental namespace, check_rep kwarg
        from jax.experimental.shard_map import shard_map as _shard_map

        fn = _shard_map(
            per_stage,
            mesh=mesh,
            in_specs=(spec_params, P()),
            out_specs=P(),
            check_rep=False,
        )
    return fn(stage_params, x_microbatches)
