"""Sharding rules: map param/activation/cache tree paths -> PartitionSpecs.

Two layout families live here:

1. the serving-substrate rules below (params / activations / decode caches
   over a ("data", "model"[, "pod"]) mesh), and
2. the fleet control plane's instance-axis layout (`FLEET_AXIS`,
   `fleet_sharding`, `shard_fleet`): a stacked `[B, ...]` problem ensemble
   laid out over a 1-D mesh of local devices. Batch parallelism over
   instances has no cross-instance communication, so the only collective the
   partitioner ever emits is the engine's one per-trip `any_active`
   reduction (core/engine.py). `fleet/solve.py` commits inputs with
   `shard_fleet` and verifies outputs with `carries_fleet_sharding`, so a
   layout fallback can never be silent.

Baseline layout (the paper-faithful starting point for the roofline pass;
the §Perf hillclimb iterates on these):

  * TP over the "model" axis on the natural tensor-parallel dim of every
    matmul (attention heads, FFN hidden, experts, vocab);
  * FSDP/ZeRO over the "data" axis on the other large dim (params + Adam
    moments are fully sharded; XLA inserts the per-layer all-gathers);
  * batch over ("pod", "data") — the pod axis is pure DP across the DCN;
  * decode KV caches: batch over ("pod","data"), sequence over "model"
    (flash-decoding-style distributed softmax via GSPMD reductions).

Rules are divisibility-aware: a dim is only assigned a mesh axis when the
axis size divides it (uneven/GSPMD-padded layouts showed up as pathological
collectives in the dry-run, e.g. Kv=8 heads over 16-way model).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

# ---------------------------------------------------------------------------
# Fleet control plane: instance-axis layout over a 1-D device mesh
# ---------------------------------------------------------------------------

# The one mesh-axis name the fleet path uses everywhere: launch/mesh.py builds
# the mesh over it, fleet/solve.py commits inputs to it, and the sharded test
# suite asserts outputs still carry it.
FLEET_AXIS = "fleet"


def fleet_pspec() -> P:
    """Leading instance axis over the fleet mesh, everything else replicated."""
    return P(FLEET_AXIS)


def fleet_sharding(mesh: Mesh) -> NamedSharding:
    """The committed layout of every `[B, ...]` leaf of a stacked fleet."""
    if FLEET_AXIS not in mesh.axis_names:
        raise ValueError(
            f"mesh {mesh.axis_names} has no {FLEET_AXIS!r} axis; build it "
            "with repro.launch.mesh.make_fleet_mesh"
        )
    return NamedSharding(mesh, fleet_pspec())


def shard_fleet(tree, mesh: Mesh):
    """Commit every array leaf of a stacked fleet pytree to the fleet layout.

    All data leaves of a stacked `Problem` / `PadInfo` are `[B, ...]` with B
    divisible by the mesh size (fleet/solve.py pads with inert repeats first),
    so one NamedSharding covers the whole tree: dim 0 over `FLEET_AXIS`,
    higher dims replicated."""
    sharding = fleet_sharding(mesh)
    return jax.tree_util.tree_map(
        lambda x: jax.device_put(x, sharding), tree
    )


def carries_fleet_sharding(x) -> bool:
    """True iff `x` is actually laid out over a multi-device fleet axis.

    This is the output-side check for the "no silent fallback" contract: a
    replicated array, a single-device array, or a NamedSharding whose dim 0
    does not name `FLEET_AXIS` all return False."""
    sharding = getattr(x, "sharding", None)
    if not isinstance(sharding, NamedSharding):
        return False
    if dict(sharding.mesh.shape).get(FLEET_AXIS, 1) < 2:
        return False
    spec = sharding.spec
    if len(spec) == 0:
        return False
    dim0 = spec[0] if isinstance(spec[0], tuple) else (spec[0],)
    return FLEET_AXIS in dim0


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    data_axis: str = "data"
    model_axis: str = "model"
    pod_axis: str | None = None  # set when the mesh has a pod dimension
    fsdp: bool = True  # shard the non-TP dim of params over data
    # Decode-cache layout: "seq" shards the KV sequence dim over model,
    # "heads" shards KV heads (falls back to seq when kv % axis != 0).
    cache_layout: str = "seq"
    # Sequence-parallel residual stream: the scan-carried [B, S, d]
    # activations are sharded over the model axis on S (Korthikanti-style
    # SP) — divides stored-activation memory by the TP degree.
    seq_shard_residual: bool = False
    # 2D ("data"+"model") tensor parallelism for serving (hillclimb option).
    serve_2d_tp: bool = False


def _axis_size(mesh: Mesh, name: str | None) -> int:
    if name is None:
        return 1
    return mesh.shape[name]


def _maybe(mesh, dim_size, axis):
    """Assign `axis` to a dim only when it divides evenly."""
    if axis is None:
        return None
    return axis if dim_size % _axis_size(mesh, axis) == 0 else None


def batch_pspec(rules: ShardingRules) -> P:
    if rules.pod_axis:
        return P((rules.pod_axis, rules.data_axis))
    return P(rules.data_axis)


def batch_axes_size(mesh: Mesh, rules: ShardingRules) -> int:
    n = _axis_size(mesh, rules.data_axis)
    if rules.pod_axis:
        n *= _axis_size(mesh, rules.pod_axis)
    return n


def batch_pspec_for(mesh: Mesh, rules: ShardingRules, batch: int) -> P:
    """Replicate when the global batch doesn't divide the DP axes (e.g. the
    batch=1 long-context decode cell)."""
    if batch % batch_axes_size(mesh, rules) == 0:
        return batch_pspec(rules)
    return P()


def _param_rule(path: str, shape, mesh: Mesh, rules: ShardingRules, cfg: ModelConfig) -> P:
    if path.endswith("/__s"):
        return P()  # per-tensor quantization scale: replicated scalar
    if path.endswith("/__q"):
        path = path[: -len("/__q")]  # int8 payload shards like its parent
    d_ax = rules.data_axis if rules.fsdp else None
    m_ax = rules.model_axis
    dims = len(shape)

    def spec(*axes):
        axes = list(axes) + [None] * (dims - len(axes))
        return P(*axes)

    # ---- embedding / head ----
    if path.endswith("embed/embed"):
        return spec(_maybe(mesh, shape[0], m_ax), _maybe(mesh, shape[1], d_ax))
    if path.endswith("embed/lm_head"):
        return spec(_maybe(mesh, shape[0], d_ax), _maybe(mesh, shape[1], m_ax))
    if path.endswith("embed/frontend_proj"):
        return spec(None, _maybe(mesh, shape[1], m_ax))

    # ---- attention (leading stacked-layer dim) ----
    if "/attn/" in path or "/self_attn/" in path or "/cross_attn/" in path:
        leaf = path.rsplit("/", 1)[-1]
        if leaf == "wq":  # [L, d, H, hd]
            h_ax = _maybe(mesh, shape[2], m_ax)
            return spec(None, _maybe(mesh, shape[1], d_ax), h_ax)
        if leaf in ("wk", "wv"):  # [L, d, Kv, hd]
            kv_ax = _maybe(mesh, shape[2], m_ax)
            if kv_ax is None:
                # few KV heads: row-parallel on d instead (psum after)
                return spec(None, _maybe(mesh, shape[1], m_ax), None)
            return spec(None, _maybe(mesh, shape[1], d_ax), kv_ax)
        if leaf == "wo":  # [L, H, hd, d]
            return spec(None, _maybe(mesh, shape[1], m_ax), None, _maybe(mesh, shape[3], d_ax))
        if leaf == "bq":  # [L, H, hd]
            return spec(None, _maybe(mesh, shape[1], m_ax))
        if leaf in ("bk", "bv"):
            return spec(None, _maybe(mesh, shape[1], m_ax))

    # ---- dense / shared MLP ----
    if path.rsplit("/", 1)[-1] in ("wi", "wg") and "/mlp" in path or "/shared/" in path and path.endswith(("wi", "wg")):
        return spec(None, _maybe(mesh, shape[1], d_ax), _maybe(mesh, shape[2], m_ax)) if dims == 3 else P()
    if path.endswith("/mlp/wo") or path.endswith("/shared/wo"):
        return spec(None, _maybe(mesh, shape[1], m_ax), _maybe(mesh, shape[2], d_ax))

    # ---- MoE ----
    if path.endswith("/moe/router"):
        return spec(None, _maybe(mesh, shape[1], d_ax), None)
    if path.endswith(("/moe/wi_e", "/moe/wg_e")):  # [L, E, d, ff]
        e_ax = _maybe(mesh, shape[1], m_ax)
        if e_ax is not None:
            return spec(None, e_ax, _maybe(mesh, shape[2], d_ax), None)
        return spec(None, None, _maybe(mesh, shape[2], d_ax), _maybe(mesh, shape[3], m_ax))
    if path.endswith("/moe/wo_e"):  # [L, E, ff, d]
        e_ax = _maybe(mesh, shape[1], m_ax)
        if e_ax is not None:
            return spec(None, e_ax, None, _maybe(mesh, shape[3], d_ax))
        return spec(None, None, _maybe(mesh, shape[2], m_ax), _maybe(mesh, shape[3], d_ax))
    if path.endswith("/moe/shared_gate"):
        return spec(None, _maybe(mesh, shape[1], d_ax), None)

    # ---- SSM ----
    if path.endswith("/ssm/in_proj"):  # [L, d, K]
        return spec(None, _maybe(mesh, shape[1], m_ax), None)
    if path.endswith("/ssm/out_proj"):  # [L, din, d]
        return spec(None, _maybe(mesh, shape[1], m_ax), None)
    if path.endswith("/ssm/conv_w") or path.endswith("/ssm/conv_b"):
        return P()

    # norms / scalars / small vectors: replicated
    return P()


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for keypath, leaf in flat:
        parts = []
        for k in keypath:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append(("/".join(parts), leaf))
    return out, treedef


def param_pspecs(cfg: ModelConfig, specs_tree, mesh: Mesh, rules: ShardingRules):
    """PartitionSpec tree congruent with the (eval_shape) param tree."""
    flat, treedef = _tree_paths(specs_tree)
    pspecs = [
        _param_rule(path, leaf.shape, mesh, rules, cfg) for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, pspecs)


def cache_pspecs(cfg: ModelConfig, caches_tree, mesh: Mesh, rules: ShardingRules):
    """Decode caches: [L, B, Kv, C, hd] attn; [L, B, nh, hp, n] ssm states."""
    nb = batch_axes_size(mesh, rules)
    m_ax = rules.model_axis

    def b_of(dim_size):
        if dim_size % nb != 0:
            return None
        return (rules.pod_axis, rules.data_axis) if rules.pod_axis else rules.data_axis

    def rule(path: str, leaf):
        dims = len(leaf.shape)
        if path.endswith(("attn/k", "attn/v")) or path.endswith(("cross_k", "cross_v")):
            # [L, B, Kv, C, hd]
            b_ax = b_of(leaf.shape[1])
            if rules.cache_layout == "heads" and leaf.shape[2] % _axis_size(mesh, m_ax) == 0:
                return P(None, b_ax, m_ax, None, None)
            return P(None, b_ax, None, _maybe(mesh, leaf.shape[3], m_ax), None)
        if path.endswith("ssm/state"):  # [L, B, nh, hp, n]
            return P(None, b_of(leaf.shape[1]), _maybe(mesh, leaf.shape[2], m_ax), None, None)
        if path.endswith("ssm/conv"):  # [L, B, cw-1, conv_dim]
            return P(None, b_of(leaf.shape[1]), None, _maybe(mesh, leaf.shape[3], m_ax))
        if dims >= 2:
            return P(None, b_of(leaf.shape[1]))
        return P()

    flat, treedef = _tree_paths(caches_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [rule(path, leaf) for path, leaf in flat]
    )


def to_named_shardings(mesh: Mesh, pspec_tree):
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
