"""Activation sharding constraints.

GSPMD propagation from parameter/input shardings alone lets intermediate
layouts drift (observed in the dry-run: attention scores re-materialized at
GLOBAL batch — a 137 TB tensor). The fix is the standard MaxText-style
practice: explicit with_sharding_constraint at the key activation points.

Models stay mesh-agnostic: they call `shard(x, kind)`; the launcher installs
the logical->physical mapping via `activation_sharding(mesh, rules)`. When no
context is installed (unit tests, single-device runs) `shard` is a no-op.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_TLS = threading.local()


def _ctx():
    return getattr(_TLS, "ctx", None)


@contextlib.contextmanager
def activation_sharding(mesh, rules):
    prev = _ctx()
    _TLS.ctx = (mesh, rules)
    try:
        yield
    finally:
        _TLS.ctx = prev


def _batch_axes(rules):
    if rules.pod_axis:
        return (rules.pod_axis, rules.data_axis)
    return rules.data_axis


def shard(x, kind: str):
    """Constrain activation x at a named logical point (no-op w/o context)."""
    ctx = _ctx()
    if ctx is None:
        return x
    mesh, rules = ctx
    m = rules.model_axis
    b = _batch_axes(rules)
    msize = mesh.shape[m]
    bsize = mesh.shape[rules.data_axis] * (
        mesh.shape[rules.pod_axis] if rules.pod_axis else 1
    )
    if x.shape[0] % bsize != 0:
        b = None  # batch=1 long-context cells: replicate the batch dim

    def div(dim):
        return x.shape[dim] % msize == 0

    if kind == "residual":  # [B, S, d]
        if getattr(rules, "seq_shard_residual", False) and x.shape[1] % msize == 0:
            spec = P(b, m, None)
        else:
            spec = P(b, None, None)
    elif kind == "heads":  # [B, S, H, hd]
        spec = P(b, None, m if div(2) else None, None)
    elif kind == "heads_t":  # [B, H, S, hd]
        spec = P(b, m if div(1) else None, None, None)
    elif kind == "ffn":  # [B, S, ff]
        spec = P(b, None, m if div(2) else None)
    elif kind == "logits":  # [B, S, V]
        spec = P(b, None, m if div(2) else None)
    elif kind == "expert_buffers":  # [E, C, d] or [E, C, ff]
        spec = P(m if x.shape[0] % msize == 0 else None, None, None)
    elif kind == "moe_groups":  # [G, Tg, d] grouped token slabs
        g_ax = b if x.shape[0] % max(bsize, 1) == 0 else None
        spec = P(g_ax, None, None)
    elif kind == "tokens_flat":  # [T, d] / [T, E] flat token tables
        spec = P(b, None)
    elif kind == "ssm_inner":  # [B, S, K]
        spec = P(b, None, None)
    elif kind == "kv_cache":  # [B, Kv, C, hd] — seq-sharded over model
        spec = P(b, None, m if div(2) else None, None)
    elif kind == "decode_scores":  # [B, Kv, G, C]
        spec = P(b, None, None, m if div(3) else None)
    else:
        raise ValueError(kind)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, TypeError):
        # Shape/axis mismatch (e.g. tiny smoke configs): leave unconstrained.
        return x
