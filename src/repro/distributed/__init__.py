from .sharding import (  # noqa: F401
    ShardingRules,
    batch_pspec,
    cache_pspecs,
    param_pspecs,
    to_named_shardings,
)
