from .sharding import (  # noqa: F401
    FLEET_AXIS,
    ShardingRules,
    batch_pspec,
    cache_pspecs,
    carries_fleet_sharding,
    fleet_pspec,
    fleet_sharding,
    param_pspecs,
    shard_fleet,
    to_named_shardings,
)
