"""repro: congestion-aware partition placement & routing for DNN inference
(Zhang & Yadav, 2026) as a production-grade JAX framework.

Layers:
  repro.core        the paper's joint placement/routing optimizer (control plane)
  repro.kernels     Pallas TPU kernels (min-plus APSP, flash attention) + oracles
  repro.models      the 10 assigned architectures (data plane)
  repro.partition   model -> partition profile bridge (L0/L1/L2, workloads)
  repro.distributed sharding rules, pipeline runner
  repro.data/optim/checkpoint  training substrate
  repro.launch      mesh, dry-run, train, serve entry points
"""

__version__ = "1.0.0"
