"""Deterministic, checkpointable synthetic data pipeline.

Production posture without external datasets: a seeded Zipf-ish token stream
with enough structure for a ~100M model to show a falling loss curve
(local n-gram correlations + copy spans). The pipeline state is exactly
(seed, step) — it lives in the checkpoint, so restart resumes the stream
bit-exactly (fault-tolerance requirement), and restores onto any mesh shape
because batches are generated globally and sharded at device_put time.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.3
    copy_prob: float = 0.3
    copy_span: int = 16


class SyntheticLM:
    """state = (config, step); batch(step) is a pure function of both."""

    def __init__(self, cfg: DataConfig, step: int = 0):
        self.cfg = cfg
        self.step = int(step)
        # Precompute a fixed Zipf table (the "vocabulary distribution").
        rs = np.random.RandomState(cfg.seed)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / np.power(ranks, cfg.zipf_a)
        self._p = p / p.sum()
        self._perm = rs.permutation(cfg.vocab)

    def state(self) -> dict:
        return {"seed": self.cfg.seed, "step": self.step}

    @classmethod
    def restore(cls, cfg: DataConfig, state: dict) -> "SyntheticLM":
        assert state["seed"] == cfg.seed, "data stream seed mismatch"
        return cls(cfg, step=state["step"])

    def next_batch(self) -> np.ndarray:
        """[global_batch, seq_len] int32, deterministic in (seed, step)."""
        cfg = self.cfg
        rs = np.random.RandomState((cfg.seed * 1_000_003 + self.step) % 2**31)
        toks = self._perm[
            rs.choice(cfg.vocab, size=(cfg.global_batch, cfg.seq_len), p=self._p)
        ].astype(np.int32)
        # Inject copy spans: learnable structure (induction heads etc.).
        n_spans = int(cfg.copy_prob * cfg.global_batch)
        for i in rs.choice(cfg.global_batch, size=n_spans, replace=False):
            span = cfg.copy_span
            if cfg.seq_len > 4 * span:
                src = rs.randint(0, cfg.seq_len // 2 - span)
                dst = rs.randint(cfg.seq_len // 2, cfg.seq_len - span)
                toks[i, dst : dst + span] = toks[i, src : src + span]
        self.step += 1
        return toks


def make_pipeline(cfg: DataConfig, state: dict | None = None) -> SyntheticLM:
    if state is not None:
        return SyntheticLM.restore(cfg, state)
    return SyntheticLM(cfg)
