from .profile import (  # noqa: F401
    ArchProfile,
    apps_from_profiles,
    enumerate_candidates,
    flops_per_token_layer,
    profile_arch,
)
from .pareto import check_fronts, pareto_front, sweep_zoo  # noqa: F401
from .executor import run_partition, split_params  # noqa: F401
