from .profile import ArchProfile, apps_from_profiles, flops_per_token_layer, profile_arch  # noqa: F401
from .executor import run_partition, split_params  # noqa: F401
