"""Split-point Pareto search over the model zoo (DESIGN.md section 17).

The paper's headline finding is that *split flexibility* dominates in
IoT-edge-cloud settings — but the placement optimizer alone only chooses
WHERE to put fixed cuts. This module searches the split axis itself, the
Pareto-front analysis of "Where to Split?" (arXiv 2601.08025) built on the
fleet engine:

  1. `enumerate_candidates` (partition/profile.py) emits every cut point
     x P in {1..4} for each zoo architecture, with per-cut L and exact
     per-layer-type w;
  2. each candidate becomes one `Problem` per (topology, load, eta) cell —
     the scenario's own traffic matrix with every app running that
     candidate's chain — and ALL candidates across ALL cells are solved as
     ONE batched `solve_fleet` call (mixed-P phantom-stage padding from
     DESIGN.md section 13 absorbs the ragged depths);
  3. per (architecture, topology, load) cell, the solved placements are
     scored on three axes — latency (J_comm + J_comp, the unweighted total
     delay), compute (J_comp), and egress (bytes/s actually shipped across
     links: lam_a * sum_k L_k over stages whose endpoints differ) — and
     `pareto_front` filters dominated candidates.

Candidate profiles are normalized per architecture so every candidate of
one arch is comparable and lands in the scenarios' operating range: bytes
scale so the largest stage packet of the arch's default profile equals the
paper's L0 = 2.0, FLOPs scale so the total per-request work equals the
paper's 1.3 (total work is split-invariant, so this is one constant per
arch, not per candidate).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..configs import ZOO, get_config
from ..core.scenarios import DEFAULT_L, DEFAULT_W, SCENARIOS
from ..core.structs import CostModel, Problem
from ..fleet import solve_fleet
from ..obs.metrics import registry as obs_registry
from ..obs.trace import span
from .profile import ArchProfile, apps_from_profiles, enumerate_candidates, profile_arch


def pareto_front(points) -> np.ndarray:
    """Boolean mask of non-dominated rows (every objective minimized).

    Row i is dominated iff some row j is <= i in every column and < i in at
    least one; duplicated points survive together (neither strictly
    improves on the other). O(N^2) with vectorized inner sweeps — cells are
    hundreds of points, not millions."""
    pts = np.asarray(points, np.float64)
    if pts.ndim != 2:
        raise ValueError(
            f"pareto_front: expected [N, D] objectives, got shape {pts.shape}"
        )
    n = pts.shape[0]
    keep = np.ones(n, bool)
    for i in range(n):
        dominated = (pts <= pts[i]).all(axis=1) & (pts < pts[i]).any(axis=1)
        if dominated.any():
            keep[i] = False
    return keep


@dataclasses.dataclass(frozen=True)
class _ArchCandidates:
    """One architecture's enumerated candidate set + normalization."""

    profiles: tuple[ArchProfile, ...]
    n_possible: int
    byte_scale: float
    flop_scale: float


def _normalized_candidates(
    arch: str, *, seq_len, n_out_tokens, parts, max_per_p
) -> _ArchCandidates:
    cfg = get_config(arch)
    profiles, n_possible = enumerate_candidates(
        cfg, seq_len=seq_len, n_out_tokens=n_out_tokens, parts=parts,
        max_per_p=max_per_p,
    )
    base = profile_arch(cfg, seq_len=seq_len, n_out_tokens=n_out_tokens)
    return _ArchCandidates(
        profiles=tuple(profiles),
        n_possible=n_possible,
        byte_scale=DEFAULT_L[0] / max(base.L_bytes),
        flop_scale=float(sum(DEFAULT_W)) / sum(base.w_flops),
    )


def _egress_bytes_per_s(src, dst, lam, L_row, hosts_row) -> float:
    """Link bytes/s of one solved instance: every stage whose consecutive
    endpoints differ ships its packet across the network."""
    total = 0.0
    for a in range(len(src)):
        endpoints = [int(src[a]), *map(int, hosts_row[a]), int(dst[a])]
        for k in range(len(endpoints) - 1):
            if endpoints[k] != endpoints[k + 1]:
                total += float(lam[a]) * float(L_row[k])
    return total


def sweep_zoo(
    archs=None,
    topologies=("iot", "mesh"),
    loads=(1.0,),
    etas=(0.5,),
    *,
    parts=(1, 2, 3, 4),
    max_per_p=16,
    seq_len=256,
    n_out_tokens=32,
    method="ALT",
    m_max=8,
    t_phi=5,
    round_to=8,
    shard=False,
    devices=None,
    chunk_size=None,
    envelope_cap_gb=2.0,
    use_pallas=False,
    interpret=True,
    solver="neumann",
    validate=True,
) -> dict:
    """Enumerate, solve, and Pareto-filter split candidates for the zoo.

    Returns a JSON-ready report: one cell per (architecture, topology,
    load), each holding every candidate evaluation (splits, parts, eta, J,
    latency/compute/egress) and the indices of its dominated-point-filtered
    Pareto front. The entire sweep is ONE `solve_fleet` call; `chunk_size`
    / `envelope_cap_gb` (default 2 GB) bound the compiled envelope exactly
    as any other fleet."""
    archs = tuple(archs) if archs else ZOO
    topologies = tuple(topologies)
    loads = tuple(float(x) for x in loads)
    etas = tuple(float(x) for x in etas)
    for t in topologies:
        if t not in SCENARIOS:
            raise ValueError(
                f"sweep_zoo: unknown topology {t!r}; "
                f"available: {tuple(SCENARIOS)}"
            )
    for eta in etas:
        if not 0.0 <= eta <= 1.0:
            raise ValueError(f"sweep_zoo: eta must be in [0, 1], got {eta}")

    with span("pareto.enumerate", archs=len(archs)):
        cand = {
            a: _normalized_candidates(
                a, seq_len=seq_len, n_out_tokens=n_out_tokens, parts=parts,
                max_per_p=max_per_p,
            )
            for a in archs
        }
    n_enumerated = sum(len(c.profiles) for c in cand.values())
    n_possible = sum(c.n_possible for c in cand.values())
    obs_registry.counter("pareto.cut_sets_possible").inc(n_possible)
    obs_registry.counter("pareto.cut_sets_dropped").inc(
        n_possible - n_enumerated
    )

    problems: list[Problem] = []
    index: list[dict] = []
    with span("pareto.build", cells=len(archs) * len(topologies) * len(loads)):
        for topo in topologies:
            for load in loads:
                base = SCENARIOS[topo](load_scale=load)
                src = np.asarray(base.apps.src)
                dst = np.asarray(base.apps.dst)
                lam = np.asarray(base.apps.lam)
                for arch in archs:
                    ac = cand[arch]
                    for eta in etas:
                        cost = CostModel(w_comm=eta, w_comp=1.0 - eta)
                        for prof in ac.profiles:
                            apps = apps_from_profiles(
                                [prof] * len(src), src, dst, lam,
                                byte_scale=ac.byte_scale,
                                flop_scale=ac.flop_scale,
                            )
                            problems.append(
                                Problem(
                                    net=base.net, apps=apps, cost=cost,
                                    hop_bound=base.hop_bound,
                                )
                            )
                            index.append(
                                {
                                    "arch": arch,
                                    "topology": topo,
                                    "load": load,
                                    "eta": eta,
                                    "splits": list(prof.splits),
                                    "parts": prof.n_parts,
                                    "L_row": [
                                        b * ac.byte_scale
                                        for b in prof.L_bytes
                                    ],
                                }
                            )

    obs_registry.counter("pareto.candidates_solved").inc(len(problems))
    with span("pareto.solve", instances=len(problems)):
        res = solve_fleet(
            problems,
            method=method,
            m_max=m_max,
            t_phi=t_phi,
            round_to=round_to,
            shard=shard,
            devices=devices,
            chunk_size=chunk_size,
            envelope_cap_gb=envelope_cap_gb,
            use_pallas=use_pallas,
            interpret=interpret,
            solver=solver,
            trace=False,
            validate=validate,
        )

    with span("pareto.extract", instances=len(problems)):
        rows = res.per_instance()
        cells: dict[tuple, list[dict]] = {}
        for rec, row, problem in zip(index, rows, problems):
            src = np.asarray(problem.apps.src)
            dst = np.asarray(problem.apps.dst)
            lam = np.asarray(problem.apps.lam)
            point = {
                "splits": rec["splits"],
                "parts": rec["parts"],
                "eta": rec["eta"],
                "J": row["J"],
                "latency": row["J_comm"] + row["J_comp"],
                "compute": row["J_comp"],
                "egress": _egress_bytes_per_s(
                    src, dst, lam, rec["L_row"], row["hosts"]
                ),
            }
            key = (rec["arch"], rec["topology"], rec["load"])
            cells.setdefault(key, []).append(point)

        out_cells = []
        for (arch, topo, load), points in sorted(cells.items()):
            objectives = np.array(
                [[p["latency"], p["compute"], p["egress"]] for p in points]
            )
            mask = pareto_front(objectives)
            for p, on in zip(points, mask):
                p["on_front"] = bool(on)
            front = np.flatnonzero(mask).tolist()
            obs_registry.histogram("pareto.front_size").observe(len(front))
            out_cells.append(
                {
                    "arch": arch,
                    "topology": topo,
                    "load": load,
                    "n_points": len(points),
                    "front_size": len(front),
                    "n_dominated": len(points) - len(front),
                    "front": front,
                    "points": points,
                }
            )
    obs_registry.gauge("pareto.cells").set(len(out_cells))

    return {
        "archs": list(archs),
        "topologies": list(topologies),
        "loads": list(loads),
        "etas": list(etas),
        "parts": list(parts),
        "max_per_p": max_per_p,
        "seq_len": seq_len,
        "method": method,
        "n_instances": len(problems),
        "candidates_per_cell": (
            0 if not out_cells
            else min(c["n_points"] for c in out_cells)
        ),
        # Mixed-P candidates solved per (topology, load) cell — the batch
        # the acceptance gate counts (all archs x etas land in one cell).
        "candidates_per_topo_load": (
            len(problems) // max(1, len(topologies) * len(loads))
        ),
        "cut_sets_possible": n_possible,
        "cut_sets_dropped": n_possible - n_enumerated,
        "rounds": res.rounds,
        "shard": res.shard.describe(),
        "pad_overhead_fraction": (
            0.0 if res.shard.padded_batch == 0
            else (res.shard.padded_batch - res.shard.batch)
            / res.shard.padded_batch
        ),
        "cells": out_cells,
    }


def check_fronts(report: dict) -> None:
    """Hard-gate a sweep report (the CI `pareto` job's assertion):

      * every cell has a non-empty front of finite points;
      * dominated-point filtering actually filtered (n_dominated > 0);
      * the recorded front is exactly the re-verified non-dominated set.

    Raises ValueError naming the first offending cell."""
    if not report["cells"]:
        raise ValueError("check_fronts: report has no cells")
    for cell in report["cells"]:
        name = f"{cell['arch']}/{cell['topology']}/load={cell['load']}"
        pts = np.array(
            [
                [p["latency"], p["compute"], p["egress"]]
                for p in cell["points"]
            ]
        )
        if not np.isfinite(pts).all():
            raise ValueError(
                f"check_fronts: cell {name} has non-finite objectives"
            )
        if not cell["front"]:
            raise ValueError(f"check_fronts: cell {name} has an empty front")
        if cell["n_dominated"] <= 0:
            raise ValueError(
                f"check_fronts: cell {name} filtered no dominated points "
                f"({cell['n_points']} points all mutually non-dominated — "
                "the candidate set is degenerate)"
            )
        expect = np.flatnonzero(pareto_front(pts)).tolist()
        if sorted(cell["front"]) != expect:
            raise ValueError(
                f"check_fronts: cell {name} front {cell['front']} does not "
                f"match the re-verified non-dominated set {expect}"
            )
