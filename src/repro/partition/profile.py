"""Bridge between the model zoo and the paper's optimizer.

Every architecture enters problem (7) as an `ArchProfile`: K = P + 1 stage
packet sizes (stage 0 raw input, stages 1..P-1 split-point activations,
stage P final output) and P per-request partition workloads in FLOPs. The
optimizer core is stage-generic (any P — DESIGN.md section 13) and so is
this bridge: `profile_arch` accepts an arbitrary strictly-ascending cut set
(`splits=`, every interior layer boundary is a legal cut), and
`enumerate_candidates` emits the per-architecture candidate family (every
cut point x P in {1..4}) that the split-point Pareto search in
partition/pareto.py solves as one batched fleet (DESIGN.md section 17).
This is the "directly measured from a test run" quantity of the paper's
Eq. (6) — here derived analytically from the architecture config (and
cross-checked against the models / launch.hlo_cost in tests).

Split-point conventions (DESIGN.md sections 4 and 17):
  * decoder-only families: cut after layer boundary k in 1..n_layers-1
    (default L/4 — the paper's "first partition acts as a local compression
    stage"); the shipped activation is the bf16 hidden state.
  * encoder-decoder: any boundary in 1..n_enc+n_dec-1 (layers indexed
    encoder-first); the default is the encoder/decoder boundary, where the
    shipped packet is the encoder memory. A cut inside the decoder ships
    the decoder hidden states AND the memory (cross-attention reads it
    downstream).
  * interleaved hybrids (hybrid_attn_period >= 1): per-partition FLOPs sum
    the per-layer-type table (attention blocks vs SSM blocks), not a
    uniform per-layer constant.
Per-family nuances are only in how the profile is computed (MoE: active
FLOPs; SSM/hybrid: stateless requests ship only layer activations).
"""
from __future__ import annotations

import dataclasses
import itertools

import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..core.structs import Apps


def _bytes_per_token_input(cfg: ModelConfig) -> float:
    if cfg.frontend != "none":
        return cfg.frontend_dim * 2.0  # bf16 patch/frame embeddings
    return 4.0  # int32 token ids


def flops_per_token_layer(
    cfg: ModelConfig,
    ctx_len: int,
    decoder: bool = False,
    layer: int | None = None,
) -> float:
    """Forward FLOPs per token for one layer (2 x MACs convention).

    `layer` selects the block index for architectures whose blocks differ —
    interleaved hybrids carry an attention branch only every
    `hybrid_attn_period`-th block and an SSM branch otherwise. Uniform
    stacks ignore it; an interleaved hybrid with layer=None raises, because
    there is no single "the" per-layer cost to return.
    """
    d = cfg.d_model
    has_attn = cfg.attends
    has_ssm = cfg.family in ("ssm", "hybrid")
    if cfg.family == "hybrid" and cfg.hybrid_attn_period >= 1:
        if layer is None:
            raise ValueError(
                f"flops_per_token_layer: {cfg.name!r} is an interleaved "
                f"hybrid (hybrid_attn_period={cfg.hybrid_attn_period}); "
                "pass layer= — attention and SSM blocks cost differently"
            )
        has_attn, has_ssm = cfg.layer_mix(layer)
    f = 0.0
    if has_attn:
        h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        f += 2.0 * (d * h * hd + 2 * d * kv * hd + h * hd * d)  # qkvo proj
        eff_ctx = min(ctx_len, cfg.sliding_window or ctx_len)
        f += 4.0 * eff_ctx * h * hd  # scores + values
        if decoder:  # cross attention
            f += 2.0 * (d * h * hd + h * hd * d) + 4.0 * ctx_len * h * hd
    if cfg.family in ("dense", "hybrid", "encdec"):
        mult = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
        f += 2.0 * mult * d * cfg.d_ff
    if cfg.family == "moe":
        mult = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
        f += 2.0 * cfg.top_k * mult * d * cfg.moe_d_ff  # active experts only
        if cfg.shared_d_ff:
            f += 2.0 * mult * d * cfg.shared_d_ff
        f += 2.0 * d * cfg.n_experts  # router
    if has_ssm:
        din, n, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
        f += 2.0 * d * (2 * din + 2 * n + nh)  # in_proj
        f += 2.0 * cfg.conv_width * (din + 2 * n)  # conv
        f += 2.0 * 2.0 * din * n  # state update + readout (per token)
        f += 2.0 * din * d  # out_proj
    return f


def total_profile_layers(cfg: ModelConfig) -> int:
    """Layer count along the cut axis (encdec: encoder then decoder)."""
    return cfg.n_layers + (cfg.n_dec_layers if cfg.family == "encdec" else 0)


def layer_flops_table(cfg: ModelConfig, seq_len: int) -> list[float]:
    """Per-layer forward FLOPs/token, indexed along the cut axis."""
    if cfg.family == "encdec":
        enc = [
            flops_per_token_layer(cfg, seq_len) for _ in range(cfg.n_layers)
        ]
        dec = [
            flops_per_token_layer(cfg, seq_len, decoder=True)
            for _ in range(cfg.n_dec_layers)
        ]
        return enc + dec
    return [
        flops_per_token_layer(cfg, seq_len, layer=l)
        for l in range(cfg.n_layers)
    ]


def _span_flops(cfg: ModelConfig, seq_len: int, table, lo: int, hi: int):
    """Per-request FLOPs of the partition covering layers [lo, hi)."""
    vals = table[lo:hi]
    if cfg.family != "encdec" and len(set(vals)) == 1:
        # Uniform stacks multiply — bitwise-identical to the historical
        # seq_len * per_layer * count arithmetic the P=2 pin holds to.
        return seq_len * vals[0] * len(vals)
    return seq_len * sum(vals)


def _cut_bytes(cfg: ModelConfig, seq_len: int, cut: int) -> float:
    """Bytes/request shipped across the boundary after layer `cut`."""
    act = seq_len * cfg.d_model * 2.0  # bf16 hidden states
    if cfg.family == "encdec" and cut > cfg.n_layers:
        # Inside the decoder: the encoder memory travels with the decoder
        # hidden states (downstream cross-attention reads it).
        return 2.0 * act
    return act


@dataclasses.dataclass(frozen=True)
class ArchProfile:
    """One candidate partitioning of one architecture.

    splits  : P-1 strictly-ascending interior cut layers (empty for P=1)
    L_bytes : K = P+1 per-request stage packet sizes
    w_flops : P per-request partition workloads

    The legacy 2-partition field names (L0/L1/L2_bytes, w1/w2_flops,
    split_layer) remain available as properties; at P=2 they are exactly
    the pre-split-search profile.
    """

    arch: str
    splits: tuple[int, ...]
    n_layers_total: int
    seq_len: int
    L_bytes: tuple[float, ...]
    w_flops: tuple[float, ...]

    @property
    def n_parts(self) -> int:
        return len(self.w_flops)

    @property
    def split_layer(self) -> int:
        return self.splits[0] if self.splits else self.n_layers_total

    @property
    def L0_bytes(self) -> float:
        return self.L_bytes[0]

    @property
    def L1_bytes(self) -> float:
        return self.L_bytes[1]

    @property
    def L2_bytes(self) -> float:
        return self.L_bytes[-1]

    @property
    def w1_flops(self) -> float:
        return self.w_flops[0]

    @property
    def w2_flops(self) -> float:
        return self.w_flops[-1]

    @property
    def L(self) -> tuple[float, ...]:
        return self.L_bytes

    @property
    def w(self) -> tuple[float, ...]:
        return self.w_flops

    def compression_ratio(self) -> float:
        """L1/L0 — how much the first partition compresses the stream."""
        if self.L0_bytes <= 0.0:
            raise ValueError(
                f"ArchProfile {self.arch!r}: compression_ratio is undefined "
                f"for L0_bytes={self.L0_bytes!r} <= 0 (empty input stage)"
            )
        return self.L1_bytes / self.L0_bytes


def profile_arch(
    cfg: ModelConfig,
    seq_len: int = 1024,
    n_out_tokens: int = 32,
    split: int | None = None,
    splits: tuple[int, ...] | None = None,
) -> ArchProfile:
    """Derive the paper's (L_{a,k}, w^{a,p}) from an architecture config.

    split  : single interior cut layer (P=2 shorthand); valid range is
             1..total_layers-1 for every family — including encdec, whose
             layers are indexed encoder-first (the historical code silently
             ignored split= there).
    splits : arbitrary strictly-ascending cut set; () profiles the
             unsplit P=1 chain. Mutually exclusive with split=.
    Defaults: decoder-only families cut at max(1, n_layers // 4); encdec
    cuts at the encoder/decoder boundary.
    """
    if split is not None and splits is not None:
        raise ValueError(
            "profile_arch: pass split= (single cut) or splits= (cut set), "
            "not both"
        )
    total = total_profile_layers(cfg)
    if splits is None:
        if split is not None:
            splits = (int(split),)
        elif cfg.family == "encdec":
            splits = (cfg.n_layers,)  # encoder / decoder boundary
        else:
            splits = (max(1, cfg.n_layers // 4),)
    cuts = tuple(int(s) for s in splits)
    bad = [s for s in cuts if not 1 <= s <= total - 1]
    if bad:
        boundary = (
            f"; the encoder/decoder boundary is layer {cfg.n_layers}"
            if cfg.family == "encdec"
            else ""
        )
        raise ValueError(
            f"profile_arch: cut layer(s) {bad} out of range for "
            f"{cfg.name!r}: valid interior cut layers are 1..{total - 1} "
            f"({total} layers total{boundary})"
        )
    if any(b <= a for a, b in zip(cuts, cuts[1:])):
        raise ValueError(
            f"profile_arch: split set {cuts} must be strictly ascending "
            "(each partition needs at least one layer)"
        )

    table = layer_flops_table(cfg, seq_len)
    bounds = (0,) + cuts + (total,)
    w = [
        _span_flops(cfg, seq_len, table, lo, hi)
        for lo, hi in zip(bounds, bounds[1:])
    ]
    if cfg.family == "encdec":
        w[-1] += 2.0 * n_out_tokens * cfg.d_model * cfg.vocab  # unembed
    else:
        w[-1] += 2.0 * seq_len * cfg.d_model * cfg.vocab  # unembed
    L = [seq_len * _bytes_per_token_input(cfg)]
    L += [_cut_bytes(cfg, seq_len, s) for s in cuts]
    L.append(n_out_tokens * 4.0)
    return ArchProfile(
        cfg.name, cuts, total, seq_len, tuple(L), tuple(w)
    )


def enumerate_candidates(
    cfg: ModelConfig,
    *,
    seq_len: int = 1024,
    n_out_tokens: int = 32,
    parts: tuple[int, ...] = (1, 2, 3, 4),
    max_per_p: int = 16,
) -> tuple[list[ArchProfile], int]:
    """All candidate split profiles for one architecture.

    For each P in `parts`, enumerates cut sets (P-1 interior boundaries out
    of total_layers-1); when a depth has more than `max_per_p` cut sets,
    a deterministic evenly-spaced subsample of the lexicographically-sorted
    combination list is kept (the endpoints — earliest and latest cut sets
    — always survive). Returns (profiles, n_possible): `n_possible` counts
    the full space before subsampling, so callers can report what was
    dropped instead of silently capping (DESIGN.md section 17).
    """
    if max_per_p < 1:
        raise ValueError(f"max_per_p must be >= 1, got {max_per_p}")
    total = total_profile_layers(cfg)
    profiles: list[ArchProfile] = []
    n_possible = 0
    for p in parts:
        if p < 1:
            raise ValueError(f"partition counts must be >= 1, got {p}")
        if p - 1 > total - 1:
            continue  # more cuts than interior boundaries
        combos = list(itertools.combinations(range(1, total), p - 1))
        n_possible += len(combos)
        if len(combos) > max_per_p:
            idx = np.unique(
                np.linspace(0, len(combos) - 1, max_per_p).round().astype(int)
            )
            combos = [combos[i] for i in idx]
        profiles += [
            profile_arch(
                cfg, seq_len=seq_len, n_out_tokens=n_out_tokens, splits=c
            )
            for c in combos
        ]
    return profiles, n_possible


def apps_from_profiles(
    profiles: list[ArchProfile],
    src: np.ndarray,
    dst: np.ndarray,
    lam: np.ndarray,
    *,
    byte_scale: float = 1.0,
    flop_scale: float = 1.0,
) -> Apps:
    """Build the optimizer's Apps from per-request profiles.

    Profiles of mixed partition depth are padded to the deepest profile's
    stage envelope with inert phantom stages (L = 0, w = 0, `Apps.parts`
    carries each app's true depth — DESIGN.md section 13), so one Apps can
    mix a P=1 chain with P=4 candidates.

    byte_scale converts bytes -> the unit of link capacities mu (e.g. 1e-6
    for links in MB/s); flop_scale converts FLOPs -> the unit of node service
    rates nu (e.g. 1e-9 for GFLOP/s nodes)."""
    n = len(profiles)
    if n == 0:
        raise ValueError("apps_from_profiles: empty profile list")
    src = np.asarray(src)
    dst = np.asarray(dst)
    lam = np.asarray(lam)
    if not (len(src) == len(dst) == len(lam) == n):
        raise ValueError(
            f"apps_from_profiles: length mismatch — {n} profiles but "
            f"src has {len(src)}, dst has {len(dst)}, lam has {len(lam)} "
            "entries"
        )
    for name, s in (("byte_scale", byte_scale), ("flop_scale", flop_scale)):
        if not np.isfinite(s) or s <= 0:
            raise ValueError(
                f"apps_from_profiles: {name} must be finite and positive, "
                f"got {s!r}"
            )
    n_parts = max(p.n_parts for p in profiles)
    L = np.zeros((n, n_parts + 1), np.float64)
    w = np.zeros((n, n_parts), np.float64)
    parts = np.zeros(n, np.int32)
    for i, p in enumerate(profiles):
        k = p.n_parts
        L[i, :k] = p.L_bytes[:-1]
        L[i, k] = p.L_bytes[-1]  # final stage sits at index `parts`
        w[i, :k] = p.w_flops
        parts[i] = k
    L *= byte_scale
    w *= flop_scale
    return Apps(
        src=jnp.asarray(np.asarray(src, np.int32)),
        dst=jnp.asarray(np.asarray(dst, np.int32)),
        lam=jnp.asarray(np.asarray(lam, np.float32)),
        L=jnp.asarray(L.astype(np.float32)),
        w=jnp.asarray(w.astype(np.float32)),
        parts=jnp.asarray(parts),
    )
