"""Bridge between the model zoo and the paper's optimizer.

Every architecture enters problem (7) as an `ArchProfile`: the three stage
packet sizes (L0 raw input, L1 split-point activation, L2 final output) and
the two per-request partition workloads (w1, w2 in FLOPs). The optimizer
core itself is stage-generic (any P — DESIGN.md section 13); this bridge
currently emits the paper's 2-partition profiles, with multi-split-point
chains per architecture a ROADMAP item. This is the
"directly measured from a test run" quantity of the paper's Eq. (6) — here
derived analytically from the architecture config (and cross-checked against
the models in tests).

Split-point conventions (DESIGN.md section 4):
  * decoder-only families: layer boundary k (default L/4 — the paper's
    "first partition acts as a local compression stage");
  * encoder-decoder: the encoder/decoder boundary (the natural 2-partition
    split); L1 is the encoder memory.
The technique applies to ALL 10 assigned architectures; per-family nuances
are only in how the profile is computed (MoE: active FLOPs; SSM/hybrid:
stateless requests ship only layer activations).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..core.structs import Apps


def _bytes_per_token_input(cfg: ModelConfig) -> float:
    if cfg.frontend != "none":
        return cfg.frontend_dim * 2.0  # bf16 patch/frame embeddings
    return 4.0  # int32 token ids


def flops_per_token_layer(cfg: ModelConfig, ctx_len: int, decoder: bool = False) -> float:
    """Forward FLOPs per token for one layer (2 x MACs convention)."""
    d = cfg.d_model
    f = 0.0
    if cfg.attends:
        h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        f += 2.0 * (d * h * hd + 2 * d * kv * hd + h * hd * d)  # qkvo proj
        eff_ctx = min(ctx_len, cfg.sliding_window or ctx_len)
        f += 4.0 * eff_ctx * h * hd  # scores + values
        if decoder:  # cross attention
            f += 2.0 * (d * h * hd + h * hd * d) + 4.0 * ctx_len * h * hd
    if cfg.family in ("dense", "hybrid", "encdec"):
        mult = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
        f += 2.0 * mult * d * cfg.d_ff
    if cfg.family == "moe":
        mult = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
        f += 2.0 * cfg.top_k * mult * d * cfg.moe_d_ff  # active experts only
        if cfg.shared_d_ff:
            f += 2.0 * mult * d * cfg.shared_d_ff
        f += 2.0 * d * cfg.n_experts  # router
    if cfg.family in ("ssm", "hybrid"):
        din, n, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
        f += 2.0 * d * (2 * din + 2 * n + nh)  # in_proj
        f += 2.0 * cfg.conv_width * (din + 2 * n)  # conv
        f += 2.0 * 2.0 * din * n  # state update + readout (per token)
        f += 2.0 * din * d  # out_proj
    return f


@dataclasses.dataclass(frozen=True)
class ArchProfile:
    arch: str
    split_layer: int
    n_layers_total: int
    seq_len: int
    L0_bytes: float  # raw input per request
    L1_bytes: float  # split-point activation per request
    L2_bytes: float  # final output per request
    w1_flops: float  # partition-1 compute per request
    w2_flops: float  # partition-2 compute per request

    @property
    def L(self) -> tuple[float, float, float]:
        return (self.L0_bytes, self.L1_bytes, self.L2_bytes)

    @property
    def w(self) -> tuple[float, float]:
        return (self.w1_flops, self.w2_flops)

    def compression_ratio(self) -> float:
        """L1/L0 — how much the first partition compresses the stream."""
        return self.L1_bytes / max(self.L0_bytes, 1.0)


def profile_arch(
    cfg: ModelConfig,
    seq_len: int = 1024,
    n_out_tokens: int = 32,
    split: int | None = None,
) -> ArchProfile:
    """Derive the paper's (L_{a,k}, w^{a,p}) from an architecture config."""
    if cfg.family == "encdec":
        split_layer = cfg.n_layers  # encoder / decoder boundary
        l0 = seq_len * _bytes_per_token_input(cfg)
        l1 = seq_len * cfg.d_model * 2.0  # encoder memory, bf16
        l2 = n_out_tokens * 4.0
        w1 = seq_len * sum(
            flops_per_token_layer(cfg, seq_len) for _ in range(cfg.n_layers)
        )
        w2 = seq_len * sum(
            flops_per_token_layer(cfg, seq_len, decoder=True)
            for _ in range(cfg.n_dec_layers)
        )
        w1 += 2.0 * seq_len * cfg.vocab * 0  # encoder has no unembed
        w2 += 2.0 * n_out_tokens * cfg.d_model * cfg.vocab  # unembed
        return ArchProfile(
            cfg.name, split_layer, cfg.n_layers + cfg.n_dec_layers, seq_len,
            l0, l1, l2, w1, w2,
        )

    n_l = cfg.n_layers
    split_layer = split if split is not None else max(1, n_l // 4)
    per_layer = flops_per_token_layer(cfg, seq_len)
    l0 = seq_len * _bytes_per_token_input(cfg)
    l1 = seq_len * cfg.d_model * 2.0
    l2 = n_out_tokens * 4.0
    w_embed = 0.0  # lookup is negligible
    w_unembed = 2.0 * seq_len * cfg.d_model * cfg.vocab
    w1 = seq_len * per_layer * split_layer + w_embed
    w2 = seq_len * per_layer * (n_l - split_layer) + w_unembed
    return ArchProfile(cfg.name, split_layer, n_l, seq_len, l0, l1, l2, w1, w2)


def apps_from_profiles(
    profiles: list[ArchProfile],
    src: np.ndarray,
    dst: np.ndarray,
    lam: np.ndarray,
    *,
    byte_scale: float = 1.0,
    flop_scale: float = 1.0,
) -> Apps:
    """Build the optimizer's Apps from per-request profiles.

    byte_scale converts bytes -> the unit of link capacities mu (e.g. 1e-6
    for links in MB/s); flop_scale converts FLOPs -> the unit of node service
    rates nu (e.g. 1e-9 for GFLOP/s nodes)."""
    n = len(profiles)
    assert len(src) == len(dst) == len(lam) == n
    L = np.array([[p.L0_bytes, p.L1_bytes, p.L2_bytes] for p in profiles]) * byte_scale
    w = np.array([[p.w1_flops, p.w2_flops] for p in profiles]) * flop_scale
    return Apps(
        src=jnp.asarray(np.asarray(src, np.int32)),
        dst=jnp.asarray(np.asarray(dst, np.int32)),
        lam=jnp.asarray(np.asarray(lam, np.float32)),
        L=jnp.asarray(L.astype(np.float32)),
        w=jnp.asarray(w.astype(np.float32)),
    )
