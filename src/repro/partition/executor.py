"""Execute a 2-partition split of an actual model: the data plane of Fig. 1.

Partition 1 = embedding + layers [0, k); partition 2 = layers [k, L) + final
norm + unembed. For encoder-decoder models the split is encoder / decoder.
The intermediate activation (the paper's stage-1 traffic, size L1) is exactly
what `run_partition(..., part=1)` returns and `part=2` consumes — the
edge_serving example ships it along the route chosen by repro.core.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import layers as L
from ..models import model as M
from ..models.config import ModelConfig


def split_params(cfg: ModelConfig, params, k: int):
    """Split a stacked-layer param tree at layer boundary k."""
    if cfg.family == "encdec":
        p1 = {
            "embed": params["embed"],
            "blocks": params["blocks"],
            "enc_final_norm": params["enc_final_norm"],
        }
        p2 = {
            "embed": params["embed"],
            "dec_blocks": params["dec_blocks"],
            "final_norm": params["final_norm"],
        }
        return p1, p2
    blocks1 = jax.tree.map(lambda a: a[:k], params["blocks"])
    blocks2 = jax.tree.map(lambda a: a[k:], params["blocks"])
    p1 = {"embed": params["embed"], "blocks": blocks1}
    p2 = {
        "embed": params["embed"],
        "blocks": blocks2,
        "final_norm": params["final_norm"],
    }
    return p1, p2


def run_partition(cfg: ModelConfig, part_params, batch_or_act, *, part: int, k: int = 0):
    """Run one partition. part=1 consumes the raw batch and returns the
    stage-1 activation; part=2 consumes that activation and returns logits."""
    kind = M._block_kind(cfg)
    if cfg.family == "encdec":
        if part == 1:
            return M.encode(cfg, part_params, batch_or_act)
        memory = batch_or_act["memory"]
        y = L.embed_tokens(part_params["embed"], batch_or_act["dec_tokens"], cfg)
        y, _ = M._stack_full(part_params["dec_blocks"], y, cfg, "dec", memory=memory)
        y = L.rmsnorm(y, part_params["final_norm"], cfg.norm_eps)
        return L.unembed(part_params["embed"], y, cfg)
    if part == 1:
        x = M._embed_input(cfg, part_params, batch_or_act)
        x, _ = M._stack_full(part_params["blocks"], x, cfg, kind, causal=True)
        return x  # the stage-1 activation (bytes = S * d * 2 = profile L1)
    x = batch_or_act
    x, _ = M._stack_full(part_params["blocks"], x, cfg, kind, causal=True)
    x = L.rmsnorm(x, part_params["final_norm"], cfg.norm_eps)
    return L.unembed(part_params["embed"], x, cfg)
