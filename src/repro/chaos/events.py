"""Seeded fault-trace generation and application (DESIGN.md section 15).

A fault trace is a deterministic, replayable sequence of timestamped events
over a fleet of `Problem` instances:

  node_down / node_up         a node dies and (later) recovers
  link_degrade / link_restore an existing edge's service rate mu is scaled
                              down by a factor in (0, 1), both directions
  flash_crowd / flash_end     every app of one instance has its arrival
                              rate lam scaled up (a rate burst)

The load-bearing design decision: a dead node is encoded EXACTLY like a
padded node — adj rows/columns zeroed, mu rows/columns set to the BIG
sentinel, nu set to NU_PAD (fleet/pad.py). The whole §9/§13 inertness
contract therefore covers dead nodes for free: zero incident traffic means
zero D/C contribution, the prohibitive marginal compute cost 1/NU_PAD and
the BIG link distances mean neither the structured init nor any placement
sweep ever selects one, and `(I - Phi^T)` keeps its Neumann solvability on
the live block. "Failure" is not a new solver concept, it is padding that
happens at runtime.

Perturbation never changes shapes or static metadata: V/A/K and `hop_bound`
are untouched, so every epoch of a control loop re-enters the SAME compiled
engine program. Killing a node can grow the live subgraph's diameter past
the recorded `hop_bound`, but the batched-XLA Neumann path floors its hop
cap at the nilpotency bound V + 1 (`kernels.neumann.ops.effective_hops`),
so propagation stays exact without a recompile. (The fixed-loop Pallas
kernel does not have that floor — the chaos controller runs the default
XLA path.)

Event schedules are a pure function of (problems, n_epochs, seed): node
kills are drawn only from nodes that are (a) not a src/dst endpoint of any
live app and (b) whose removal keeps the surviving subgraph of live nodes
connected given everything already down — so a generated trace never
creates an unservable epoch by construction, and the controller's
feasibility guarantee is meaningfully about the solver, not the generator.
"""
from __future__ import annotations

import dataclasses
import json
from collections import defaultdict

import jax.numpy as jnp
import numpy as np

from ..core.structs import BIG, Network, Problem
from ..fleet.pad import NU_PAD

NODE_DOWN = "node_down"
NODE_UP = "node_up"
LINK_DEGRADE = "link_degrade"
LINK_RESTORE = "link_restore"
FLASH_CROWD = "flash_crowd"
FLASH_END = "flash_end"

EVENT_KINDS = (
    NODE_DOWN, NODE_UP, LINK_DEGRADE, LINK_RESTORE, FLASH_CROWD, FLASH_END,
)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One timestamped fault or recovery on one instance.

    epoch    : control epoch at which the event fires
    kind     : one of EVENT_KINDS
    instance : fleet index the event applies to
    node     : dead/recovering node (node events; -1 otherwise)
    edge     : undirected (u, v) with u < v (link events; () otherwise)
    scale    : mu multiplier in (0, 1) for link_degrade, lam multiplier
               > 1 for flash_crowd; 1.0 for recoveries
    """

    epoch: int
    kind: str
    instance: int
    node: int = -1
    edge: tuple = ()
    scale: float = 1.0

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {self.kind!r}")

    def to_dict(self) -> dict:
        return {
            "epoch": self.epoch, "kind": self.kind,
            "instance": self.instance, "node": self.node,
            "edge": list(self.edge), "scale": self.scale,
        }


@dataclasses.dataclass(frozen=True)
class InstanceHealth:
    """Immutable cumulative fault state of one instance.

    Value-equality is the controller's freeze signal: `health == previous`
    means nothing changed since the instance was last solved, so its engine
    lane can start frozen (`warm_active=False`).

    down       : frozenset of dead node indices
    link_scale : sorted tuple of ((u, v), scale) for degraded edges, u < v
    rate_scale : lam multiplier (1.0 = no flash crowd)
    """

    down: frozenset = frozenset()
    link_scale: tuple = ()
    rate_scale: float = 1.0

    @property
    def pristine(self) -> bool:
        return (
            not self.down and not self.link_scale and self.rate_scale == 1.0
        )

    def apply_event(self, ev: FaultEvent) -> "InstanceHealth":
        if ev.kind == NODE_DOWN:
            return dataclasses.replace(self, down=self.down | {ev.node})
        if ev.kind == NODE_UP:
            return dataclasses.replace(self, down=self.down - {ev.node})
        if ev.kind == LINK_DEGRADE:
            scales = dict(self.link_scale)
            scales[tuple(ev.edge)] = ev.scale
            return dataclasses.replace(
                self, link_scale=tuple(sorted(scales.items()))
            )
        if ev.kind == LINK_RESTORE:
            scales = dict(self.link_scale)
            scales.pop(tuple(ev.edge), None)
            return dataclasses.replace(
                self, link_scale=tuple(sorted(scales.items()))
            )
        if ev.kind == FLASH_CROWD:
            return dataclasses.replace(self, rate_scale=ev.scale)
        if ev.kind == FLASH_END:
            return dataclasses.replace(self, rate_scale=1.0)
        raise ValueError(f"unknown event kind {ev.kind!r}")


@dataclasses.dataclass(frozen=True)
class FaultTrace:
    """A replayable event schedule over `n_epochs` x `n_instances`."""

    events: tuple
    n_epochs: int
    n_instances: int

    def counts(self) -> dict:
        out = {k: 0 for k in EVENT_KINDS}
        for ev in self.events:
            out[ev.kind] += 1
        return out

    def timeline(self):
        """Yield (epoch, fired_events, healths) for every epoch in order.

        `healths` is the post-event `InstanceHealth` list — the state the
        controller should perturb and solve against for that epoch."""
        by_epoch = defaultdict(list)
        for ev in self.events:
            by_epoch[ev.epoch].append(ev)
        healths = [InstanceHealth() for _ in range(self.n_instances)]
        for epoch in range(self.n_epochs):
            fired = by_epoch.get(epoch, [])
            for ev in fired:
                healths[ev.instance] = healths[ev.instance].apply_event(ev)
            yield epoch, fired, list(healths)

    def to_json(self) -> str:
        return json.dumps(
            {
                "n_epochs": self.n_epochs,
                "n_instances": self.n_instances,
                "counts": self.counts(),
                "events": [ev.to_dict() for ev in self.events],
            },
            indent=1,
        )

    def save(self, path) -> None:
        with open(path, "w") as fh:
            fh.write(self.to_json() + "\n")


def _undirected_edges(adj: np.ndarray) -> list:
    both = (adj > 0) | (adj.T > 0)
    return [tuple(map(int, e)) for e in np.argwhere(np.triu(both, 1))]


def _connected_without(adj: np.ndarray, down) -> bool:
    """True iff the live (non-`down`) nodes form one connected component."""
    n = adj.shape[0]
    live = np.ones(n, bool)
    live[list(down)] = False
    idx = np.flatnonzero(live)
    if idx.size == 0:
        return False
    a = ((adj > 0) | (adj.T > 0)).copy()
    a[~live] = False
    a[:, ~live] = False
    seen = np.zeros(n, bool)
    stack = [int(idx[0])]
    seen[idx[0]] = True
    while stack:
        u = stack.pop()
        for v in np.flatnonzero(a[u]):
            if not seen[v]:
                seen[v] = True
                stack.append(int(v))
    return bool(seen[live].all())


def _protected_nodes(problem: Problem) -> set:
    """src/dst endpoints of live apps: killing one makes traffic
    uninjectable/unabsorbable — never scheduled (module doc)."""
    lam = np.asarray(problem.apps.lam)
    src = np.asarray(problem.apps.src)
    dst = np.asarray(problem.apps.dst)
    live = lam > 0
    return set(map(int, src[live])) | set(map(int, dst[live]))


def generate_trace(
    problems,
    n_epochs: int,
    *,
    seed: int = 0,
    node_failures: int = 5,
    link_degradations: int = 3,
    flash_crowds: int = 1,
    min_duration: int = 2,
    max_duration: int = 6,
    degrade_range: tuple = (0.2, 0.6),
    crowd_range: tuple = (1.5, 3.0),
) -> FaultTrace:
    """Schedule a deterministic fault trace over a fleet.

    Exactly `node_failures` node kills, `link_degradations` link
    degradations and `flash_crowds` rate bursts fire at rng-chosen epochs
    in [1, n_epochs - min_duration), each with an rng-chosen duration in
    [min_duration, max_duration] epochs (recovery events past the horizon
    are dropped: the fault simply persists to the end). The whole trace is
    a pure function of (problems, n_epochs, seed).

    Raises if a requested fault cannot be scheduled on ANY instance at its
    chosen epoch (e.g. every killable node is already down) — shrink the
    counts or grow the fleet rather than silently under-delivering chaos.
    """
    n_inst = len(problems)
    if n_inst == 0:
        raise ValueError("generate_trace: empty fleet")
    if n_epochs < min_duration + 2:
        raise ValueError(
            f"generate_trace: n_epochs={n_epochs} too short for faults of "
            f"min_duration={min_duration} (need >= {min_duration + 2})"
        )
    rng = np.random.RandomState(seed)
    adjs = [np.asarray(p.net.adj) for p in problems]
    protected = [_protected_nodes(p) for p in problems]

    hi = n_epochs - min_duration
    plan = defaultdict(list)
    for kind, count in (
        (NODE_DOWN, node_failures),
        (LINK_DEGRADE, link_degradations),
        (FLASH_CROWD, flash_crowds),
    ):
        for _ in range(count):
            plan[int(rng.randint(1, hi))].append(kind)

    recoveries = defaultdict(list)
    events = []
    healths = [InstanceHealth() for _ in range(n_inst)]

    def schedule(epoch, kind):
        # Walk instances in rng order until one can host this fault.
        for inst in map(int, rng.permutation(n_inst)):
            h = healths[inst]
            if kind == NODE_DOWN:
                cand = [
                    v
                    for v in range(adjs[inst].shape[0])
                    if v not in protected[inst]
                    and v not in h.down
                    and _connected_without(adjs[inst], h.down | {v})
                ]
                if not cand:
                    continue
                node = int(cand[rng.randint(len(cand))])
                fire = FaultEvent(epoch, NODE_DOWN, inst, node=node)
                recover = dataclasses.replace(fire, kind=NODE_UP)
            elif kind == LINK_DEGRADE:
                degraded = {e for e, _ in h.link_scale}
                cand = [
                    e
                    for e in _undirected_edges(adjs[inst])
                    if e not in degraded
                    and e[0] not in h.down
                    and e[1] not in h.down
                ]
                if not cand:
                    continue
                edge = cand[rng.randint(len(cand))]
                fire = FaultEvent(
                    epoch, LINK_DEGRADE, inst, edge=edge,
                    scale=float(rng.uniform(*degrade_range)),
                )
                recover = dataclasses.replace(
                    fire, kind=LINK_RESTORE, scale=1.0
                )
            else:  # FLASH_CROWD
                if h.rate_scale != 1.0:
                    continue
                fire = FaultEvent(
                    epoch, FLASH_CROWD, inst,
                    scale=float(rng.uniform(*crowd_range)),
                )
                recover = dataclasses.replace(fire, kind=FLASH_END, scale=1.0)
            end = epoch + int(rng.randint(min_duration, max_duration + 1))
            if end < n_epochs:
                recoveries[end].append(recover)
            return fire
        raise ValueError(
            f"generate_trace: no instance can host a {kind} at epoch "
            f"{epoch} (seed={seed}); reduce fault counts or durations"
        )

    for epoch in range(n_epochs):
        for recover in recoveries.pop(epoch, []):
            recover = dataclasses.replace(recover, epoch=epoch)
            healths[recover.instance] = healths[recover.instance].apply_event(
                recover
            )
            events.append(recover)
        for kind in plan.pop(epoch, []):
            fire = schedule(epoch, kind)
            healths[fire.instance] = healths[fire.instance].apply_event(fire)
            events.append(fire)
    return FaultTrace(tuple(events), n_epochs, n_inst)


def apply_health(problem: Problem, health: InstanceHealth):
    """Apply one instance's fault state to its base problem.

    Returns (perturbed_problem, live_mask) where live_mask is a [V] float32
    validity mask (1.0 = live). Dead nodes get EXACTLY the pad encoding —
    adj rows/cols 0, mu rows/cols BIG, nu = NU_PAD (module doc) — link
    degradation scales mu on both directions of existing edges, and a flash
    crowd scales every app's lam. Shapes and `hop_bound` are unchanged, so
    the perturbed problem re-enters the same compiled engine program.
    """
    v = problem.net.n_nodes
    live = np.ones(v, np.float32)
    if health.pristine:
        return problem, live
    adj = np.array(problem.net.adj, dtype=np.float32)
    mu = np.array(problem.net.mu, dtype=np.float32)
    nu = np.array(problem.net.nu, dtype=np.float32)
    for (u, w), scale in health.link_scale:
        for a, b in ((u, w), (w, u)):
            if adj[a, b] > 0:
                mu[a, b] = mu[a, b] * scale
    for d in sorted(health.down):
        live[d] = 0.0
        adj[d, :] = 0.0
        adj[:, d] = 0.0
        mu[d, :] = BIG
        mu[:, d] = BIG
        nu[d] = NU_PAD
    apps = problem.apps
    if health.rate_scale != 1.0:
        apps = dataclasses.replace(
            apps,
            lam=jnp.asarray(
                np.asarray(apps.lam) * np.float32(health.rate_scale)
            ),
        )
    net = Network(
        adj=jnp.asarray(adj), mu=jnp.asarray(mu), nu=jnp.asarray(nu)
    )
    return dataclasses.replace(problem, net=net, apps=apps), live
