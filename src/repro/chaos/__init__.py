"""Fault injection for the control plane: seeded chaos over fleet problems.

events.py  — fault-trace generation (node churn, link degradation, flash
             crowds) and application to `Problem`s via the pad encoding
repair.py  — fleet-level placement repair after faults (vmapped eviction)

See DESIGN.md section 15 and launch/control.py for the epoch controller
that drives trace -> repair -> warm re-solve.
"""
from .events import (  # noqa: F401
    EVENT_KINDS,
    FLASH_CROWD,
    FLASH_END,
    LINK_DEGRADE,
    LINK_RESTORE,
    NODE_DOWN,
    NODE_UP,
    FaultEvent,
    FaultTrace,
    InstanceHealth,
    apply_health,
    generate_trace,
)
from .repair import Apsp0Cache, refresh_apsp0, repair_fleet  # noqa: F401
