"""Fleet-level failure repair: one vmapped `repair_placement` per epoch.

Bridges the per-instance repair primitive (core/placement.py) to the fleet
envelope the controller actually carries: the perturbed problems are padded
and stacked exactly like `solve_fleet` would stack them, the per-instance
live masks are extended with zeros over the pad tail (padded nodes ARE dead
nodes under the shared encoding), and `repair_placement` runs vmapped over
the instance axis. The result is a stacked `State` ready to hand to
`solve_fleet(warm_start=...)`.

Identity contract (inherited from `repair_placement`): with every mask
all-ones the returned State is bitwise the input — the empty-fault-trace
stability the tests pin.

`Apsp0Cache` caches the zero-load APSP behind that repair across control
epochs: the metric `zero_load_dp` depends only on (adj, mu, cost), and most
chaos epochs perturb none of them (flash crowds scale lam; event-free
epochs change nothing), so the [B, V, V] (dist, nexthop) pair from the
previous epoch can be reused by value-equality of the inputs — the same
controller-owned-snapshot pattern as `core.structs.HopBoundCache`. A hit
injects the cached pair through `repair_placement(sp=...)`; because the
cold path and the cache both evaluate the identical `zero_load_dp` program
on bitwise-identical inputs, reuse is exact, and
`launch.control --verify-apsp0` asserts that bitwise parity per epoch in
the chaos CI job.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.placement import repair_placement, zero_load_dp
from ..core.structs import State
from ..fleet.pad import stack_problems
from ..kernels.minplus import apsp_with_nexthop


@dataclasses.dataclass
class Apsp0Cache:
    """Host-side snapshot of one fleet's zero-load APSP — NOT a pytree.

    key     : np leaves of the stacked (adj, mu, cost) the pair was computed
              from (plus the static cost kind) — the full input closure of
              `zero_load_dp`, compared by VALUE each refresh
    dist    : [B, V, V] fp32 zero-load all-pairs distances
    nexthop : [B, V, V] int32 SP next hops
    reused  : whether the last refresh was a hit (feeds `control.apsp0.*`)
    hits / misses : lifetime refresh counters
    """

    key: tuple
    dist: "np.ndarray"
    nexthop: "np.ndarray"
    reused: bool = False
    hits: int = 0
    misses: int = 0

    def sp(self):
        """The `(dist, nexthop)` pair in `repair_placement(sp=...)` form."""
        return jnp.asarray(self.dist), jnp.asarray(self.nexthop)


def _apsp0_key(stacked) -> tuple:
    """Value key over everything `zero_load_dp` reads (kind is static)."""
    leaves = jax.tree_util.tree_leaves(
        (stacked.net.adj, stacked.net.mu, stacked.cost)
    )
    return (stacked.cost.kind,) + tuple(np.asarray(x) for x in leaves)


def _apsp0_key_equal(a: tuple, b: tuple) -> bool:
    if len(a) != len(b) or a[0] != b[0]:
        return False
    return all(
        x.shape == y.shape and x.dtype == y.dtype and np.array_equal(x, y)
        for x, y in zip(a[1:], b[1:])
    )


def refresh_apsp0(
    problems,
    cache: Apsp0Cache | None,
    *,
    round_to: int = 1,
    envelope=None,
    hop_bound=None,
    n_parts=None,
    use_pallas: bool = False,
    interpret: bool = True,
) -> Apsp0Cache:
    """Return a cache valid for this epoch's problems (hit or recompute).

    The envelope arguments must match what `repair_fleet` / `solve_fleet`
    use so the [B, V, V] shapes line up. On a hit the returned cache is the
    old one with `reused=True`; on a miss the APSP is recomputed from
    scratch (one jitted vmapped `apsp_with_nexthop` over `zero_load_dp`) —
    the exact computation the sp=None path of `repair_placement` would fuse,
    on the exact stacked inputs, which is what makes reuse bitwise-exact.
    """
    stacked, _ = stack_problems(
        problems, round_to=round_to, envelope=envelope, hop_bound=hop_bound,
        n_parts=n_parts,
    )
    key = _apsp0_key(stacked)
    if cache is not None and _apsp0_key_equal(cache.key, key):
        cache.reused = True
        cache.hits += 1
        return cache
    dist, nexthop = jax.jit(
        jax.vmap(
            lambda p: apsp_with_nexthop(
                zero_load_dp(p), use_pallas=use_pallas, interpret=interpret
            )
        )
    )(stacked)
    return Apsp0Cache(
        key=key,
        dist=np.asarray(dist),
        nexthop=np.asarray(nexthop),
        reused=False,
        hits=cache.hits if cache is not None else 0,
        misses=(cache.misses if cache is not None else 0) + 1,
    )


def repair_fleet(
    problems,
    state: State,
    live_masks,
    *,
    round_to: int = 1,
    envelope=None,
    hop_bound=None,
    n_parts=None,
    use_pallas: bool = False,
    interpret: bool = True,
    apsp0: Apsp0Cache | None = None,
) -> State:
    """Evict every dead-hosted partition across a fleet in one vmapped call.

    problems   : the PERTURBED problems (dead nodes already pad-encoded)
    state      : stacked [B, ...] State over the fleet envelope — typically
                 `FleetResult.state` from the previous epoch's
                 `solve_fleet(..., keep_state=True)`
    live_masks : per-instance [V_i] masks from `chaos.apply_health`
                 (1.0 = live); shorter than the envelope is fine, the pad
                 tail is dead by definition
    round_to / envelope / hop_bound / n_parts : must match what the solves
                 use, so the stacked envelope — and therefore the state
                 shape — agrees epoch over epoch
    apsp0      : a `refresh_apsp0` cache covering THIS epoch's problems;
                 its (dist, nexthop) pair is injected into every lane's
                 `repair_placement` (bitwise-identical to the fused sp=None
                 path). None keeps the APSP inside the vmapped program.
    """
    stacked, _ = stack_problems(
        problems, round_to=round_to, envelope=envelope, hop_bound=hop_bound,
        n_parts=n_parts,
    )
    b = len(problems)
    v_env = int(stacked.net.adj.shape[-1])
    exp = (b,) + tuple(stacked.apps.w.shape[1:]) + (v_env,)
    if tuple(state.x.shape) != exp:
        raise ValueError(
            f"repair_fleet: state placement shape {tuple(state.x.shape)} "
            f"does not match the fleet envelope {exp} — the envelope "
            "drifted since the state was produced; re-solve cold"
        )
    masks = np.zeros((b, v_env), np.float32)
    for i, m in enumerate(live_masks):
        m = np.asarray(m, dtype=np.float32)
        masks[i, : m.size] = m
    fn = functools.partial(
        repair_placement, use_pallas=use_pallas, interpret=interpret
    )
    if apsp0 is None:
        return jax.vmap(fn)(stacked, state, jnp.asarray(masks))
    return jax.vmap(lambda p, s, m, sp: fn(p, s, m, sp=sp))(
        stacked, state, jnp.asarray(masks), apsp0.sp()
    )
