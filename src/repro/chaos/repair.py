"""Fleet-level failure repair: one vmapped `repair_placement` per epoch.

Bridges the per-instance repair primitive (core/placement.py) to the fleet
envelope the controller actually carries: the perturbed problems are padded
and stacked exactly like `solve_fleet` would stack them, the per-instance
live masks are extended with zeros over the pad tail (padded nodes ARE dead
nodes under the shared encoding), and `repair_placement` runs vmapped over
the instance axis. The result is a stacked `State` ready to hand to
`solve_fleet(warm_start=...)`.

Identity contract (inherited from `repair_placement`): with every mask
all-ones the returned State is bitwise the input — the empty-fault-trace
stability the tests pin.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.placement import repair_placement
from ..core.structs import State
from ..fleet.pad import stack_problems


def repair_fleet(
    problems,
    state: State,
    live_masks,
    *,
    round_to: int = 1,
    envelope=None,
    hop_bound=None,
    n_parts=None,
    use_pallas: bool = False,
    interpret: bool = True,
) -> State:
    """Evict every dead-hosted partition across a fleet in one vmapped call.

    problems   : the PERTURBED problems (dead nodes already pad-encoded)
    state      : stacked [B, ...] State over the fleet envelope — typically
                 `FleetResult.state` from the previous epoch's
                 `solve_fleet(..., keep_state=True)`
    live_masks : per-instance [V_i] masks from `chaos.apply_health`
                 (1.0 = live); shorter than the envelope is fine, the pad
                 tail is dead by definition
    round_to / envelope / hop_bound / n_parts : must match what the solves
                 use, so the stacked envelope — and therefore the state
                 shape — agrees epoch over epoch
    """
    stacked, _ = stack_problems(
        problems, round_to=round_to, envelope=envelope, hop_bound=hop_bound,
        n_parts=n_parts,
    )
    b = len(problems)
    v_env = int(stacked.net.adj.shape[-1])
    exp = (b,) + tuple(stacked.apps.w.shape[1:]) + (v_env,)
    if tuple(state.x.shape) != exp:
        raise ValueError(
            f"repair_fleet: state placement shape {tuple(state.x.shape)} "
            f"does not match the fleet envelope {exp} — the envelope "
            "drifted since the state was produced; re-solve cold"
        )
    masks = np.zeros((b, v_env), np.float32)
    for i, m in enumerate(live_masks):
        m = np.asarray(m, dtype=np.float32)
        masks[i, : m.size] = m
    fn = functools.partial(
        repair_placement, use_pallas=use_pallas, interpret=interpret
    )
    return jax.vmap(fn)(stacked, state, jnp.asarray(masks))
