"""int8 gradient compression with error feedback, for the cross-pod (DCN)
all-reduce. DCN bandwidth between pods is ~10x scarcer than ICI; quantizing
the pod-level gradient exchange 4x (fp32->int8) with error feedback keeps
convergence while shrinking the dominant multi-pod collective.

Used by launch/train.py when `--grad-compression int8` is set; the error
accumulator is part of the training state (and thus checkpointed)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array, error: jax.Array | None = None):
    """Per-tensor symmetric int8 quantization. Returns (q, scale, new_error)."""
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    recon = q.astype(jnp.float32) * scale
    new_error = xf - recon
    return q, scale, new_error


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale
