"""Minimal-dependency AdamW (+ global-norm clipping, cosine schedule).

Optimizer state is a pytree congruent with params (fp32 moments); sharding
rules map it with the same specs as the parameters (ZeRO-style: the moments
live wherever the FSDP/TP shards of the parameter live)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"mu": zeros, "nu": jax.tree.map(jnp.copy, zeros), "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_update(
    grads,
    state,
    params,
    lr,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    step = state["step"] + 1
    sf = step.astype(jnp.float32)
    bc1 = 1.0 - b1**sf
    bc2 = 1.0 - b2**sf

    def upd(p, g, mu, nu):
        gf = g.astype(jnp.float32)
        mu_n = b1 * mu + (1 - b1) * gf
        nu_n = b2 * nu + (1 - b2) * jnp.square(gf)
        mhat = mu_n / bc1
        vhat = nu_n / bc2
        pf = p.astype(jnp.float32)
        new_p = pf - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * pf)
        return new_p.astype(p.dtype), mu_n, nu_n

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}


def cosine_schedule(step, *, peak_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    sf = step.astype(jnp.float32)
    warm = peak_lr * sf / max(warmup, 1)
    prog = jnp.clip((sf - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)
