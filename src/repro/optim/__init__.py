from .adamw import adamw_init, adamw_update, clip_by_global_norm, cosine_schedule  # noqa: F401
from .compression import compress_int8, decompress_int8  # noqa: F401
