import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For every (architecture x input-shape) cell and each production mesh
(single-pod 16x16, multi-pod 2x16x16), lower + compile the appropriate step
function with ShapeDtypeStruct stand-ins, then record:

  * memory_analysis()  — proves the cell fits per-device HBM
  * cost_analysis()    — HLO FLOPs / bytes for the roofline terms
  * collective bytes   — parsed from the post-SPMD optimized HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
     collective-permute operand sizes)

Results are written as JSON under results/dryrun/ and consumed by
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--debug-mesh]
  python -m repro.launch.dryrun --all --both-meshes
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.distributed.act import activation_sharding
from repro.distributed.sharding import (
    ShardingRules,
    batch_pspec,
    batch_pspec_for,
    cache_pspecs,
    param_pspecs,
    to_named_shardings,
)
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.launch import hlo_cost
from repro.launch import steps as St
from repro.models.config import SHAPES, shape_applicable
from jax.sharding import NamedSharding, PartitionSpec as P

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\("
)


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the optimized HLO.

    Returns {op_kind: bytes} per device per step. (For all-reduce the wire
    cost is ~2x the operand under a ring schedule; the roofline applies
    per-kind factors — see benchmarks/roofline.py.)"""
    out: dict[str, float] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        out[kind] = out.get(kind, 0.0) + float(n * nbytes)
    return out


def _loop_trip_counts(hlo_text: str) -> list[int]:
    """Trip counts of while loops (scan-over-layers shows up here)."""
    return [int(x) for x in re.findall(r'known_trip_count[^0-9]*?(\d+)', hlo_text)][:20]


def build_cell(arch: str, shape_name: str, mesh, rules: ShardingRules,
               microbatches: int = 1, vocab_chunks: int = 0,
               cache_layout: str | None = None, moe_groups: int = -1,
               seq_shard: bool = False, remat: bool | None = None,
               no_fsdp: bool = False, quant_int8: bool = False):
    """Returns (step_fn, in_args_specs, in_shardings, donate) for a cell."""
    import dataclasses
    from repro.distributed.sharding import batch_axes_size

    cfg = get_config(arch)
    if vocab_chunks:
        cfg = dataclasses.replace(cfg, vocab_chunking=vocab_chunks)
    if moe_groups < 0:  # auto: one dispatch group per data shard
        moe_groups = batch_axes_size(mesh, rules)
    cfg = dataclasses.replace(cfg, moe_groups=moe_groups)
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if seq_shard:
        rules = dataclasses.replace(rules, seq_shard_residual=True)
    if no_fsdp:
        rules = dataclasses.replace(rules, fsdp=False)
    shape = SHAPES[shape_name]

    def _wrap(fn):
        def wrapped(*a):
            with __import__("repro.distributed.act", fromlist=["activation_sharding"]).activation_sharding(mesh, rules):
                return fn(*a)
        return wrapped

    if shape.kind == "train":
        step = _wrap(St.make_train_step(cfg, microbatches=microbatches))
        p_specs = St.param_specs(cfg)
        o_specs = St.opt_specs(cfg)
        b_specs = St.batch_specs(cfg, shape)
        p_sh = to_named_shardings(mesh, param_pspecs(cfg, p_specs, mesh, rules))
        o_sh = {
            "mu": to_named_shardings(mesh, param_pspecs(cfg, p_specs, mesh, rules)),
            "nu": to_named_shardings(mesh, param_pspecs(cfg, p_specs, mesh, rules)),
            "step": NamedSharding(mesh, P()),
        }
        b_sh = jax.tree.map(
            lambda _: NamedSharding(mesh, batch_pspec_for(mesh, rules, shape.global_batch)),
            b_specs,
        )
        return step, (p_specs, o_specs, b_specs), (p_sh, o_sh, b_sh), (0, 1)

    scfg = St.serve_config(cfg)
    if quant_int8:
        scfg = dataclasses.replace(scfg, quantize_int8=True)
    if cache_layout:
        rules = dataclasses.replace(rules, cache_layout=cache_layout)
    if shape.kind == "prefill":
        step = _wrap(St.make_prefill_step(scfg, shape.seq_len))
        p_specs = St.param_specs(scfg)
        b_specs = St.batch_specs(scfg, shape)
        p_sh = to_named_shardings(mesh, param_pspecs(scfg, p_specs, mesh, rules))
        b_sh = jax.tree.map(
            lambda _: NamedSharding(mesh, batch_pspec_for(mesh, rules, shape.global_batch)),
            b_specs,
        )
        return step, (p_specs, b_specs), (p_sh, b_sh), ()

    # decode
    step = _wrap(St.make_serve_step(scfg))
    p_specs = St.param_specs(scfg)
    c_specs = St.cache_specs(scfg, shape)
    t_spec = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
    p_sh = to_named_shardings(mesh, param_pspecs(scfg, p_specs, mesh, rules))
    c_sh = to_named_shardings(mesh, cache_pspecs(scfg, c_specs, mesh, rules))
    t_sh = NamedSharding(mesh, batch_pspec_for(mesh, rules, shape.global_batch))
    pos_sh = NamedSharding(mesh, P())
    return step, (p_specs, c_specs, t_spec, pos_spec), (p_sh, c_sh, t_sh, pos_sh), (1,)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             debug_mesh: bool = False, microbatches: int = 1,
             vocab_chunks: int = 0, cache_layout: str | None = None,
             moe_groups: int = -1, seq_shard: bool = False,
             remat: bool | None = None, no_fsdp: bool = False,
             quant_int8: bool = False,
             save: bool = True, tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "multi_pod": multi_pod, "tag": tag,
        "microbatches": microbatches, "vocab_chunks": vocab_chunks,
        "cache_layout": cache_layout,
    }
    if not ok:
        record.update(status="skipped", reason=reason)
        if save:
            _save(record)
        return record

    mesh = (
        make_debug_mesh(multi_pod=multi_pod)
        if debug_mesh
        else make_production_mesh(multi_pod=multi_pod)
    )
    rules = ShardingRules(pod_axis="pod" if multi_pod else None)
    t0 = time.time()
    try:
        step, arg_specs, in_sh, donate = build_cell(
            arch, shape_name, mesh, rules, microbatches=microbatches,
            vocab_chunks=vocab_chunks, cache_layout=cache_layout,
            moe_groups=moe_groups, seq_shard=seq_shard, remat=remat,
            no_fsdp=no_fsdp, quant_int8=quant_int8,
        )
        with mesh:
            jitted = jax.jit(step, in_shardings=in_sh, donate_argnums=donate)
            lowered = jitted.lower(*arg_specs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            # cost_analysis() returns a dict on recent JAX, a 1-element list
            # of dicts on older releases; accept both.
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):
                ca = ca[0] if ca else {}
            cost = dict(ca)
            cost = {k: float(v) for k, v in cost.items() if np.isscalar(v)}
            try:
                ma = compiled.memory_analysis()
                mem = {
                    "argument_bytes": float(getattr(ma, "argument_size_in_bytes", 0)),
                    "output_bytes": float(getattr(ma, "output_size_in_bytes", 0)),
                    "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)),
                    "alias_bytes": float(getattr(ma, "alias_size_in_bytes", 0)),
                    "generated_code_bytes": float(
                        getattr(ma, "generated_code_size_in_bytes", 0)
                    ),
                }
            except Exception as e:  # pragma: no cover
                mem = {"error": str(e)}
            hlo = compiled.as_text()
            analysis = hlo_cost.analyze(hlo)
            coll = analysis["collectives"]
            trips = _loop_trip_counts(hlo)
        # Per-device argument bytes (params+opt+caches) from specs+shardings.
        arg_bytes = _sharded_arg_bytes(arg_specs, in_sh, mesh)
        record.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            cost_analysis=cost,
            hlo_flops=analysis["flops"],
            hlo_bytes_accessed=analysis["bytes"],
            hlo_warnings=analysis["warnings"],
            memory=mem,
            collective_bytes=coll,
            loop_trip_counts=trips,
            per_device_argument_gib=round(arg_bytes / 2**30, 3),
            n_devices=int(np.prod(list(mesh.shape.values()))),
            hlo_bytes=len(hlo),
        )
    except Exception as e:
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      trace=traceback.format_exc()[-2000:])
    if save:
        _save(record)
    return record


def _sharded_arg_bytes(arg_specs, in_sh, mesh) -> float:
    """Per-device bytes of all inputs under their shardings."""
    total = 0.0
    flat_specs = jax.tree_util.tree_leaves(arg_specs)
    flat_sh = jax.tree_util.tree_leaves(
        in_sh, is_leaf=lambda x: isinstance(x, NamedSharding)
    )
    for spec, sh in zip(flat_specs, flat_sh):
        if not hasattr(spec, "shape"):
            continue
        n = int(np.prod(spec.shape)) if spec.shape else 1
        nbytes = n * spec.dtype.itemsize
        shards = 1
        if isinstance(sh, NamedSharding):
            for axis in jax.tree_util.tree_leaves(tuple(sh.spec)):
                if axis is not None:
                    shards *= mesh.shape[axis]
        total += nbytes / shards
    return total


def _save(record: dict):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    tag = f"-{record['tag']}" if record.get("tag") else ""
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}{tag}.json"
    (RESULTS_DIR / name).write_text(json.dumps(record, indent=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCHS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--debug-mesh", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--vocab-chunks", type=int, default=0)
    ap.add_argument("--cache-layout", choices=["seq", "heads"], default=None)
    ap.add_argument("--moe-groups", type=int, default=-1)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--quant-int8", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    elif args.arch and not args.shape:
        cells = [(args.arch, shape) for shape in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    n_ok = n_skip = n_err = 0
    for arch, shape in cells:
        for mp in meshes:
            r = run_cell(
                arch, shape, multi_pod=mp, debug_mesh=args.debug_mesh,
                microbatches=args.microbatches, vocab_chunks=args.vocab_chunks,
                cache_layout=args.cache_layout, moe_groups=args.moe_groups,
                seq_shard=args.seq_shard, no_fsdp=args.no_fsdp,
                quant_int8=args.quant_int8,
                remat=(False if args.no_remat else None), tag=args.tag,
            )
            status = r["status"]
            n_ok += status == "ok"
            n_skip += status == "skipped"
            n_err += status == "error"
            if status == "ok":
                fl = r.get("hlo_flops", 0)
                print(
                    f"OK    {arch:22s} {shape:12s} {r['mesh']:8s} "
                    f"compile={r['compile_s']:7.1f}s flops={fl:.3e} "
                    f"args/dev={r['per_device_argument_gib']:.2f}GiB "
                    f"coll={ {k: f'{v:.2e}' for k, v in r['collective_bytes'].items()} }",
                    flush=True,
                )
            elif status == "skipped":
                print(f"SKIP  {arch:22s} {shape:12s} {r['mesh']:8s} {r['reason'][:60]}", flush=True)
            else:
                print(f"ERROR {arch:22s} {shape:12s} {r['mesh']:8s} {r['error'][:200]}", flush=True)
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
