"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).

Production target: TPU v5e pods, 16x16 = 256 chips per pod.
  single pod:  (data=16, model=16)           — ICI everywhere
  multi-pod:   (pod=2, data=16, model=16)    — "pod" is the DCN-class axis
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False, model: int = 2):
    """Tiny mesh for fast iteration on sharding rules (8-16 fake devices)."""
    n = len(jax.devices())
    if multi_pod:
        data = n // (2 * model)
        return jax.make_mesh((2, data, model), ("pod", "data", "model"))
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
