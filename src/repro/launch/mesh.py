"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).

Production target: TPU v5e pods, 16x16 = 256 chips per pod.
  single pod:  (data=16, model=16)           — ICI everywhere
  multi-pod:   (pod=2, data=16, model=16)    — "pod" is the DCN-class axis

The fleet control plane uses a different, 1-D mesh (`make_fleet_mesh`): one
"fleet" axis over the local devices, sharding the instance axis of a stacked
scenario ensemble (fleet/solve.py). CI exercises it on a simulated mesh via
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
from __future__ import annotations

import jax
import numpy as np


def make_fleet_mesh(n_devices: int | None = None):
    """1-D instance-axis mesh for the fleet control plane.

    n_devices : use only the first `n_devices` local devices (None = all).
        Asking for more devices than exist is a configuration error and
        raises — the old behaviour of silently running on whatever was
        available is exactly the fallback PR 4 removed.
    """
    from ..distributed.sharding import FLEET_AXIS

    devices = jax.devices()
    if n_devices is not None:
        if not 1 <= n_devices <= len(devices):
            raise ValueError(
                f"requested {n_devices} devices, have {len(devices)}"
            )
        devices = devices[:n_devices]
    return jax.sharding.Mesh(np.asarray(devices), (FLEET_AXIS,))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False, model: int = 2):
    """Tiny mesh for fast iteration on sharding rules (8-16 fake devices)."""
    n = len(jax.devices())
    if multi_pod:
        data = n // (2 * model)
        return jax.make_mesh((2, data, model), ("pod", "data", "model"))
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
