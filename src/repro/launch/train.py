"""Training entry point: sharded train loop + fault tolerance.

Production behaviors implemented and tested:
  * pjit-sharded step over the ambient mesh (rules from distributed/sharding)
  * checkpoint every --ckpt-every steps (atomic, keep-K), --resume restarts
    from the latest checkpoint including the data-stream position
  * SIGTERM/SIGINT (preemption) triggers a final checkpoint before exit
  * elastic restore: checkpoints are mesh-shape-agnostic (see checkpoint/)
  * metrics JSONL for monitoring

Example (CPU smoke):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
      --steps 20 --global-batch 8 --seq-len 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import signal
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.data import DataConfig, make_pipeline
from repro.distributed.act import activation_sharding
from repro.distributed.sharding import (
    ShardingRules,
    batch_pspec_for,
    param_pspecs,
    to_named_shardings,
)
from repro.launch import steps as St
from repro.models import init_params
from repro.optim import adamw_init
from jax.sharding import NamedSharding, PartitionSpec as P


def build_mesh(model_parallel: int):
    n = len(jax.devices())
    mp = max(1, min(model_parallel, n))
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if cfg.frontend != "none" or cfg.family == "encdec":
        # LM-style driver trains token-only families; frontend archs are
        # exercised by the partitioned-serving example instead.
        cfg = dataclasses.replace(cfg, frontend="none", frontend_dim=0)

    mesh = build_mesh(args.model_parallel)
    rules = ShardingRules()

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw_init(params)
    p_sh = to_named_shardings(mesh, param_pspecs(cfg, params, mesh, rules))
    o_sh = {"mu": p_sh, "nu": p_sh, "step": NamedSharding(mesh, P())}
    b_spec = batch_pspec_for(mesh, rules, args.global_batch)
    params = jax.device_put(params, p_sh)
    opt = jax.device_put(opt, o_sh)

    data_cfg = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq_len, global_batch=args.global_batch,
        seed=1234 + args.seed,
    )
    pipeline = make_pipeline(data_cfg)

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        (params, opt), extra, start_step = ckpt.restore(
            None, (params, opt), shardings=(p_sh, o_sh)
        )
        pipeline = make_pipeline(data_cfg, extra["data"])
        print(f"resumed from step {start_step}", flush=True)

    base_step = St.make_train_step(cfg, lr=args.lr, microbatches=args.microbatches)

    def step_fn(p, o, batch):
        with activation_sharding(mesh, rules):
            return base_step(p, o, batch)

    jit_step = jax.jit(
        step_fn,
        in_shardings=(p_sh, o_sh, {"tokens": NamedSharding(mesh, b_spec)}),
        donate_argnums=(0, 1),
    )

    metrics_path = Path(args.metrics) if args.metrics else None
    stop = {"now": False}

    def _preempt(signum, frame):
        stop["now"] = True

    signal.signal(signal.SIGTERM, _preempt)
    signal.signal(signal.SIGINT, _preempt)

    def save(step):
        if ckpt:
            ckpt.save(step, (params, opt), extra={"data": pipeline.state()})

    losses = []
    t_start = time.time()
    step = start_step
    with mesh:
        for step in range(start_step, args.steps):
            batch = {"tokens": jnp.asarray(pipeline.next_batch())}
            params, opt, m = jit_step(params, opt, batch)
            loss = float(m["loss"])
            losses.append(loss)
            if step % args.log_every == 0:
                line = {
                    "step": step, "loss": round(loss, 4),
                    "grad_norm": round(float(m["grad_norm"]), 4),
                    "elapsed_s": round(time.time() - t_start, 1),
                }
                print(json.dumps(line), flush=True)
                if metrics_path:
                    with open(metrics_path, "a") as f:
                        f.write(json.dumps(line) + "\n")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                save(step + 1)
            if stop["now"]:
                print("preemption signal: checkpointing and exiting", flush=True)
                save(step + 1)
                return 0
    if ckpt:
        save(args.steps)
    n = max(1, len(losses) // 5)
    print(
        f"done: first-5-avg={np.mean(losses[:n]):.4f} "
        f"last-5-avg={np.mean(losses[-n:]):.4f}",
        flush=True,
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
