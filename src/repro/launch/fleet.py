"""Fleet control-plane CLI: batched re-optimization of scenario ensembles.

The serving-side counterpart of `launch/serve.py`: where serve.py executes
one node's DNN partition, this entry point is the *control plane* that
(re)places partitions and routes for a whole fleet of edge deployments in
one batched solve (DESIGN.md section 9).

Examples (CPU):
  PYTHONPATH=src python -m repro.launch.fleet --families erdos_renyi,iot_hierarchy \
      --instances 16 --seed 7 --m-max 8
  PYTHONPATH=src python -m repro.launch.fleet --scenario iot --load-grid 0.4,0.8,1.2
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.fleet --instances 10 --shard

Observability: `--trace-out spans.jsonl` (or REPRO_TRACE=spans.jsonl)
records the host span trace — a Chrome trace_event twin lands next to it —
and the emitted JSON carries a "metrics" snapshot plus the engine's
round-trace summary under "trace" (DESIGN.md section 14).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

from repro.core import SCENARIOS
from repro.fleet import FAMILIES, load_grid, sample_fleet, solve_fleet
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--families",
        default=None,
        help=f"comma-separated generator families ({','.join(FAMILIES)})",
    )
    ap.add_argument("--instances", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--partitions",
        default=None,
        help="comma-separated DNN split depths (e.g. 1,2,3) cycled across "
        "instances — heterogeneous P is padded to one K envelope with inert "
        "phantom stages and solved as a single batch; with --scenario the "
        "first value sets the whole grid's depth. Default: the paper's P=2",
    )
    ap.add_argument(
        "--scenario",
        choices=list(SCENARIOS),
        default=None,
        help="use one paper scenario instead of sampled families",
    )
    ap.add_argument(
        "--load-grid",
        default=None,
        help="comma-separated load scales applied to --scenario",
    )
    from repro.fleet import METHODS

    ap.add_argument("--method", choices=list(METHODS), default="ALT")
    ap.add_argument("--m-max", type=int, default=30)
    ap.add_argument("--t-phi", type=int, default=10)
    ap.add_argument("--round-to", type=int, default=8)
    ap.add_argument(
        "--shard",
        action="store_true",
        help="run the engine with the instance axis committed over a 1-D "
        "fleet mesh of local devices (non-divisible batches are padded with "
        "inert repeats and trimmed)",
    )
    ap.add_argument(
        "--devices",
        type=int,
        default=None,
        help="cap the fleet mesh to the first N local devices "
        "(requires --shard)",
    )
    ap.add_argument(
        "--envelope-cap-gb",
        type=float,
        default=None,
        help="bound the per-device footprint of the [B, A, K, V, V] engine "
        "buffers by auto-capping the chunk size for this (V, A) tier",
    )
    ap.add_argument(
        "--solver",
        choices=("neumann", "lu"),
        default="neumann",
        help="linear fixed-point path: hop-capped Neumann propagation "
        "(default) or dense LU reference",
    )
    ap.add_argument(
        "--use-pallas",
        action="store_true",
        help="route the min-plus APSP and Neumann propagation through the "
        "Pallas kernels instead of the pure-XLA paths",
    )
    ap.add_argument(
        "--interpret",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="with --use-pallas, run the kernel bodies under the Pallas "
        "interpreter (CPU validation). A real TPU/GPU launch passes "
        "--use-pallas --no-interpret; no effect without --use-pallas",
    )
    ap.add_argument(
        "--chunk-size",
        type=int,
        default=None,
        help="split fleets larger than this into fixed-B chunks sharing one "
        "compiled (V, A, B) program",
    )
    ap.add_argument(
        "--block-apps",
        type=int,
        default=1,
        help="placement sweep schedule: 1 = the paper's sequential per-app "
        "scan (default), k > 1 = blocked sweep with size-k batched "
        "precompute, 0 = one block over all apps. Results are "
        "bitwise-identical across block sizes",
    )
    ap.add_argument(
        "--lane-chunk",
        type=int,
        default=None,
        help="round-body layout over the instance axis: 0 = fused vmap (the "
        "only layout compatible with --shard), k >= 1 = lax.map over k-lane "
        "chunks (faster warm on a single host). Default: auto (chunked when "
        "unsharded, vmap when a mesh is committed); bitwise-identical "
        "results either way",
    )
    ap.add_argument(
        "--trace-out",
        default=None,
        help="write the host span trace to this JSONL path (a Chrome "
        "trace_event file lands next to it); REPRO_TRACE=path does the same",
    )
    args = ap.parse_args(argv)

    if args.trace_out:
        obs_trace.configure(
            enabled=True,
            jsonl_path=args.trace_out,
            chrome_path=obs_trace.chrome_path_for(args.trace_out),
        )
    else:
        obs_trace.maybe_configure_from_env()

    partitions = (
        [int(x) for x in args.partitions.split(",")] if args.partitions else None
    )
    with obs_trace.span("launch.fleet.build", instances=args.instances):
        if args.scenario:
            scales = (
                [float(s) for s in args.load_grid.split(",")]
                if args.load_grid
                else [1.0] * args.instances
            )
            grid_kw = {"n_parts": partitions[0]} if partitions else {}
            fleet = load_grid(SCENARIOS[args.scenario], scales, **grid_kw)
        else:
            families = args.families.split(",") if args.families else None
            fleet = sample_fleet(
                args.instances, families=families, seed=args.seed,
                partitions=partitions,
            )

    t0 = time.time()
    with obs_trace.span(
        "launch.fleet.solve", method=args.method, instances=len(fleet)
    ):
        res = solve_fleet(
            fleet,
            method=args.method,
            m_max=args.m_max,
            t_phi=args.t_phi,
            round_to=args.round_to,
            shard=args.shard,
            devices=args.devices,
            solver=args.solver,
            use_pallas=args.use_pallas,
            interpret=args.interpret,
            chunk_size=args.chunk_size,
            envelope_cap_gb=args.envelope_cap_gb,
            block_apps=args.block_apps,
            lane_chunk=args.lane_chunk,
        )
    dt = time.time() - t0
    print(
        json.dumps(
            {
                "method": res.method,
                "solver": args.solver,
                "use_pallas": args.use_pallas,
                "interpret": args.interpret,
                "block_apps": args.block_apps,
                "lane_chunk": args.lane_chunk,
                "instances": res.n_instances,
                # split depths in the batch (per-instance P also appears in
                # each per_instance row as "partitions")
                "partition_mix": sorted(
                    {int(p) for p in res.parts[res.app_mask > 0]}
                ),
                "wall_s": round(dt, 2),
                "inst_per_s": round(res.n_instances / dt, 3),
                # while_loop trips actually executed: < m_max means the whole
                # batch converged and the engine exited early
                "rounds": res.rounds,
                "m_max": args.m_max,
                # the instance-axis layout decision: sharded or not, why, and
                # how many inert pad lanes were run and trimmed
                "shard": dataclasses.asdict(res.shard),
                "summary": res.summary(),
                # obs layer 3: the process-local metrics this solve produced
                "metrics": obs_metrics.registry.snapshot(),
                # obs layer 1: host summary of the engine's round trace
                "trace": None if res.trace is None else res.trace.to_dict(),
                "per_instance": res.per_instance(),
            },
            indent=1,
        ),
        flush=True,
    )
    obs_trace.flush()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
