"""Static cost model over optimized (post-SPMD) HLO text.

Why not `compiled.cost_analysis()`: XLA's aggregate counts each while-loop
body ONCE, so anything under scan-over-layers (i.e. ~everything here) is
undercounted by a factor of n_layers. This analyzer parses the HLO module
into computations, costs each op, and scales while bodies by their
`known_trip_count` backend config — recursively, memoized.

Costed quantities (per device, per step):
  flops       2 * prod(result_dims) * prod(contracting_dims)  for every dot
  bytes       sum of operand+result bytes of top-level ops (fusion internals
              are free — fusions are costed at their boundary, which models
              DRAM traffic under perfect intra-fusion reuse)
  collectives result bytes per op kind (all-gather / all-reduce /
              reduce-scatter / all-to-all / collective-permute)

Validated against the analytic MODEL_FLOPS = 6*N*D in tests/test_dryrun.py.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(r"(?:calls=|to_apply=|body=)(%[\w\.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_OPERAND_RE = re.compile(r"%[\w\.\-]+")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "iota", "partition-id", "replica-id",
}


def _shape_list(type_str):
    """All (dtype, dims) found in a result-type string (tuples give many)."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype = m.group(1)
        if dtype not in _DTYPE_BYTES:
            continue
        dims = [int(x) for x in m.group(2).split(",") if x]
        out.append((dtype, dims))
    return out


def _nbytes(shapes):
    total = 0
    for dtype, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


class Op:
    __slots__ = ("name", "kind", "shapes", "operands", "attrs")

    def __init__(self, name, kind, shapes, operands, attrs):
        self.name = name
        self.kind = kind
        self.shapes = shapes
        self.operands = operands
        self.attrs = attrs


def _parse_rhs(rhs: str):
    """rhs like 'f32[8,16]{1,0} dot(%a, %b), attrs...' -> (type, kind, ops, attrs)."""
    i = 0
    if rhs.startswith("("):
        depth = 0
        for j, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    i = j + 1
                    break
    type_end = rhs.find(" ", i)
    if type_end < 0:
        return rhs, "", "", ""
    type_str = rhs[:type_end]
    rest = rhs[type_end + 1 :]
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return type_str, rest.strip().split(" ")[0], "", ""
    kind = m.group(1)
    # operand list = up to matching close paren
    depth = 0
    start = m.end() - 1
    end = start
    for j in range(start, len(rest)):
        if rest[j] == "(":
            depth += 1
        elif rest[j] == ")":
            depth -= 1
            if depth == 0:
                end = j
                break
    operands_str = rest[start + 1 : end]
    attrs = rest[end + 1 :]
    return type_str, kind, operands_str, attrs


def parse_module(hlo_text: str):
    """-> (computations: {name: [Op]}, entry_name)."""
    comps: dict[str, list[Op]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        h = _HEADER_RE.match(line)
        if h:
            cur = h.group(2)
            comps[cur] = []
            if h.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        om = _OP_RE.match(line)
        if not om:
            continue
        name, rhs = om.group(1), om.group(2)
        type_str, kind, operands_str, attrs = _parse_rhs(rhs)
        shapes = _shape_list(type_str)
        operands = _OPERAND_RE.findall(operands_str)
        comps[cur].append(Op(name, kind, shapes, operands, attrs))
    return comps, entry


class CostResult(dict):
    @property
    def flops(self):
        return self["flops"]


def analyze(hlo_text: str) -> dict:
    comps, entry = parse_module(hlo_text)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {}, "warnings": ["no ENTRY"]}

    shape_tables = {
        cname: {op.name: op.shapes for op in ops} for cname, ops in comps.items()
    }
    memo: dict[str, tuple] = {}
    warnings: list[str] = []

    def cost(cname: str):
        if cname in memo:
            return memo[cname]
        flops = 0.0
        nbytes = 0.0
        coll = defaultdict(float)
        table = shape_tables.get(cname, {})
        for op in comps.get(cname, []):
            # --- nested computations ---
            if op.kind == "while":
                trip_m = _TRIP_RE.search(op.attrs)
                trip = int(trip_m.group(1)) if trip_m else 1
                if not trip_m:
                    warnings.append(f"unknown trip count in {cname}/{op.name}")
                body = _CALLED_RE.search(op.attrs)
                condm = _COND_RE.search(op.attrs)
                if body:
                    f, b, c = cost(body.group(1))
                    flops += f * trip
                    nbytes += b * trip
                    for k, v in c.items():
                        coll[k] += v * trip
                if condm:
                    f, b, c = cost(condm.group(1))
                    flops += f * trip
                continue
            if op.kind == "conditional":
                bm = _BRANCHES_RE.search(op.attrs)
                if bm:
                    branches = _OPERAND_RE.findall(bm.group(1))
                    results = [cost(x) for x in branches]
                    if results:
                        flops += max(r[0] for r in results)
                        nbytes += max(r[1] for r in results)
                        for r in results:
                            for k, v in r[2].items():
                                coll[k] += v
                continue
            called = _CALLED_RE.search(op.attrs)
            if called and op.kind in ("fusion", "call", "custom-call", "reduce",
                                      "reduce-window", "scatter", "sort", "map",
                                      "select-and-scatter", "all-reduce",
                                      "reduce-scatter"):
                f, _, _ = cost(called.group(1))
                flops += f  # dots inside fusions still count flops
            # --- op-level cost ---
            if op.kind == "dot":
                out_n = 1
                for _, dims in op.shapes[:1]:
                    for d in dims:
                        out_n *= d
                k = 1
                cm = _CONTRACT_RE.search(op.attrs)
                if cm and op.operands:
                    lhs_shapes = table.get(op.operands[0], [])
                    if lhs_shapes:
                        _, lhs_dims = lhs_shapes[0]
                        for ci in [int(x) for x in cm.group(1).split(",") if x]:
                            if ci < len(lhs_dims):
                                k *= lhs_dims[ci]
                flops += 2.0 * out_n * k
            if op.kind in COLLECTIVE_KINDS:
                coll[op.kind] += _nbytes(op.shapes)
            if op.kind in _FREE_OPS:
                continue
            # bytes: result + operands
            nbytes += _nbytes(op.shapes)
            for o in op.operands:
                nbytes += _nbytes(table.get(o, []))
        memo[cname] = (flops, nbytes, dict(coll))
        return memo[cname]

    f, b, c = cost(entry)
    return {"flops": f, "bytes": b, "collectives": c, "warnings": warnings[:10]}


def top_dots(hlo_text: str, n: int = 15) -> list[dict]:
    """The n largest dots by (trip-scaled) FLOPs, with op metadata — the
    profiler view used by the section-Perf hillclimb to find waste."""
    comps, entry = parse_module(hlo_text)
    shape_tables = {
        cname: {op.name: op.shapes for op in ops} for cname, ops in comps.items()
    }
    # computation -> multiplier (trip counts through the call graph)
    mult: dict[str, float] = {entry: 1.0}
    changed = True
    guard = 0
    while changed and guard < 50:
        changed = False
        guard += 1
        for cname, ops in comps.items():
            if cname not in mult:
                continue
            m = mult[cname]
            for op in ops:
                trip = 1.0
                if op.kind == "while":
                    t = _TRIP_RE.search(op.attrs)
                    trip = float(t.group(1)) if t else 1.0
                for ref in _OPERAND_RE.findall(op.attrs):
                    if ref in comps:
                        new = m * (trip if op.kind == "while" else 1.0)
                        if mult.get(ref, 0.0) < new:
                            mult[ref] = new
                            changed = True
    rows = []
    meta_re = re.compile(r'op_name="([^"]*)"')
    for cname, ops in comps.items():
        table = shape_tables[cname]
        for op in ops:
            if op.kind != "dot":
                continue
            out_n = 1
            for _, dims in op.shapes[:1]:
                for d in dims:
                    out_n *= d
            k = 1
            cm = _CONTRACT_RE.search(op.attrs)
            if cm and op.operands:
                lhs = table.get(op.operands[0], [])
                if lhs:
                    _, ld = lhs[0]
                    for ci in [int(x) for x in cm.group(1).split(",") if x]:
                        if ci < len(ld):
                            k *= ld[ci]
            f = 2.0 * out_n * k * mult.get(cname, 1.0)
            mm = meta_re.search(op.attrs)
            rows.append(
                {"flops": f, "comp": cname, "shape": op.shapes[:1],
                 "meta": mm.group(1) if mm else ""}
            )
    rows.sort(key=lambda r: -r["flops"])
    return rows[:n]
