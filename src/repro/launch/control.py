"""Epoch controller: fault trace -> repair -> warm re-solve, with a
degradation ladder that guarantees every epoch ends with a servable
placement (DESIGN.md section 15).

Per epoch:
  1. advance the fault trace and apply each instance's `InstanceHealth`
     to its base problem (`chaos.apply_health` — dead nodes become padded
     nodes, degraded links get scaled mu, flash crowds scale lam);
  2. repair the previous epoch's placement (`chaos.repair_fleet`): evict
     partitions from dead hosts, rebuild phi around dead nodes — the
     repaired state is both the warm start AND the degradation floor;
  3. warm re-solve with freeze masks: only instances whose health changed
     since their last solve burn rounds (`solve_fleet(warm_start=...,
     warm_active=changed)`); an event-free epoch costs one init eval.

Degradation ladder on non-finite J, infeasible placement, or an exception:
warm -> cold re-solve from scratch -> CoLocated (the always-feasible
single-host baseline) -> carry the repaired previous placement unchanged.
Escalation honors a soft per-epoch timeout and optional exponential
backoff. Every rung records through obs.metrics (`control.*` counters,
recovery-latency histogram) and obs.trace spans, and the whole run
serializes to JSON for BENCH_serve.json.

CLI:
  PYTHONPATH=src python -m repro.launch.control --instances 8 --epochs 50 \
      --seed 11 --m-max 8 --json-out control.json --events-out events.json \
      --assert-feasible
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.control --instances 8 --shard
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import logging
import time

import numpy as np

from repro.chaos import (
    FaultTrace,
    InstanceHealth,
    apply_health,
    generate_trace,
    refresh_apsp0,
    repair_fleet,
)
from repro.core.structs import hop_bound_cache
from repro.fleet import FAMILIES, sample_fleet, solve_fleet
from repro.fleet.pad import (
    fleet_envelope,
    fleet_part_envelope,
    unify_hop_bound,
)
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.trace import span

logger = logging.getLogger("repro.control")


@dataclasses.dataclass
class EpochReport:
    """What one control epoch did and what it cost.

    mode     : "cold" (first epoch / post-fallback) or "warm"
    outcome  : "ok" — the first-choice solve was accepted;
               "cold-retry" / "colocated" — a ladder rung caught it;
               "carry" — every rung failed, the repaired previous placement
               was carried unchanged (still servable: repair guarantees no
               dead hosts)
    perturbed: instances whose health changed this epoch
    rounds   : engine while_loop trips of the accepted solve (0 for carry)
    cold_rounds : rounds of the comparison solve-from-scratch when the
               controller ran one (compare_cold; event epochs only)
    recovery_s : wall time from epoch start to accepted placement, only for
               epochs where at least one fault/recovery fired
    """

    epoch: int
    mode: str
    outcome: str
    attempts: int
    perturbed: int
    events: list
    rounds: int
    J_median: float
    finite: bool
    feasible: bool
    wall_s: float
    recovery_s: float | None = None
    cold_rounds: int | None = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ControlResult:
    reports: list
    n_instances: int
    counts: dict
    wall_s: float

    def summary(self) -> dict:
        n = len(self.reports)
        rec = [
            r.recovery_s for r in self.reports if r.recovery_s is not None
        ]
        warm_ok = [
            r for r in self.reports if r.mode == "warm" and r.outcome == "ok"
        ]
        warm_event = [r for r in warm_ok if r.perturbed > 0]
        cold_cmp = [
            r.cold_rounds for r in self.reports if r.cold_rounds is not None
        ]
        out = {
            "epochs": n,
            "instances": self.n_instances,
            "epochs_per_s": round(n / max(self.wall_s, 1e-9), 4),
            "wall_s": round(self.wall_s, 3),
            "feasible_fraction": (
                sum(r.feasible for r in self.reports) / max(n, 1)
            ),
            "infeasible_epochs": sum(not r.feasible for r in self.reports),
            "nonfinite_epochs": sum(not r.finite for r in self.reports),
            "fallback_epochs": sum(
                r.outcome != "ok" for r in self.reports
            ),
            "fallback_rate": (
                sum(r.outcome != "ok" for r in self.reports) / max(n, 1)
            ),
            "p50_recovery_latency_s": (
                round(float(np.percentile(rec, 50)), 4) if rec else 0.0
            ),
            "p95_recovery_latency_s": (
                round(float(np.percentile(rec, 95)), 4) if rec else 0.0
            ),
            "warm_epochs": len(warm_ok),
            # Trend-linted ("rounds_executed" => machine-portable, lower is
            # better): mean engine trips of warm event-epochs vs the
            # matching solve-from-scratch comparison runs.
            "warm_rounds_executed": (
                round(float(np.mean([r.rounds for r in warm_event])), 3)
                if warm_event else 0.0
            ),
            "events": dict(self.counts),
        }
        if cold_cmp:
            out["cold_rounds_executed"] = round(float(np.mean(cold_cmp)), 3)
        return out


def _feasible_hosts(hosts, parts_list, live_masks) -> bool:
    """No live partition of any app may sit on a dead (or padded) node."""
    hosts = np.asarray(hosts)
    for b, live in enumerate(live_masks):
        live = np.asarray(live) > 0
        n_real = live.size
        parts = np.asarray(parts_list[b])
        for a in range(parts.size):
            hs = hosts[b, a, : int(parts[a])]
            if (hs >= n_real).any():
                return False
            if not live[hs].all():
                return False
    return True


def run_control(
    fleet,
    trace: FaultTrace | None = None,
    *,
    epochs: int | None = None,
    seed: int = 0,
    m_max: int = 8,
    t_phi: int = 5,
    alpha: float = 0.5,
    tol: float = 1e-3,
    patience: int = 4,
    solver: str = "neumann",
    use_pallas: bool = False,
    interpret: bool = True,
    block_apps: int = 1,
    lane_chunk: int | None = None,
    round_to: int = 8,
    shard: bool = False,
    devices: int | None = None,
    timeout_s: float | None = None,
    backoff_s: float = 0.0,
    compare_cold: bool = False,
    verify_hop_bound: bool = False,
    verify_apsp0: bool = False,
    trace_kwargs: dict | None = None,
) -> ControlResult:
    """Run the fault-injection control loop over a fleet (module doc).

    fleet        : base (unperturbed) `Problem` list
    trace        : a pre-generated `FaultTrace`; None generates one from
                   (fleet, epochs, seed, **trace_kwargs)
    interpret    : with use_pallas, run kernel bodies under the Pallas
                   interpreter (CPU validation; --no-interpret on real TPU)
    timeout_s    : soft per-epoch budget — once exceeded, the ladder stops
                   escalating and carries the repaired placement
    backoff_s    : base of the exponential retry backoff between rungs
    compare_cold : on each warm event-epoch, also run an (unused) cold
                   solve-from-scratch on the same perturbed problems and
                   record its rounds — the warm-start efficiency baseline
    verify_hop_bound : per epoch, re-derive every instance's hop bound from
                   scratch and assert the incremental `HopBoundCache` refresh
                   matches it bitwise (the §16 exactness contract; CI runs
                   the chaos job with this on)
    verify_apsp0 : per warm epoch, recompute the zero-load APSP from scratch
                   and assert the `Apsp0Cache` pair the repair consumed
                   matches it bitwise (same CI posture as verify_hop_bound)
    block_apps / lane_chunk : forwarded to every `solve_fleet` rung — the
                   placement sweep schedule and the round-body lane layout
                   (both bitwise-invariant knobs; see fleet/solve.py)

    The solver's hop bound stays PINNED from the base fleet (shape
    stability: re-deriving it per epoch would recompile the engine whenever
    the diameter moved). The per-epoch `hop_bound_cache` maintenance is the
    cheap incremental tracker feeding the `control.hop_bound.*` metrics —
    most epochs leave adjacency untouched (degradations scale mu, flash
    crowds scale lam) and cost one host-side array compare; node churn
    epochs re-close warm in one or two squaring sweeps. On the XLA solver
    path the `effective_hops` V+1 floor keeps the solve exact even when the
    true post-fault diameter exceeds the pinned bound; the tracker counts
    those epochs (`control.hop_bound.exceeds_pinned`) so a Pallas fixed-hop
    deployment knows when its slack was actually consumed.
    """
    base = list(fleet)
    n_inst = len(base)
    if trace is None:
        if epochs is None:
            raise ValueError("run_control: pass either trace= or epochs=")
        trace = generate_trace(
            base, epochs, seed=seed, **(trace_kwargs or {})
        )
    if trace.n_instances != n_inst:
        raise ValueError(
            f"run_control: trace covers {trace.n_instances} instances, "
            f"fleet has {n_inst}"
        )
    # Pin the stacked envelope from the BASE fleet: perturbation never
    # changes shapes, so every epoch's repair + solve agree on it and the
    # carried State stays shape-stable (warm_start would raise otherwise).
    envelope = fleet_envelope(base, round_to=round_to)
    part_env = fleet_part_envelope(base)
    hop_bound = unify_hop_bound(base)
    parts_list = [np.asarray(p.apps.parts) for p in base]

    solve_common = dict(
        m_max=m_max, t_phi=t_phi, alpha=alpha, tol=tol, patience=patience,
        round_to=round_to, shard=shard, devices=devices, solver=solver,
        use_pallas=use_pallas, interpret=interpret,
        block_apps=block_apps, lane_chunk=lane_chunk, keep_state=True,
        # The controller re-validates shape-stable perturbations of an
        # already-validated base fleet every epoch; keep the checks on —
        # they are exactly the NaN firewall this loop exists for.
        validate=True,
    )

    reg = obs_metrics.registry
    reports: list = []
    prev_state = None
    prev_health = [InstanceHealth() for _ in range(n_inst)]
    force_all_active = False
    hop_caches = [None] * n_inst
    apsp0 = None
    t_run = time.time()

    for epoch, fired, healths in trace.timeline():
        t0 = time.time()
        with span("control.epoch", epoch=epoch, events=len(fired)):
            with span("control.chaos", epoch=epoch):
                pairs = [
                    apply_health(p, h) for p, h in zip(base, healths)
                ]
                probs = [pr for pr, _ in pairs]
                masks = [m for _, m in pairs]
            with span("control.hop_bound", epoch=epoch):
                hop_caches = [
                    hop_bound_cache(
                        pr.net, hc, use_pallas=use_pallas, interpret=interpret
                    )
                    for pr, hc in zip(probs, hop_caches)
                ]
                if verify_hop_bound:
                    for i, (pr, hc) in enumerate(zip(probs, hop_caches)):
                        scratch = hop_bound_cache(
                            pr.net, None, use_pallas=use_pallas,
                            interpret=interpret,
                        )
                        if not np.array_equal(hc.dist, scratch.dist):
                            raise AssertionError(
                                f"control: epoch {epoch} instance {i}: "
                                "incremental hop-bound closure diverged "
                                "from the from-scratch solve "
                                f"(warm bound {hc.hop_bound}, scratch "
                                f"{scratch.hop_bound})"
                            )
                tracked = max(c.hop_bound for c in hop_caches)
                reg.gauge("control.hop_bound.max").set(tracked)
                reg.counter("control.hop_bound.warm_sweeps").inc(
                    sum(c.sweeps for c in hop_caches if c.sweeps > 0)
                )
                reg.counter("control.hop_bound.unchanged").inc(
                    sum(1 for c in hop_caches if c.sweeps == 0)
                )
                if tracked > hop_bound:
                    reg.counter("control.hop_bound.exceeds_pinned").inc()
            changed = np.array(
                [h != ph for h, ph in zip(healths, prev_health)], dtype=bool
            )
            repaired = None
            if prev_state is not None:
                with span("control.repair", epoch=epoch):
                    env_kw = dict(
                        round_to=round_to, envelope=envelope,
                        hop_bound=hop_bound, n_parts=part_env,
                        use_pallas=use_pallas, interpret=interpret,
                    )
                    apsp0 = refresh_apsp0(probs, apsp0, **env_kw)
                    reg.counter(
                        "control.apsp0.hits" if apsp0.reused
                        else "control.apsp0.misses"
                    ).inc()
                    if verify_apsp0 and apsp0.reused:
                        scratch = refresh_apsp0(probs, None, **env_kw)
                        if not (
                            np.array_equal(apsp0.dist, scratch.dist)
                            and np.array_equal(apsp0.nexthop, scratch.nexthop)
                        ):
                            raise AssertionError(
                                f"control: epoch {epoch}: cached zero-load "
                                "APSP diverged from the from-scratch solve "
                                "(the Apsp0Cache key let a changed input "
                                "through)"
                            )
                    repaired = repair_fleet(
                        probs, prev_state, masks, apsp0=apsp0, **env_kw
                    )

            mode = "warm" if repaired is not None else "cold"
            ladder = []
            if repaired is not None:
                active = (
                    np.ones(n_inst, bool) if force_all_active else changed
                )
                ladder.append(
                    (
                        "warm",
                        dict(
                            method="ALT", warm_start=repaired,
                            warm_active=active,
                        ),
                    )
                )
            ladder.append(("cold", dict(method="ALT")))
            ladder.append(("colocated", dict(method="CoLocated")))

            result = None
            accepted_rung = None
            attempts = 0
            for rung, (name, extra) in enumerate(ladder):
                if (
                    attempts > 0
                    and timeout_s is not None
                    and time.time() - t0 > timeout_s
                ):
                    logger.warning(
                        "control: epoch %d over budget (%.2fs > %.2fs); "
                        "carrying repaired placement",
                        epoch, time.time() - t0, timeout_s,
                    )
                    break
                if attempts > 0 and backoff_s > 0:
                    time.sleep(backoff_s * (2 ** (attempts - 1)))
                attempts += 1
                try:
                    with span("control.solve", epoch=epoch, rung=name):
                        r = solve_fleet(probs, **extra, **solve_common)
                except Exception:
                    logger.exception(
                        "control: epoch %d %s solve raised", epoch, name
                    )
                    continue
                if not np.isfinite(r.J).all():
                    logger.warning(
                        "control: epoch %d %s solve returned non-finite J; "
                        "escalating", epoch, name,
                    )
                    continue
                if not _feasible_hosts(r.hosts, parts_list, masks):
                    logger.warning(
                        "control: epoch %d %s solve placed on a dead host; "
                        "escalating", epoch, name,
                    )
                    continue
                result = r
                accepted_rung = rung
                break

            perturbed = int(changed.sum())
            if result is not None:
                outcome = (
                    "ok" if accepted_rung == 0
                    else "cold-retry" if ladder[accepted_rung][0] == "cold"
                    else "colocated"
                )
                prev_state = result.state
                rounds = int(result.rounds)
                j_med = float(np.median(result.J))
                finite = True
                feasible = True
            else:
                # Degradation floor: the repaired previous placement (or the
                # pristine-epoch None -> there is nothing to serve, which
                # cannot happen past epoch 0 since cold+colocated both ran).
                outcome = "carry"
                rounds = 0
                j_med = float("nan")
                finite = False
                feasible = repaired is not None and _feasible_hosts(
                    np.asarray(repaired.hosts()), parts_list, masks
                )
                if repaired is not None:
                    prev_state = repaired

            cold_rounds = None
            if (
                compare_cold
                and result is not None
                and mode == "warm"
                and perturbed > 0
            ):
                with span("control.compare_cold", epoch=epoch):
                    rc = solve_fleet(
                        probs, method="ALT",
                        **{
                            k: v for k, v in solve_common.items()
                            if k != "keep_state"
                        },
                    )
                cold_rounds = int(rc.rounds)

            wall = time.time() - t0
            report = EpochReport(
                epoch=epoch,
                mode=mode,
                outcome=outcome,
                attempts=attempts,
                perturbed=perturbed,
                events=[ev.to_dict() for ev in fired],
                rounds=rounds,
                J_median=j_med,
                finite=finite,
                feasible=feasible,
                wall_s=round(wall, 4),
                recovery_s=round(wall, 4) if fired else None,
                cold_rounds=cold_rounds,
            )
            reports.append(report)
            prev_health = list(healths)
            force_all_active = outcome != "ok"

            reg.counter("control.epochs").inc()
            reg.counter(f"control.outcome.{outcome}").inc()
            reg.counter(f"control.mode.{mode}").inc()
            if not feasible:
                reg.counter("control.infeasible_epochs").inc()
            if fired:
                reg.histogram("control.recovery_latency_s").observe(wall)
            reg.gauge("control.last_rounds").set(rounds)

    return ControlResult(
        reports=reports,
        n_instances=n_inst,
        counts=trace.counts(),
        wall_s=time.time() - t_run,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fault-injection control loop over a sampled fleet"
    )
    ap.add_argument(
        "--families", default="iot_hierarchy",
        help=f"comma-separated generator families ({','.join(FAMILIES)})",
    )
    ap.add_argument("--instances", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--epochs", type=int, default=50)
    ap.add_argument("--node-failures", type=int, default=5)
    ap.add_argument("--link-degradations", type=int, default=3)
    ap.add_argument("--flash-crowds", type=int, default=1)
    ap.add_argument("--m-max", type=int, default=8)
    ap.add_argument("--t-phi", type=int, default=5)
    ap.add_argument("--round-to", type=int, default=8)
    ap.add_argument(
        "--solver", choices=("neumann", "lu"), default="neumann"
    )
    ap.add_argument(
        "--use-pallas", action="store_true",
        help="route the min-plus APSP and Neumann propagation through the "
        "Pallas kernels instead of the pure-XLA paths",
    )
    ap.add_argument(
        "--interpret", action=argparse.BooleanOptionalAction, default=True,
        help="with --use-pallas, run kernel bodies under the Pallas "
        "interpreter (a real TPU/GPU launch passes --no-interpret)",
    )
    ap.add_argument(
        "--verify-hop-bound", action="store_true",
        help="assert the incremental per-epoch hop-bound cache matches a "
        "from-scratch closure bitwise (exactness gate; used by CI chaos)",
    )
    ap.add_argument(
        "--verify-apsp0", action="store_true",
        help="assert the cached zero-load APSP behind each warm epoch's "
        "repair matches a from-scratch solve bitwise (exactness gate; used "
        "by CI chaos)",
    )
    ap.add_argument(
        "--block-apps", type=int, default=1,
        help="placement sweep schedule for every solve rung (1 = sequential "
        "scan, k > 1 = blocked, 0 = one block; bitwise-invariant)",
    )
    ap.add_argument(
        "--lane-chunk", type=int, default=None,
        help="round-body layout over the instance axis (0 = fused vmap, "
        "k >= 1 = lax.map lane chunks; default auto — see solve_fleet)",
    )
    ap.add_argument("--shard", action="store_true")
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument(
        "--timeout-s", type=float, default=None,
        help="soft per-epoch budget before the ladder stops escalating",
    )
    ap.add_argument(
        "--backoff-s", type=float, default=0.0,
        help="base of the exponential retry backoff between ladder rungs",
    )
    ap.add_argument(
        "--compare-cold", action="store_true",
        help="also run a solve-from-scratch on warm event-epochs and "
        "record its rounds (the warm-start efficiency baseline)",
    )
    ap.add_argument("--json-out", default=None)
    ap.add_argument(
        "--events-out", default=None,
        help="write the generated fault trace (the replayable event "
        "schedule) to this JSON path",
    )
    ap.add_argument("--trace-out", default=None, help="host span trace JSONL")
    ap.add_argument(
        "--assert-feasible", action="store_true",
        help="exit nonzero unless every epoch was feasible with finite J",
    )
    args = ap.parse_args(argv)

    if args.trace_out:
        obs_trace.configure(
            enabled=True,
            jsonl_path=args.trace_out,
            chrome_path=obs_trace.chrome_path_for(args.trace_out),
        )
    else:
        obs_trace.maybe_configure_from_env()

    with span("launch.control.build", instances=args.instances):
        fleet = sample_fleet(
            args.instances,
            families=args.families.split(","),
            seed=args.seed,
        )
        trace = generate_trace(
            fleet, args.epochs, seed=args.seed + 1,
            node_failures=args.node_failures,
            link_degradations=args.link_degradations,
            flash_crowds=args.flash_crowds,
        )
    if args.events_out:
        trace.save(args.events_out)

    ctl = run_control(
        fleet, trace=trace, m_max=args.m_max, t_phi=args.t_phi,
        solver=args.solver, use_pallas=args.use_pallas,
        interpret=args.interpret, round_to=args.round_to, shard=args.shard,
        devices=args.devices, timeout_s=args.timeout_s,
        backoff_s=args.backoff_s, compare_cold=args.compare_cold,
        verify_hop_bound=args.verify_hop_bound,
        verify_apsp0=args.verify_apsp0,
        block_apps=args.block_apps, lane_chunk=args.lane_chunk,
    )
    s = ctl.summary()
    print(
        json.dumps(
            {
                "summary": s,
                "metrics": obs_metrics.registry.snapshot(),
                "epochs": [r.to_dict() for r in ctl.reports],
            },
            indent=1,
            default=str,
        ),
        flush=True,
    )
    if args.json_out:
        with open(args.json_out, "w") as fh:
            json.dump(
                {
                    "summary": s,
                    "metrics": obs_metrics.registry.snapshot(),
                    "epochs": [r.to_dict() for r in ctl.reports],
                },
                fh, indent=1, default=str,
            )
    obs_trace.flush()
    if args.assert_feasible and (
        s["infeasible_epochs"] or s["nonfinite_epochs"]
    ):
        print(
            f"ASSERTION FAILED: {s['infeasible_epochs']} infeasible / "
            f"{s['nonfinite_epochs']} non-finite epochs",
            flush=True,
        )
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
