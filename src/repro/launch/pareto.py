"""Split-point Pareto search CLI (DESIGN.md section 17).

Enumerates per-architecture candidate split sets (every cut point x P in
{1..4}) for the model zoo, solves ALL candidates over a (topology, load,
eta) grid as ONE batched `solve_fleet` call, and emits the dominated-point-
filtered latency/compute/egress Pareto front per (architecture, topology,
load).

Examples (CPU):
  PYTHONPATH=src python -m repro.launch.pareto --archs qwen1.5-0.5b,hymba-1.5b \
      --topologies iot,mesh --max-per-p 8 --m-max 6
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.pareto --shard --assert-front
  PYTHONPATH=src python -m repro.launch.pareto --json-out fronts.json \
      --plot-out plots/

Observability: `--trace-out spans.jsonl` records the host span trace
(enumerate/build/solve/extract); the JSON carries the obs metrics snapshot
(candidates solved, cut sets dropped, front sizes, pad overhead).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

from repro.configs import ZOO
from repro.core import SCENARIOS
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.partition.pareto import check_fronts, sweep_zoo


def write_front_plots(report: dict, out_dir: str) -> list[str]:
    """Scatter each cell's candidates (latency vs egress, compute as size)
    with the Pareto front highlighted. Gated on matplotlib: environments
    without it get a clean skip, not a crash."""
    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        print("plot: matplotlib not installed — skipping front plots")
        return []
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    written = []
    for cell in report["cells"]:
        pts = cell["points"]
        lat = [p["latency"] for p in pts]
        egr = [p["egress"] for p in pts]
        on = [p["on_front"] for p in pts]
        fig, ax = plt.subplots(figsize=(5, 4))
        ax.scatter(
            [x for x, f in zip(lat, on) if not f],
            [y for y, f in zip(egr, on) if not f],
            s=12, alpha=0.4, label="dominated",
        )
        fr = sorted(
            ((lat[i], egr[i]) for i in cell["front"]), key=lambda t: t[0]
        )
        ax.plot(
            [x for x, _ in fr], [y for _, y in fr],
            "ro-", ms=5, lw=1, label=f"front ({cell['front_size']})",
        )
        ax.set_xlabel("latency (J_comm + J_comp)")
        ax.set_ylabel("egress (bytes/s on links)")
        ax.set_title(
            f"{cell['arch']} @ {cell['topology']} load={cell['load']}"
        )
        ax.legend(fontsize=8)
        fig.tight_layout()
        path = out / (
            f"front_{cell['arch']}_{cell['topology']}_"
            f"load{cell['load']:g}.png"
        )
        fig.savefig(path, dpi=120)
        plt.close(fig)
        written.append(str(path))
    return written


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--archs",
        default=None,
        help=f"comma-separated architectures (default: all {len(ZOO)} zoo "
        "configs)",
    )
    ap.add_argument(
        "--topologies",
        default="iot,mesh",
        help=f"comma-separated scenarios ({','.join(SCENARIOS)})",
    )
    ap.add_argument("--loads", default="1.0", help="comma-separated load scales")
    ap.add_argument(
        "--etas",
        default="0.5",
        help="comma-separated comm/comp weightings (Fig-5 axis); each eta "
        "solves every candidate once and the fronts pool across etas",
    )
    ap.add_argument(
        "--parts", default="1,2,3,4", help="comma-separated split depths"
    )
    ap.add_argument(
        "--max-per-p",
        type=int,
        default=16,
        help="candidate cut sets kept per (arch, P) — deterministic "
        "evenly-spaced subsample of the full enumeration; the dropped "
        "count is reported, never silent",
    )
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--method", default="ALT")
    ap.add_argument("--m-max", type=int, default=8)
    ap.add_argument("--t-phi", type=int, default=5)
    ap.add_argument("--round-to", type=int, default=8)
    ap.add_argument(
        "--shard",
        action="store_true",
        help="commit the candidate axis over a 1-D fleet mesh of local "
        "devices",
    )
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--chunk-size", type=int, default=None)
    ap.add_argument(
        "--envelope-cap-gb",
        type=float,
        default=2.0,
        help="bound the per-device [B, A, K, V, V] engine footprint "
        "(auto-chunks the candidate batch)",
    )
    ap.add_argument(
        "--solver", choices=("neumann", "lu"), default="neumann"
    )
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument(
        "--interpret", action=argparse.BooleanOptionalAction, default=True
    )
    ap.add_argument(
        "--assert-front",
        action="store_true",
        help="hard-gate the report (CI): non-empty finite fronts in every "
        "cell, dominated points actually filtered, fronts re-verified",
    )
    ap.add_argument(
        "--json-out",
        default=None,
        help="write the full report JSON here (stdout gets a summary)",
    )
    ap.add_argument(
        "--plot-out",
        default=None,
        help="write per-cell front plots (PNG) into this directory "
        "(requires matplotlib; skipped cleanly without it)",
    )
    ap.add_argument("--trace-out", default=None)
    args = ap.parse_args(argv)

    if args.trace_out:
        obs_trace.configure(
            enabled=True,
            jsonl_path=args.trace_out,
            chrome_path=obs_trace.chrome_path_for(args.trace_out),
        )
    else:
        obs_trace.maybe_configure_from_env()

    t0 = time.time()
    with obs_trace.span("launch.pareto"):
        report = sweep_zoo(
            archs=args.archs.split(",") if args.archs else None,
            topologies=tuple(args.topologies.split(",")),
            loads=tuple(float(x) for x in args.loads.split(",")),
            etas=tuple(float(x) for x in args.etas.split(",")),
            parts=tuple(int(x) for x in args.parts.split(",")),
            max_per_p=args.max_per_p,
            seq_len=args.seq_len,
            method=args.method,
            m_max=args.m_max,
            t_phi=args.t_phi,
            round_to=args.round_to,
            shard=args.shard,
            devices=args.devices,
            chunk_size=args.chunk_size,
            envelope_cap_gb=args.envelope_cap_gb,
            use_pallas=args.use_pallas,
            interpret=args.interpret,
            solver=args.solver,
        )
    dt = time.time() - t0
    report["wall_s"] = round(dt, 2)
    report["candidates_per_s"] = round(report["n_instances"] / dt, 3)
    report["metrics"] = obs_metrics.registry.snapshot()

    if args.assert_front:
        check_fronts(report)
    if args.plot_out:
        report["plots"] = write_front_plots(report, args.plot_out)
    if args.json_out:
        pathlib.Path(args.json_out).write_text(
            json.dumps(report, indent=1) + "\n"
        )
        summary = {
            k: v for k, v in report.items() if k != "cells"
        }
        summary["cells"] = [
            {k: v for k, v in c.items() if k != "points"}
            for c in report["cells"]
        ]
        print(json.dumps(summary, indent=1), flush=True)
    else:
        print(json.dumps(report, indent=1), flush=True)
    obs_trace.flush()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
