"""Serving entry point: batched prefill + decode with slot-based continuous
batching (vLLM-style, simplified to synchronous steps).

A fixed pool of B slots runs lockstep decode; finished sequences free their
slot and the scheduler admits queued requests via a fresh prefill. Straggler/
hot-node mitigation at the cluster level is the paper's own contribution —
see examples/edge_serving.py where repro.core re-routes around degraded
nodes; this module is the per-node execution engine.

CPU smoke:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --requests 12 --batch-slots 4 --prompt-len 32 --max-new 16

The JSON summary carries serving SLO telemetry through the obs metrics
registry (DESIGN.md section 14): p50/p95 end-to-end latency, p50/p95
time-to-first-token, and decode throughput, plus the raw registry snapshot
under "metrics". REPRO_TRACE=path additionally records host spans around
the prefill/decode loop.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import decode_step, init_caches, init_params, prefill
from repro.launch.steps import serve_config
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [prompt_len]
    max_new: int
    arrived: float
    started: float | None = None
    tokens: list | None = None
    finished: float | None = None
    # Wall time the first generated token landed (set once; survives the
    # re-prefill hack because dataclasses.replace copies it).
    first_token: float | None = None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    obs_trace.maybe_configure_from_env()
    registry = obs_metrics.registry

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    cfg = serve_config(cfg)
    if cfg.frontend != "none" or cfg.family == "encdec":
        cfg = dataclasses.replace(cfg, frontend="none", frontend_dim=0)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))

    rng = np.random.RandomState(args.seed)
    queue = [
        Request(
            rid=i,
            prompt=rng.randint(0, cfg.vocab, size=args.prompt_len).astype(np.int32),
            max_new=args.max_new,
            arrived=time.time(),
        )
        for i in range(args.requests)
    ]

    b = args.batch_slots
    jit_prefill = jax.jit(
        lambda p, batch: prefill(cfg, p, batch, args.max_seq)
    )
    jit_decode = jax.jit(
        lambda p, caches, tok, pos: decode_step(cfg, p, caches, tok, pos)
    )

    # Slot state (lockstep positions; per-slot remaining budget).
    active: list[Request | None] = [None] * b
    caches = None
    cur_tokens = np.zeros((b, 1), np.int32)
    pos = args.prompt_len
    done: list[Request] = []
    decode_steps = 0
    t0 = time.time()

    def admit():
        nonlocal caches, cur_tokens, pos
        free = [i for i, r in enumerate(active) if r is None]
        if not free or not queue:
            return
        # Lockstep batch: admit up to all free slots at once with a batched
        # prefill (empty slots run a dummy prompt).
        prompts = np.zeros((b, args.prompt_len), np.int32)
        for i in range(b):
            if active[i] is not None and active[i].tokens:
                continue
        batchful = []
        for i in free:
            if queue:
                r = queue.pop(0)
                r.started = time.time()
                r.tokens = []
                active[i] = r
                batchful.append(i)
        prompts = np.stack(
            [
                active[i].prompt if active[i] is not None
                else np.zeros(args.prompt_len, np.int32)
                for i in range(b)
            ]
        )
        with obs_trace.span("serve.prefill", admitted=len(batchful)):
            new_caches, logits = jit_prefill(
                params, {"tokens": jnp.asarray(prompts)}
            )
        caches = new_caches
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1)).astype(np.int32)
        cur_tokens = nxt[:, None]
        pos = args.prompt_len

    with obs_trace.span("serve.run", requests=args.requests, slots=b):
        admit()
        while any(r is not None for r in active) or queue:
            logits, caches = jit_decode(
                params, caches, jnp.asarray(cur_tokens), jnp.int32(pos)
            )
            decode_steps += 1
            nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1)).astype(np.int32)
            pos += 1
            finished_any = False
            for i, r in enumerate(active):
                if r is None:
                    continue
                r.tokens.append(int(nxt[i]))
                if r.first_token is None:
                    r.first_token = time.time()
                if len(r.tokens) >= r.max_new or pos >= args.max_seq - 1:
                    r.finished = time.time()
                    done.append(r)
                    active[i] = None
                    finished_any = True
            cur_tokens = nxt[:, None]
            if finished_any and queue:
                # Simplification: re-prefill the whole batch when slots free
                # up (a real engine would use paged attention to splice
                # requests).
                for i, r in enumerate(active):
                    if r is not None:
                        queue.insert(0, dataclasses.replace(r))
                        active[i] = None
                admit()

    dt = time.time() - t0
    total_tokens = sum(len(r.tokens) for r in done)

    # Serving SLOs through the obs registry (layer 3): the percentiles the
    # JSON reports are computed FROM the histogram snapshot, so the CLI and
    # any metrics consumer can never disagree.
    lat_hist = registry.histogram("serve.latency_s")
    ttft_hist = registry.histogram("serve.ttft_s")
    for r in done:
        lat_hist.observe(r.finished - r.arrived)
        if r.first_token is not None:
            ttft_hist.observe(r.first_token - r.arrived)
    registry.counter("serve.requests").inc(len(done))
    decode_tps = total_tokens / dt
    registry.gauge("serve.decode_tokens_per_s").set(decode_tps)
    lat_snap = lat_hist.snapshot()
    ttft_snap = ttft_hist.snapshot()
    print(
        json.dumps(
            {
                "requests": len(done),
                "decode_steps": decode_steps,
                "generated_tokens": total_tokens,
                "tokens_per_s": round(total_tokens / dt, 2),
                "decode_tokens_per_s": round(decode_tps, 2),
                "mean_latency_s": round(lat_snap["mean"], 3),
                "p50_latency_s": round(lat_snap["p50"], 3),
                "p95_latency_s": round(lat_snap["p95"], 3),
                "p50_ttft_s": round(ttft_snap["p50"], 3),
                "p95_ttft_s": round(ttft_snap["p95"], 3),
                "metrics": {
                    k: (
                        {kk: round(vv, 4) for kk, vv in v.items()}
                        if isinstance(v, dict)
                        else round(v, 4) if isinstance(v, float) else v
                    )
                    for k, v in registry.snapshot().items()
                },
            }
        ),
        flush=True,
    )
    obs_trace.flush()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
