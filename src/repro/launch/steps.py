"""Step functions + ShapeDtypeStruct input specs for every (arch x shape).

train_step  : fwd loss -> grads -> AdamW (optionally grad-accumulated over
              microbatches — an activation-memory lever for the hillclimb)
prefill_step: full-prompt forward building the KV caches + last logits
serve_step  : one-token decode against a seq_len KV cache

All are pure functions of explicit state — jit/lower-able with ShapeDtype
stand-ins (the dry-run never allocates real parameters)."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..models import config as C
from ..models import model as M
from ..optim import adamw_update, clip_by_global_norm
from ..models.config import ModelConfig, ShapeConfig


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; shardable, no allocation)
# ---------------------------------------------------------------------------
def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            return {
                "feats": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), jnp.bfloat16),
                "dec_tokens": jax.ShapeDtypeStruct((b, s), i32),
            }
        if cfg.frontend != "none":
            return {
                "feats": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    # decode: one new token; the KV cache of seq_len is separate state.
    return {"token": jax.ShapeDtypeStruct((b, 1), i32)}


def param_specs(cfg: ModelConfig):
    return M.param_specs(cfg)


def opt_specs(cfg: ModelConfig):
    p = M.param_specs(cfg)
    zeros = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32), p)
    return {
        "mu": zeros,
        "nu": zeros,
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: M.init_caches(cfg, shape.global_batch, shape.seq_len)
    )


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, *, lr: float = 3e-4, grad_clip: float = 1.0,
                    microbatches: int = 1):
    def loss_of(params, batch):
        return M.loss_fn(cfg, params, batch)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def slice_mb(i, t):
                mb = t.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(t, i * mb, mb, axis=0)

            def body(carry, i):
                acc, = carry
                mb_batch = jax.tree.map(lambda t: slice_mb(i, t), batch)
                l, g = jax.value_and_grad(loss_of)(params, mb_batch)
                acc = jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32), acc, g)
                return (acc,), l

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum,), losses = jax.lax.scan(
                body, (zero,), jnp.arange(microbatches)
            )
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = jnp.mean(losses)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        new_params, new_opt = adamw_update(grads, opt_state, params, lr)
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ModelConfig, total_len: int):
    def prefill_step(params, batch):
        caches, logits = M.prefill(cfg, params, batch, total_len)
        return caches, logits

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, caches, token, pos):
        logits, new_caches = M.decode_step(cfg, params, caches, token, pos)
        return logits, new_caches

    return serve_step


def serve_config(cfg: ModelConfig) -> ModelConfig:
    """Serving stores parameters in bf16 (no fp32 master needed)."""
    return dataclasses.replace(cfg, param_dtype="bfloat16", remat=False)
