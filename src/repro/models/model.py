"""Model assembly: family-specific blocks, scan-over-layers stacks, and the
public forward / loss / prefill / decode entry points.

Everything is a pure function of (cfg, params, batch); params are plain dict
pytrees with per-layer leaves stacked on axis 0 (scan-over-layers keeps HLO
size and compile time flat in depth — essential for the 80-cell dry-run).

Batch formats:
  LM families      {"tokens": int32 [B, S]}
  frontend archs   {"feats": [B, S, frontend_dim], "labels": int32 [B, S]}
  encdec           {"feats"|"tokens": encoder input, "dec_tokens": [B, S]}
Decode:
  {"token": int32 [B, 1]} + per-layer caches + scalar position.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from . import ssm as S
from .config import ModelConfig
from .quant import dequantize_params, is_quantized_leaf, quantize_params

Params = Any


# ---------------------------------------------------------------------------
# per-family block init
# ---------------------------------------------------------------------------
def _init_block(key, cfg: ModelConfig, kind: str) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    if kind == "dense":
        p = {"norm1": L.init_norm(d), "attn": L.init_attention(ks[0], cfg)}
        if cfg.parallel_block:
            p["mlp"] = L.init_mlp(ks[1], cfg)
        else:
            p["norm2"] = L.init_norm(d)
            p["mlp"] = L.init_mlp(ks[1], cfg)
        return p
    if kind == "moe":
        return {
            "norm1": L.init_norm(d),
            "attn": L.init_attention(ks[0], cfg),
            "norm2": L.init_norm(d),
            "moe": L.init_moe(ks[1], cfg),
        }
    if kind == "ssm":
        return {"norm1": L.init_norm(d), "ssm": S.init_ssm(ks[0], cfg)}
    if kind == "hybrid":
        return {
            "norm1": L.init_norm(d),
            "attn": L.init_attention(ks[0], cfg),
            "ssm": S.init_ssm(ks[1], cfg),
            "norm_attn_out": L.init_norm(d),
            "norm_ssm_out": L.init_norm(d),
            "norm2": L.init_norm(d),
            "mlp": L.init_mlp(ks[2], cfg),
        }
    if kind == "enc":
        return {
            "norm1": L.init_norm(d),
            "attn": L.init_attention(ks[0], cfg),
            "norm2": L.init_norm(d),
            "mlp": L.init_mlp(ks[1], cfg),
        }
    if kind == "dec":
        return {
            "norm1": L.init_norm(d),
            "self_attn": L.init_attention(ks[0], cfg),
            "norm_cross": L.init_norm(d),
            "cross_attn": L.init_attention(ks[1], cfg),
            "norm2": L.init_norm(d),
            "mlp": L.init_mlp(ks[2], cfg),
        }
    raise ValueError(kind)


def _block_kind(cfg: ModelConfig) -> str:
    return {"dense": "dense", "moe": "moe", "ssm": "ssm", "hybrid": "hybrid"}[
        cfg.family
    ] if cfg.family != "encdec" else "enc"


# ---------------------------------------------------------------------------
# cross attention (no RoPE, bidirectional over memory)
# ---------------------------------------------------------------------------
def _cross_attention(p, x, mem_k, mem_v, cfg: ModelConfig):
    """x: [B, S, d]; mem_k/mem_v: [B, Kv, Sm, hd] precomputed from memory."""
    b, s, _ = x.shape
    cd = L.dtype_of(cfg.compute_dtype)
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
    qt = q.transpose(0, 2, 1, 3)
    from ..kernels.flash_attention import flash_attention

    o = flash_attention(
        qt, mem_k, mem_v, causal=False, use_pallas=cfg.use_pallas_attention
    )
    o = o.transpose(0, 2, 1, 3)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cd))


def _cross_kv(p, memory, cfg: ModelConfig):
    cd = L.dtype_of(cfg.compute_dtype)
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"].astype(cd))
    if "bk" in p:
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# full-sequence block application (train / prefill)
# ---------------------------------------------------------------------------
def _block_full(p, x, cfg: ModelConfig, kind: str, *, causal=True, memory=None,
                want_cache=False, total_len=0):
    cache = {}
    if kind in ("dense", "enc"):
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        attn_out = L.attention_full(p["attn"], h, cfg, causal=causal)
        if want_cache:
            cache["attn"] = L.prefill_cache(p["attn"], h, cfg, total_len)
        if cfg.parallel_block:
            x = x + attn_out + L.mlp_apply(p["mlp"], h, cfg)
        else:
            x = x + attn_out
            x = x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["norm2"], cfg.norm_eps), cfg)
        return x, cache
    if kind == "moe":
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        if want_cache:
            cache["attn"] = L.prefill_cache(p["attn"], h, cfg, total_len)
        x = x + L.attention_full(p["attn"], h, cfg, causal=causal)
        x = x + L.moe_apply(p["moe"], L.rmsnorm(x, p["norm2"], cfg.norm_eps), cfg)
        return x, cache
    if kind == "ssm":
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        y, final_state = S.ssm_apply(p["ssm"], h, cfg)
        if want_cache:
            cache["ssm"] = _ssm_prefill_cache(p["ssm"], h, cfg, final_state)
        return x + y, cache
    if kind == "hybrid":
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        attn_out = L.attention_full(p["attn"], h, cfg, causal=causal)
        ssm_out, final_state = S.ssm_apply(p["ssm"], h, cfg)
        if want_cache:
            cache["attn"] = L.prefill_cache(p["attn"], h, cfg, total_len)
            cache["ssm"] = _ssm_prefill_cache(p["ssm"], h, cfg, final_state)
        mixed = 0.5 * (
            L.rmsnorm(attn_out, p["norm_attn_out"], cfg.norm_eps)
            + L.rmsnorm(ssm_out, p["norm_ssm_out"], cfg.norm_eps)
        )
        x = x + mixed
        x = x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["norm2"], cfg.norm_eps), cfg)
        return x, cache
    if kind == "dec":
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        if want_cache:
            cache["attn"] = L.prefill_cache(p["self_attn"], h, cfg, total_len)
        x = x + L.attention_full(p["self_attn"], h, cfg, causal=True)
        hc = L.rmsnorm(x, p["norm_cross"], cfg.norm_eps)
        mem_k, mem_v = _cross_kv(p["cross_attn"], memory, cfg)
        if want_cache:
            cache["cross_k"] = mem_k
            cache["cross_v"] = mem_v
        x = x + _cross_attention(p["cross_attn"], hc, mem_k, mem_v, cfg)
        x = x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["norm2"], cfg.norm_eps), cfg)
        return x, cache
    raise ValueError(kind)


def _ssm_prefill_cache(p_ssm, h, cfg: ModelConfig, final_state):
    """Conv tail (last conv_width-1 pre-conv channels) + final SSD state."""
    cd = L.dtype_of(cfg.compute_dtype)
    din, n = cfg.ssm_d_inner, cfg.ssm_state
    zxbcdt = jnp.einsum("bld,dk->blk", h, p_ssm["in_proj"].astype(cd))
    _, xs, b_in, c_in, _ = S._split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xs, b_in, c_in], axis=-1)
    tail = conv_in[:, -(cfg.conv_width - 1):, :]
    return {"state": final_state, "conv": tail}


# ---------------------------------------------------------------------------
# decode block application
# ---------------------------------------------------------------------------
def _block_decode(p, x, cache, pos, cfg: ModelConfig, kind: str):
    new_cache = {}
    if kind in ("dense", "moe"):
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        attn_out, new_attn = L.attention_decode(p["attn"], h, cache["attn"], pos, cfg)
        new_cache["attn"] = new_attn
        if kind == "dense" and cfg.parallel_block:
            x = x + attn_out + L.mlp_apply(p["mlp"], h, cfg)
        elif kind == "dense":
            x = x + attn_out
            x = x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["norm2"], cfg.norm_eps), cfg)
        else:
            x = x + attn_out
            x = x + L.moe_apply(p["moe"], L.rmsnorm(x, p["norm2"], cfg.norm_eps), cfg)
        return x, new_cache
    if kind == "ssm":
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        y, new_ssm = S.ssm_decode(p["ssm"], h, cache["ssm"], cfg)
        new_cache["ssm"] = new_ssm
        return x + y, new_cache
    if kind == "hybrid":
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        attn_out, new_attn = L.attention_decode(p["attn"], h, cache["attn"], pos, cfg)
        ssm_out, new_ssm = S.ssm_decode(p["ssm"], h, cache["ssm"], cfg)
        new_cache["attn"] = new_attn
        new_cache["ssm"] = new_ssm
        mixed = 0.5 * (
            L.rmsnorm(attn_out, p["norm_attn_out"], cfg.norm_eps)
            + L.rmsnorm(ssm_out, p["norm_ssm_out"], cfg.norm_eps)
        )
        x = x + mixed
        x = x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["norm2"], cfg.norm_eps), cfg)
        return x, new_cache
    if kind == "dec":
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        attn_out, new_attn = L.attention_decode(
            p["self_attn"], h, cache["attn"], pos, cfg
        )
        new_cache["attn"] = new_attn
        x = x + attn_out
        hc = L.rmsnorm(x, p["norm_cross"], cfg.norm_eps)
        x = x + _cross_attention(p["cross_attn"], hc, cache["cross_k"], cache["cross_v"], cfg)
        new_cache["cross_k"] = cache["cross_k"]
        new_cache["cross_v"] = cache["cross_v"]
        x = x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["norm2"], cfg.norm_eps), cfg)
        return x, new_cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stacks (scan over layers)
# ---------------------------------------------------------------------------
def _stack_full(blocks, x, cfg: ModelConfig, kind: str, *, causal=True,
                memory=None, want_cache=False, total_len=0, remat=None):
    remat = cfg.remat if remat is None else remat

    def body(xc, p_layer):
        p_layer = dequantize_params(p_layer, L.dtype_of(cfg.compute_dtype))
        out, cache = _block_full(
            p_layer, xc, cfg, kind, causal=causal, memory=memory,
            want_cache=want_cache, total_len=total_len,
        )
        return out, (cache if want_cache else None)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, caches = jax.lax.scan(body, x, blocks)
    return x, caches


def _stack_decode(blocks, caches, x, pos, cfg: ModelConfig, kind: str):
    def body(xc, inp):
        p_layer, cache_layer = inp
        p_layer = dequantize_params(p_layer, L.dtype_of(cfg.compute_dtype))
        out, new_cache = _block_decode(p_layer, xc, cache_layer, pos, cfg, kind)
        return out, new_cache

    x, new_caches = jax.lax.scan(body, x, (blocks, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, rng) -> Params:
    if cfg.family == "hybrid" and cfg.hybrid_attn_period >= 1:
        raise ValueError(
            f"init_params: {cfg.name!r} declares an interleaved hybrid "
            f"layer mix (hybrid_attn_period={cfg.hybrid_attn_period}) but "
            "the executable substrate only implements parallel hybrid "
            "blocks (attention + SSM every layer); interleaved configs are "
            "profile-only — see partition/profile.py"
        )
    k_embed, k_blocks, k_dec, k_norm = jax.random.split(rng, 4)
    params = {"embed": L.init_embed(k_embed, cfg), "final_norm": L.init_norm(cfg.d_model)}
    kind = _block_kind(cfg)
    layer_keys = jax.random.split(k_blocks, cfg.n_layers)
    params["blocks"] = jax.vmap(lambda k: _init_block(k, cfg, kind))(layer_keys)
    if cfg.family == "encdec":
        dec_keys = jax.random.split(k_dec, cfg.n_dec_layers)
        params["dec_blocks"] = jax.vmap(lambda k: _init_block(k, cfg, "dec"))(dec_keys)
        params["enc_final_norm"] = L.init_norm(cfg.d_model)
    return params


def param_specs(cfg: ModelConfig):
    """Shape/dtype tree without allocating (for the dry-run)."""
    def build():
        p = init_params(cfg, jax.random.PRNGKey(0))
        if cfg.quantize_int8:
            p = quantize_params(p)
        return p

    return jax.eval_shape(build)


def _embed_input(cfg: ModelConfig, params, batch):
    if cfg.frontend != "none":
        return L.embed_frontend(params["embed"], batch["feats"], cfg)
    return L.embed_tokens(params["embed"], batch["tokens"], cfg)


def encode(cfg: ModelConfig, params, batch):
    """Encoder stack (encdec family): bidirectional attention."""
    x = _embed_input(cfg, params, batch)
    x, _ = _stack_full(params["blocks"], x, cfg, "enc", causal=False)
    return L.rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)


def forward(cfg: ModelConfig, params, batch):
    """Full-sequence forward -> final hidden states [B, S, d]."""
    if cfg.family == "encdec":
        memory = encode(cfg, params, batch)
        y = L.embed_tokens(params["embed"], batch["dec_tokens"], cfg)
        y, _ = _stack_full(params["dec_blocks"], y, cfg, "dec", memory=memory)
        return L.rmsnorm(y, params["final_norm"], cfg.norm_eps)
    x = _embed_input(cfg, params, batch)
    kind = _block_kind(cfg)
    x, _ = _stack_full(params["blocks"], x, cfg, kind, causal=True)
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def logits_fn(cfg: ModelConfig, params, batch):
    return L.unembed(params["embed"], forward(cfg, params, batch), cfg)


def _targets(cfg: ModelConfig, batch):
    if cfg.family == "encdec":
        tok = batch["dec_tokens"]
        return tok[:, 1:], None
    if cfg.frontend != "none":
        return batch["labels"][:, 1:], None
    return batch["tokens"][:, 1:], None


def loss_fn(cfg: ModelConfig, params, batch):
    """Mean next-token cross entropy (fp32 logsumexp, optional vocab
    chunking — a memory/perf knob for the huge-vocab archs)."""
    h = forward(cfg, params, batch)[:, :-1]
    targets, _ = _targets(cfg, batch)
    embed = params["embed"]
    if cfg.vocab_chunking:
        return _chunked_ce(cfg, embed, h, targets)
    logits = L.unembed(embed, h, cfg).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tl = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - tl)


def _chunked_ce(cfg: ModelConfig, embed, h, targets):
    """Cross entropy without materializing [B, S, V] logits: streams over
    vocab chunks keeping a running logsumexp + the target logit."""
    v = cfg.vocab
    nch = cfg.vocab_chunking
    csize = math.ceil(v / nch)
    w = embed["embed"].T if cfg.tie_embeddings else embed["lm_head"]
    cd = L.dtype_of(cfg.compute_dtype)
    b, s, d = h.shape
    lse = jnp.full((b, s), -jnp.inf, jnp.float32)
    tl = jnp.zeros((b, s), jnp.float32)
    for c in range(nch):
        lo = c * csize
        hi = min(v, lo + csize)
        logits_c = jnp.einsum("bsd,dv->bsv", h, w[:, lo:hi].astype(cd)).astype(jnp.float32)
        lse = jnp.logaddexp(lse, jax.nn.logsumexp(logits_c, axis=-1))
        in_chunk = (targets >= lo) & (targets < hi)
        idx = jnp.clip(targets - lo, 0, hi - lo - 1)
        got = jnp.take_along_axis(logits_c, idx[..., None], axis=-1)[..., 0]
        tl = tl + jnp.where(in_chunk, got, 0.0)
    return jnp.mean(lse - tl)


# ---------------------------------------------------------------------------
# prefill + decode
# ---------------------------------------------------------------------------
def prefill(cfg: ModelConfig, params, batch, total_len: int):
    """Run the full prompt, returning (caches, last-position logits)."""
    if cfg.family == "encdec":
        memory = encode(cfg, params, batch)
        y = L.embed_tokens(params["embed"], batch["dec_tokens"], cfg)
        y, caches = _stack_full(
            params["dec_blocks"], y, cfg, "dec", memory=memory,
            want_cache=True, total_len=total_len, remat=False,
        )
        y = L.rmsnorm(y, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(params["embed"], y[:, -1:], cfg)
        return caches, logits
    x = _embed_input(cfg, params, batch)
    kind = _block_kind(cfg)
    x, caches = _stack_full(
        params["blocks"], x, cfg, kind, causal=True,
        want_cache=True, total_len=total_len, remat=False,
    )
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x[:, -1:], cfg)
    return caches, logits


def init_caches(cfg: ModelConfig, batch: int, seq_len: int):
    """Zero caches for decode-from-scratch (the dry-run decode cells)."""
    kind = _block_kind(cfg) if cfg.family != "encdec" else "dec"
    n_layers = cfg.n_dec_layers if cfg.family == "encdec" else cfg.n_layers

    def one_layer(_):
        c = {}
        if kind in ("dense", "moe", "hybrid", "dec"):
            c["attn"] = L.init_cache(cfg, batch, seq_len)
        if kind in ("ssm", "hybrid"):
            c["ssm"] = S.init_ssm_cache(cfg, batch)
        if kind == "dec":
            cd = L.dtype_of(cfg.compute_dtype)
            shape = (batch, cfg.n_kv_heads, seq_len, cfg.head_dim)
            c["cross_k"] = jnp.zeros(shape, cd)
            c["cross_v"] = jnp.zeros(shape, cd)
        return c

    return jax.vmap(one_layer)(jnp.arange(n_layers))


def decode_step(cfg: ModelConfig, params, caches, token, pos):
    """One decode step. token: [B, 1] int32; pos: scalar int32.

    Returns (logits [B, 1, vocab], new caches)."""
    x = L.embed_tokens(params["embed"], token, cfg)
    if cfg.family == "encdec":
        x, new_caches = _stack_decode(params["dec_blocks"], caches, x, pos, cfg, "dec")
    else:
        kind = _block_kind(cfg)
        x, new_caches = _stack_decode(params["blocks"], caches, x, pos, cfg, kind)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(params["embed"], x, cfg), new_caches
