"""Weight-only int8 quantization for serving (section Perf iteration on the
decode cells).

Serving 104B-class models on 16 GiB/chip pods cannot keep bf16 weights
TP-resident (13 GiB/chip at TP=16) next to a 32k KV cache; FSDP-gathering
them per step makes decode collective-bound (measured: 25.6 GB gathered per
token). Weight-only int8 halves the resident footprint so weights stay
sharded and no per-step gather is needed.

Storage: each large float leaf W -> {"__q": int8, "__s": f32 scalar} with
symmetric per-tensor scale (per-channel is the production upgrade; scalar
keeps the sharding rules trivial). Dequantization happens PER LAYER inside
the scan body, so the transient bf16 copy is one layer's slice, not the
model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_MIN_QUANT_SIZE = 1 << 16  # don't quantize norms/biases/small tables


def is_quantized_leaf(x) -> bool:
    return isinstance(x, dict) and "__q" in x


def quantize_leaf(w, per_layer: bool = False):
    """per_layer=True: one scale per leading (stacked-layer) index, so scan
    bodies can slice layer l as (__q[l], __s[l])."""
    wf = w.astype(jnp.float32)
    if per_layer and w.ndim >= 2:
        axes = tuple(range(1, w.ndim))
        amax = jnp.max(jnp.abs(wf), axis=axes)  # [L]
        scale = jnp.maximum(amax / 127.0, 1e-12)
        s_b = scale.reshape(scale.shape + (1,) * (w.ndim - 1))
    else:
        scale = jnp.maximum(jnp.max(jnp.abs(wf)) / 127.0, 1e-12)
        s_b = scale
    q = jnp.clip(jnp.round(wf / s_b), -127, 127).astype(jnp.int8)
    return {"__q": q, "__s": scale.astype(jnp.float32)}


def dequantize_leaf(x, dtype=jnp.bfloat16):
    q, s = x["__q"], x["__s"]
    s = s.reshape(s.shape + (1,) * (q.ndim - s.ndim))
    return (q.astype(jnp.float32) * s).astype(dtype)


def _eligible(leaf) -> bool:
    return (
        hasattr(leaf, "dtype")
        and jnp.issubdtype(leaf.dtype, jnp.floating)
        and leaf.ndim >= 2
        and leaf.size >= _MIN_QUANT_SIZE
    )


def quantize_params(params):
    """Quantize every large float leaf of a param tree. Leaves under the
    stacked-layer subtrees get per-layer scales (scan-sliceable)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for keypath, leaf in flat:
        path = "/".join(str(getattr(k, "key", k)) for k in keypath)
        stacked = "blocks" in path
        out.append(quantize_leaf(leaf, per_layer=stacked) if _eligible(leaf) else leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def dequantize_params(params, dtype=jnp.bfloat16):
    """Inverse of quantize_params (applied per-layer inside scan bodies)."""
    return jax.tree.map(
        lambda x: dequantize_leaf(x, dtype) if is_quantized_leaf(x) else x,
        params,
        is_leaf=lambda x: is_quantized_leaf(x) or not isinstance(x, dict),
    )
