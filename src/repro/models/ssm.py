"""Mamba-2 / SSD (state-space duality) mixer, arXiv:2405.21060.

Training/prefill uses the chunked dual form: intra-chunk (quadratic,
attention-like) + inter-chunk state passing (linear recurrence over chunk
boundaries) — O(L) memory in sequence length, constant-size decode state.
Decode is the plain SSM recurrence:

    h <- exp(dt*A) h + dt * B x ,   y = C h + D x

Layout: d_inner = expand * d_model, heads of size ssm_head_dim, one B/C group
(ngroups=1), scalar A per head. Gated RMSNorm before out-projection.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..distributed.act import shard
from .config import ModelConfig
from .layers import _dense_init, dtype_of, rmsnorm

NEG_INF = -1e30


def init_ssm(key, cfg: ModelConfig):
    d = cfg.d_model
    din = cfg.ssm_d_inner
    n = cfg.ssm_state
    nh = cfg.ssm_heads
    cw = cfg.conv_width
    conv_dim = din + 2 * n
    pd = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * din + 2 * n + nh), pd),
        "conv_w": _dense_init(ks[1], (cw, conv_dim), pd, scale=1.0 / math.sqrt(cw)),
        "conv_b": jnp.zeros((conv_dim,), pd),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)
        ),  # A = -exp(A_log) in [-16, -1]
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 0.01, jnp.float32))),
        "ssm_norm": jnp.zeros((din,), jnp.float32),
        "out_proj": _dense_init(
            ks[4], (din, d), pd, scale=1.0 / math.sqrt(din * 2 * cfg.n_layers)
        ),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    din, n, nh = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :din]
    xs = zxbcdt[..., din : 2 * din]
    b_in = zxbcdt[..., 2 * din : 2 * din + n]
    c_in = zxbcdt[..., 2 * din + n : 2 * din + 2 * n]
    dt = zxbcdt[..., 2 * din + 2 * n :]
    return z, xs, b_in, c_in, dt


def _causal_conv(x, w, bias, conv_state=None):
    """Depthwise causal conv. x: [B, L, C], w: [K, C]. Returns (y, new_state)
    where state is the last K-1 inputs (for streaming decode)."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, L+K-1, C]
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    y = y + bias[None, None, :]
    new_state = xp[:, -(k - 1) :, :] if k > 1 else None
    return jax.nn.silu(y), new_state


def _segsum(x):
    """[..., q] -> [..., q, q]: T[i, j] = sum_{k=j+1..i} x[k], -inf above diag."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    t = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, t, NEG_INF)


def ssd_chunked(xs, dt, A, B, C, chunk, init_state=None):
    """SSD dual form.

    xs: [b, l, h, p]  dt: [b, l, h]  A: [h]  B, C: [b, l, n]
    Returns (y [b, l, h, p], final_state [b, h, p, n]).
    """
    b, l, h, p = xs.shape
    n = B.shape[-1]
    pad = (-l) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    lp = l + pad
    nc = lp // chunk
    q = chunk

    xb = xs.reshape(b, nc, q, h, p)
    dtb = dt.reshape(b, nc, q, h).astype(jnp.float32)
    Bb = B.reshape(b, nc, q, n)
    Cb = C.reshape(b, nc, q, n)

    dA = dtb * A[None, None, None, :]  # [b, nc, q, h] (negative)
    dA_cs = jnp.cumsum(dA, axis=2)

    # 1) intra-chunk (diagonal blocks).
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dA, -1, -2)))  # [b, nc, h, q, q]
    scores = jnp.einsum("bcqn,bcsn->bcqs", Cb.astype(jnp.float32), Bb.astype(jnp.float32))
    M = Lmat * scores[:, :, None, :, :] * jnp.moveaxis(dtb, -1, -2)[:, :, :, None, :]
    y_diag = jnp.einsum("bchqs,bcshp->bcqhp", M, xb.astype(jnp.float32))

    # 2) per-chunk input -> end-of-chunk state.
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # [b, nc, q, h]
    states = jnp.einsum(
        "bcqn,bcqh,bcqhp->bchpn",
        Bb.astype(jnp.float32),
        decay_states * dtb,
        xb.astype(jnp.float32),
    )

    # 3) inter-chunk recurrence (scan over chunks).
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # [b, nc, h]

    def step(carry, inp):
        s_in, (cd, st) = carry, inp
        s_out = cd[:, :, None, None] * s_in + st
        return s_out, s_in  # emit the state BEFORE this chunk

    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        step,
        init_state.astype(jnp.float32),
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # [b, nc, h, p, n]

    # 4) contribution of the carried-in state to each position.
    state_decay_out = jnp.exp(dA_cs)  # [b, nc, q, h]
    y_off = jnp.einsum(
        "bcqn,bchpn,bcqh->bcqhp", Cb.astype(jnp.float32), prev_states, state_decay_out
    )

    y = (y_diag + y_off).reshape(b, lp, h, p)[:, :l]
    return y, final_state


def ssm_apply(p, x, cfg: ModelConfig, init_state=None):
    """Full-sequence SSD mixer. x: [B, L, d] -> ([B, L, d], final_state)."""
    cd = dtype_of(cfg.compute_dtype)
    b, l, _ = x.shape
    din, n, nh, hp = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = shard(jnp.einsum("bld,dk->blk", x, p["in_proj"].astype(cd)), "ssm_inner")
    z, xs, b_in, c_in, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xs, b_in, c_in], axis=-1)
    conv_out, _ = _causal_conv(conv_in, p["conv_w"].astype(cd), p["conv_b"].astype(cd))
    xs = conv_out[..., :din]
    b_in = conv_out[..., din : din + n]
    c_in = conv_out[..., din + n :]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(b, l, nh, hp)
    y, final_state = ssd_chunked(xh, dt, A, b_in, c_in, cfg.ssm_chunk, init_state)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, l, din).astype(cd)

    y = rmsnorm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    out = shard(jnp.einsum("blk,kd->bld", y, p["out_proj"].astype(cd)), "residual")
    return out, final_state


def init_ssm_cache(cfg: ModelConfig, batch: int):
    nh, hp, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.ssm_d_inner + 2 * n
    return {
        "state": jnp.zeros((batch, nh, hp, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype_of(cfg.compute_dtype)),
    }


def ssm_decode(p, x, cache, cfg: ModelConfig):
    """One-token recurrent step. x: [B, 1, d]."""
    cd = dtype_of(cfg.compute_dtype)
    b = x.shape[0]
    din, n, nh, hp = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = jnp.einsum("bld,dk->blk", x, p["in_proj"].astype(cd))
    z, xs, b_in, c_in, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xs, b_in, c_in], axis=-1)  # [B, 1, conv_dim]
    conv_out, new_conv = _causal_conv(
        conv_in, p["conv_w"].astype(cd), p["conv_b"].astype(cd), conv_state=cache["conv"]
    )
    xs = conv_out[..., :din]
    b_in = conv_out[..., din : din + n]
    c_in = conv_out[..., din + n :]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])[:, 0]  # [B, nh]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])  # [B, nh]
    xh = xs.reshape(b, nh, hp).astype(jnp.float32)
    Bv = b_in[:, 0].astype(jnp.float32)  # [B, n]
    Cv = c_in[:, 0].astype(jnp.float32)

    state = cache["state"] * dA[:, :, None, None] + jnp.einsum(
        "bn,bh,bhp->bhpn", Bv, dt, xh
    )
    y = jnp.einsum("bn,bhpn->bhp", Cv, state) + p["D"][None, :, None] * xh
    y = y.reshape(b, 1, din).astype(cd)
    y = rmsnorm(y * jax.nn.silu(z), p["ssm_norm"], cfg.norm_eps)
    out = jnp.einsum("blk,kd->bld", y, p["out_proj"].astype(cd))
    return out, {"state": state, "conv": new_conv}
