"""Model configuration for the assigned architecture zoo.

One frozen dataclass covers all five families:
  dense   – standard decoder-only transformer (GQA/MQA, MLP variants)
  moe     – dense attention + mixture-of-experts FFN (top-k, shared experts)
  ssm     – attention-free Mamba-2 / SSD stack
  hybrid  – Hymba-style parallel attention + SSM heads per block
  encdec  – encoder-decoder (Seamless backbone)
vlm/audio archs are a dense/encdec backbone plus a stub modality frontend
(precomputed patch/frame embeddings enter through a learned projector).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int | None = None  # tokens; None = full attention
    parallel_block: bool = False  # command-r style attn ∥ mlp
    # mlp
    d_ff: int = 0
    mlp_act: str = "swiglu"  # swiglu | geglu | relu2 | gelu
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_d_ff: int = 0  # qwen2-moe shared expert (0 = none)
    capacity_factor: float = 1.25
    # MoE dispatch groups (launcher sets = number of data shards so each DP
    # shard dispatches locally; 0/1 = single global dispatch).
    moe_groups: int = 0
    # ssm (mamba2 / hymba SSM branch)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 128
    # hybrid layer mix: 0 = parallel hybrid (hymba — every block computes the
    # attention AND SSM branches); p >= 1 = interleaved — only every p-th
    # block (layer % p == p - 1) is an attention block, the rest are
    # SSM-only. Interleaved configs are profile-only substrate-wise
    # (init_params raises); per-layer costing lives in partition/profile.py.
    hybrid_attn_period: int = 0
    # encdec
    n_dec_layers: int = 0
    # modality frontend stub ("none" | "patch" | "frames")
    frontend: str = "none"
    frontend_dim: int = 0
    # misc
    embed_scale: bool = False  # gemma: embeddings * sqrt(d)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # dtypes (strings to stay hashable/static)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # execution knobs
    remat: bool = True
    use_pallas_attention: bool = False
    quantize_int8: bool = False  # weight-only int8 storage (serving)
    # loss
    vocab_chunking: int = 0  # 0 = unchunked cross-entropy

    # ---- derived ----
    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def attends(self) -> bool:
        return self.family in ("dense", "moe", "hybrid", "encdec")

    def layer_mix(self, layer: int) -> tuple[bool, bool]:
        """(has_attention, has_ssm) for block `layer` (0-indexed).

        Uniform-stack families return the same pair for every layer; an
        interleaved hybrid (hybrid_attn_period >= 1) alternates block types,
        so per-layer FLOP/param accounting must ask per layer."""
        if self.family == "hybrid" and self.hybrid_attn_period >= 1:
            p = self.hybrid_attn_period
            is_attn = layer % p == p - 1
            return is_attn, not is_attn
        return self.attends, self.family in ("ssm", "hybrid")

    def n_attn_layers(self) -> int:
        """How many blocks carry an attention branch."""
        if self.family == "hybrid" and self.hybrid_attn_period >= 1:
            return sum(
                1 for l in range(self.n_layers) if self.layer_mix(l)[0]
            )
        return self.n_layers if self.attends else 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context (constant/bounded state)?"""
        if self.family == "ssm":
            return True
        if self.family == "encdec":
            return False
        return self.sliding_window is not None

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += d * v
        if self.frontend != "none":
            total += self.frontend_dim * d
        attn_p = mix_p = ssm_p = 0
        if self.family in ("dense", "moe", "hybrid", "encdec"):
            h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim
            attn_p += d * h * hd + 2 * d * kv * hd + h * hd * d  # qkvo
        if self.family in ("dense", "hybrid", "encdec"):
            mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
            mix_p += mult * d * self.d_ff
        if self.family == "moe":
            mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
            mix_p += d * self.n_experts  # router
            mix_p += self.n_experts * mult * d * self.moe_d_ff
            if self.shared_d_ff:
                mix_p += mult * d * self.shared_d_ff
        if self.family in ("ssm", "hybrid"):
            din, n, nh = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            ssm_p += d * (2 * din + 2 * n + nh)  # in_proj (z,x,B,C,dt)
            ssm_p += self.conv_width * (din + 2 * n)  # conv
            ssm_p += 3 * nh  # A_log, D, dt_bias
            ssm_p += din * d  # out_proj
        if self.family == "hybrid" and self.hybrid_attn_period >= 1:
            # Interleaved: attention params only on attention blocks, SSM
            # params only on the rest; MLP in every block.
            na = self.n_attn_layers()
            total += na * attn_p + (self.n_layers - na) * ssm_p
            total += self.n_layers * mix_p
        else:
            total += self.n_layers * (attn_p + mix_p + ssm_p)
        if self.family == "encdec":
            # decoder: self-attn + cross-attn + mlp
            h, kv, hd = self.n_heads, self.n_kv_heads, self.head_dim
            dec = 2 * (d * h * hd + 2 * d * kv * hd + h * hd * d)
            mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
            dec += mult * d * self.d_ff
            total += self.n_dec_layers * dec
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE counts only routed top_k experts)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
        expert_p = mult * d * self.moe_d_ff
        inactive = self.n_layers * (self.n_experts - self.top_k) * expert_p
        return self.n_params() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a valid dry-run cell, with the skip reason."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention (DESIGN.md section 4)"
    return True, ""
