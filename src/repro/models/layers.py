"""Layer primitives shared by all architecture families.

Pure functions over param pytrees (no framework dependency). Parameter
layout conventions (per layer, pre-stacking):

  attn:  wq [d, H, hd]   wk/wv [d, Kv, hd]   wo [H, hd, d]  (+ optional biases)
  mlp:   wi [d, ff] (+ wg [d, ff] for GLU)   wo [ff, d]
  moe:   router [d, E]   wi_e [E, d, ff] (+ wg_e)   wo_e [E, ff, d]
  norm:  scale [d]
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from ..kernels.flash_attention import flash_attention
from ..distributed.act import shard
from .config import ModelConfig
from .quant import dequantize_leaf, is_quantized_leaf

Params = dict


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# norm
# ---------------------------------------------------------------------------
def rmsnorm(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def init_norm(d):
    return jnp.zeros((d,), jnp.float32)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x, positions, theta):
    """x: [B, S, H, hd], positions: [S] or [B, S] absolute token positions."""
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )  # [half]
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freq[None, :]  # [S, half]
        ang = ang[None, :, None, :]  # [1, S, 1, half]
    else:
        ang = positions[..., None].astype(jnp.float32) * freq  # [B, S, half]
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    pd = dtype_of(cfg.param_dtype)
    p = {
        "wq": _dense_init(ks[0], (d, h, hd), pd),
        "wk": _dense_init(ks[1], (d, kv, hd), pd),
        "wv": _dense_init(ks[2], (d, kv, hd), pd),
        "wo": _dense_init(ks[3], (h, hd, d), pd, scale=1.0 / math.sqrt(h * hd * 2 * cfg.n_layers)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), pd)
        p["bk"] = jnp.zeros((kv, hd), pd)
        p["bv"] = jnp.zeros((kv, hd), pd)
    return p


def _qkv(p, x, cfg: ModelConfig):
    cd = dtype_of(cfg.compute_dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    return shard(q, "heads"), shard(k, "heads"), shard(v, "heads")


def attention_full(p, x, cfg: ModelConfig, *, causal: bool = True, positions=None):
    """Full-sequence attention (training / prefill). x: [B, S, d]."""
    b, s, _ = x.shape
    cd = dtype_of(cfg.compute_dtype)
    q, k, v = _qkv(p, x, cfg)
    if positions is None:
        positions = jnp.arange(s)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    # [B, S, H, hd] -> [B, H, S, hd]
    qt, kt, vt = (shard(t.transpose(0, 2, 1, 3), "heads_t") for t in (q, k, v))
    o = flash_attention(
        qt,
        kt,
        vt,
        causal=causal,
        window=cfg.sliding_window,
        use_pallas=cfg.use_pallas_attention,
    )
    o = shard(o, "heads_t").transpose(0, 2, 1, 3)  # [B, S, H, hd]
    return shard(jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cd)), "residual")


def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """KV ring-buffer length: bounded by the sliding window when present."""
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    c = cache_len(cfg, seq_len)
    cd = dtype_of(cfg.compute_dtype)
    shape = (batch, cfg.n_kv_heads, c, cfg.head_dim)
    return {"k": jnp.zeros(shape, cd), "v": jnp.zeros(shape, cd)}


def prefill_cache(p, x, cfg: ModelConfig, seq_len_total: int):
    """Compute the attention output AND the ring cache left by a prefill.

    For ring slot i (cache length C, prefill length S): the slot holds the
    key of absolute position t_i = S-1 - ((S-1-i) mod C), matching the
    decode-time write rule slot(t) = t mod C.
    """
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    positions = jnp.arange(s)
    k_rot = rope(k, positions, cfg.rope_theta)
    c = cache_len(cfg, seq_len_total)
    slot = jnp.arange(c)
    # Slots not yet written (t_i < 0, possible when prefill < cache length)
    # hold clipped-stale data; decode masks them out via abs_pos >= 0.
    t_i = (s - 1) - ((s - 1 - slot) % c)
    kc = jnp.take(k_rot, t_i, axis=1, mode="clip").transpose(0, 2, 1, 3)
    vc = jnp.take(v, t_i, axis=1, mode="clip").transpose(0, 2, 1, 3)
    return {"k": kc, "v": vc}


def attention_decode(p, x, cache, pos, cfg: ModelConfig):
    """One-token decode. x: [B, 1, d]; cache k/v: [B, Kv, C, hd]; pos scalar.

    The cache is a ring buffer (slot = pos mod C); RoPE is applied at write
    time with absolute positions, and masking reconstructs each slot's
    absolute position as  abs_i = pos - ((pos - i) mod C).
    """
    b = x.shape[0]
    cd = dtype_of(cfg.compute_dtype)
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    group = h // kv
    q, k, v = _qkv(p, x, cfg)  # [B, 1, *, hd]
    pos_arr = jnp.full((1,), pos, jnp.int32)
    q = rope(q, pos_arr, cfg.rope_theta)
    k = rope(k, pos_arr, cfg.rope_theta)

    c = cache["k"].shape[2]
    slot = jnp.mod(pos, c)
    kc = jax.lax.dynamic_update_slice(
        cache["k"], k.transpose(0, 2, 1, 3).astype(cache["k"].dtype), (0, 0, slot, 0)
    )
    vc = jax.lax.dynamic_update_slice(
        cache["v"], v.transpose(0, 2, 1, 3).astype(cache["v"].dtype), (0, 0, slot, 0)
    )
    kc = shard(kc, "kv_cache")
    vc = shard(vc, "kv_cache")

    idx = jnp.arange(c)
    abs_pos = pos - jnp.mod(pos - idx, c)  # in [pos - C + 1, pos]
    valid = abs_pos >= 0
    if cfg.sliding_window is not None:
        valid &= abs_pos > pos - cfg.sliding_window

    qg = q.reshape(b, kv, group, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bkcd->bkgc", qg, kc.astype(jnp.float32))
    scores = shard(scores / math.sqrt(hd), "decode_scores")
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    # Distributed softmax over the seq-sharded cache (flash-decoding style):
    # GSPMD turns the max/sum reductions into tiny all-reduces.
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgc,bkcd->bkgd", w, vc.astype(jnp.float32)).astype(cd)
    o = o.reshape(b, 1, h, hd)
    out = shard(jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cd)), "residual")
    return out, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------
def _act(name: str):
    if name == "swiglu":
        return jax.nn.silu
    if name == "geglu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


def is_glu(name: str) -> bool:
    return name in ("swiglu", "geglu")


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> Params:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    pd = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {
        "wi": _dense_init(ks[0], (d, ff), pd),
        "wo": _dense_init(ks[1], (ff, d), pd, scale=1.0 / math.sqrt(ff * 2 * cfg.n_layers)),
    }
    if is_glu(cfg.mlp_act):
        p["wg"] = _dense_init(ks[2], (d, ff), pd)
    return p


def mlp_apply(p, x, cfg: ModelConfig):
    cd = dtype_of(cfg.compute_dtype)
    act = _act(cfg.mlp_act)
    h = shard(jnp.einsum("bsd,df->bsf", x, p["wi"].astype(cd)), "ffn")
    if is_glu(cfg.mlp_act):
        g = shard(jnp.einsum("bsd,df->bsf", x, p["wg"].astype(cd)), "ffn")
        h = act(g) * h
    else:
        h = act(h)
    return shard(jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(cd)), "residual")


# ---------------------------------------------------------------------------
# Mixture of Experts (sort-based dispatch, capacity-dropped, GShard-style)
# ---------------------------------------------------------------------------
def init_moe(key, cfg: ModelConfig) -> Params:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    pd = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p = {
        "router": _dense_init(ks[0], (d, e), pd, scale=0.02),
        "wi_e": _dense_init(ks[1], (e, d, ff), pd, scale=1.0 / math.sqrt(d)),
        "wo_e": _dense_init(ks[2], (e, ff, d), pd, scale=1.0 / math.sqrt(ff * 2 * cfg.n_layers)),
    }
    if is_glu(cfg.mlp_act):
        p["wg_e"] = _dense_init(ks[3], (e, d, ff), pd, scale=1.0 / math.sqrt(d))
    if cfg.shared_d_ff:
        sub = dataclasses.replace(cfg, d_ff=cfg.shared_d_ff)
        p["shared"] = init_mlp(ks[4], sub, d_ff=cfg.shared_d_ff)
        p["shared_gate"] = _dense_init(ks[5], (d, 1), pd, scale=0.02)
    return p


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    return max(4, math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))


def _moe_dispatch_combine(p, xf, cfg: ModelConfig, cap: int):
    """One dispatch group: xf [T, d] -> [T, d].

    Sort-based dispatch into per-expert capacity buffers (overflow dropped),
    batched expert GEMMs, weighted combine. Router softmax over the selected
    top-k (Mixtral convention)."""
    cd = dtype_of(cfg.compute_dtype)
    e, k = cfg.n_experts, cfg.top_k
    t, d = xf.shape

    logits = jnp.einsum("td,de->te", xf, p["router"].astype(cd)).astype(jnp.float32)
    top_logits, top_idx = jax.lax.top_k(logits, k)  # [T, k]
    top_w = jax.nn.softmax(top_logits, axis=-1)

    flat_e = top_idx.reshape(t * k)
    flat_w = top_w.reshape(t * k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first_of_group = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(t * k) - first_of_group
    keep = pos_in_e < cap
    buf_idx = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # drop slot
    token_of = order // k

    xbuf = jnp.zeros((e * cap, d), cd).at[buf_idx].set(
        xf[token_of].astype(cd), mode="drop"
    )
    xbuf = xbuf.reshape(e, cap, d)
    h = jnp.einsum("ecd,edf->ecf", xbuf, p["wi_e"].astype(cd))
    act = _act(cfg.mlp_act)
    if "wg_e" in p:
        g = jnp.einsum("ecd,edf->ecf", xbuf, p["wg_e"].astype(cd))
        h = act(g) * h
    else:
        h = act(h)
    ybuf = jnp.einsum("ecf,efd->ecd", h, p["wo_e"].astype(cd)).reshape(e * cap, d)

    gathered = jnp.take(ybuf, jnp.minimum(buf_idx, e * cap - 1), axis=0)
    contrib = gathered * (flat_w[order] * keep).astype(cd)[:, None]
    return jnp.zeros((t, d), cd).at[token_of].add(contrib)


def moe_apply(p, x, cfg: ModelConfig):
    """x: [B, S, d] -> [B, S, d].

    Dispatch is performed per GROUP (cfg.moe_groups, set by the launcher to
    the number of data shards): each group routes its own tokens into its own
    capacity buffers, so under SPMD every data shard dispatches locally and
    the expert GEMMs carry a leading group dim sharded over data — without
    this, buffers whose expert dim doesn't divide the model axis (Mixtral:
    E=8 on a 16-way axis) were replicated onto every device, inflating
    per-device FLOPs ~50x (section Perf iteration 1)."""
    b, s, d = x.shape
    t = b * s
    groups = max(1, cfg.moe_groups)
    if t % groups != 0:
        groups = 1
    tg = t // groups
    cap = moe_capacity(cfg, tg)
    xg = shard(x.reshape(groups, tg, d), "moe_groups")
    out = jax.vmap(lambda xf: _moe_dispatch_combine(p, xf, cfg, cap))(xg)
    out = shard(out, "moe_groups").reshape(t, d)

    if "shared" in p:
        cd = dtype_of(cfg.compute_dtype)
        xf = x.reshape(t, d)
        gate = jax.nn.sigmoid(
            jnp.einsum("td,do->to", xf, p["shared_gate"].astype(cd)).astype(jnp.float32)
        ).astype(cd)
        shared = mlp_apply(p["shared"], x, dataclasses.replace(cfg, d_ff=cfg.shared_d_ff))
        out = out + (gate * shared.reshape(t, d))

    return out.reshape(b, s, d)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def init_embed(key, cfg: ModelConfig) -> Params:
    pd = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {"embed": _dense_init(ks[0], (cfg.vocab, cfg.d_model), pd, scale=0.02)}
    if not cfg.tie_embeddings:
        p["lm_head"] = _dense_init(ks[1], (cfg.d_model, cfg.vocab), pd)
    if cfg.frontend != "none":
        p["frontend_proj"] = _dense_init(ks[2], (cfg.frontend_dim, cfg.d_model), pd)
    return p


def embed_tokens(p, tokens, cfg: ModelConfig):
    cd = dtype_of(cfg.compute_dtype)
    table = p["embed"]
    if is_quantized_leaf(table):
        # Gather int8 rows, dequantize only the gathered slice.
        rows = jnp.take(table["__q"], tokens, axis=0).astype(jnp.float32)
        x = (rows * table["__s"]).astype(cd)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), cd)
        return shard(x, "residual")
    x = jnp.take(table.astype(cd), tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cd)
    return shard(x, "residual")


def embed_frontend(p, feats, cfg: ModelConfig):
    """Stub modality frontend: precomputed patch/frame embeddings -> d."""
    cd = dtype_of(cfg.compute_dtype)
    w = p["frontend_proj"]
    if is_quantized_leaf(w):
        w = dequantize_leaf(w, cd)
    return shard(
        jnp.einsum("bsf,fd->bsd", feats.astype(cd), w.astype(cd)),
        "residual",
    )


def unembed(p, x, cfg: ModelConfig):
    cd = dtype_of(cfg.compute_dtype)
    w = p["embed"] if cfg.tie_embeddings else p["lm_head"]
    if is_quantized_leaf(w):
        w = dequantize_leaf(w, cd)
    else:
        w = w.astype(cd)
    if cfg.tie_embeddings:
        w = w.T
    return shard(jnp.einsum("bsd,dv->bsv", x, w), "logits")
