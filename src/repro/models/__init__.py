from .config import SHAPES, ModelConfig, ShapeConfig, shape_applicable  # noqa: F401
from .model import (  # noqa: F401
    decode_step,
    encode,
    forward,
    init_caches,
    init_params,
    logits_fn,
    loss_fn,
    param_specs,
    prefill,
)
