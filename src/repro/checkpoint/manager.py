"""Fault-tolerant checkpointing.

Properties required at 1000-node scale, implemented here:
  * atomic:      write to step_NNNNNN.tmp/, fsync, rename — a preempted save
                 never corrupts the latest good checkpoint;
  * keep-K:      bounded disk, oldest pruned after a successful save;
  * self-descr.: tree structure + dtypes stored in a manifest, so restore
                 can validate against the running config;
  * mesh-shape-agnostic: arrays are saved UNSHARDED (logical values); restore
                 device_puts onto whatever mesh/sharding the new job uses —
                 this is what makes elastic re-scaling work (tests cover
                 save on one mesh shape, restore on another);
  * resumable data stream: the pipeline state rides along.

On a real cluster the np.save calls become a parallel writer per host with
process-local shards; the manifest/atomic-rename/keep-K logic is identical.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- helpers -----------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp"):
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save/restore ------------------------------------------------------
    def save(self, step: int, tree, extra: dict | None = None):
        """tree: pytree of arrays. extra: small json-able state (data stream,
        rng, schedule position...)."""
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat, treedef = jax.tree_util.tree_flatten(tree)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(flat),
            "leaves": [],
            "extra": extra or {},
        }
        for i, leaf in enumerate(flat):
            arr = np.asarray(leaf)
            np.save(tmp / f"leaf_{i:05d}.npy", arr)
            manifest["leaves"].append(
                {"i": i, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = self._step_dir(step)
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic on POSIX
        self._prune()

    def restore(self, step: int | None, like_tree, *, shardings=None):
        """Restore into the structure of like_tree. If shardings given
        (a congruent tree of NamedSharding), device_put accordingly —
        the mesh may differ from the one that saved."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        flat, treedef = jax.tree_util.tree_flatten(like_tree)
        assert manifest["n_leaves"] == len(flat), (
            f"checkpoint has {manifest['n_leaves']} leaves, model needs {len(flat)}"
        )
        loaded = []
        for i, ref in enumerate(flat):
            arr = np.load(d / f"leaf_{i:05d}.npy")
            want = tuple(getattr(ref, "shape", arr.shape))
            assert tuple(arr.shape) == want, (i, arr.shape, want)
            loaded.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, loaded)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, manifest["extra"], step

    def _prune(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
