"""The paper's contribution: congestion-aware joint partition placement and
routing for partitioned DNN inference over multi-hop edge networks.

The whole stack is generic over the partition count: P (stages K = P + 1)
is per-`Problem` data (`Apps.parts`), with the paper's P = 2 evaluation as
the default scenario profile — see DESIGN.md section 13."""
from .structs import (  # noqa: F401
    Apps,
    BIG,
    BIG_THRESHOLD,
    CostModel,
    HopBoundCache,
    Network,
    Problem,
    State,
    app_live_mask,
    forwarding_mass,
    hop_bound_cache,
    infer_hop_bound,
    partition_live_mask,
    stage_live_mask,
    stage_targets,
    with_hop_bound,
)
from .flow import (  # noqa: F401
    SOLVERS,
    loads,
    objective,
    objective_from_loads,
    stage_solve,
    stage_traffic,
    total_absorbed,
)
from .forwarding import forwarding_sweep, forwarding_update  # noqa: F401
from .marginals import cost_to_go, link_marginals, round_eval  # noqa: F401
from .placement import (  # noqa: F401
    blocked_placement_update,
    blocked_sweep_cert,
    placement_update,
    repair_phi,
    structured_init,
    zero_load_dp,
)
from .engine import (  # noqa: F401
    EngineCarry,
    engine_solve,
    engine_solve_single,
    round_step,
    stack_single,
)
from .alt import (  # noqa: F401
    ALL_METHODS,
    METHOD_KWARGS,
    Result,
    compare_all,
    method_kwargs,
    solve_alt,
    solve_colocated,
    solve_congunaware,
    solve_oneshot,
)
from .scenarios import (  # noqa: F401
    SCENARIOS,
    build_network,
    gen_apps,
    geant,
    iot,
    mesh,
    random_connected,
    smallworld,
    stage_profile,
)
