"""Device-resident ALT round engine: ONE `while_loop` core behind both the
sequential solvers (core/alt.py) and the batched fleet solver (fleet/solve.py).

The paper's Algorithm 1 is a single alternating loop (placement sweep ->
T_phi forwarding sweeps -> objective). This module is the one place that
loop lives: a pure `round_step(carry) -> carry` implementing the restructured
round dataflow (one `round_eval` feeding both the history/stall logic and the
next placement sweep — DESIGN.md section 10), plus best-iterate tracking,
per-instance stall counters, and freeze masking, all carried on device.

`engine_solve` wraps `round_step` in a jitted `lax.while_loop` whose
predicate is "any live instance below m_max": a fully converged batch — or
the B=1 sequential case — exits as soon as every instance has stalled,
instead of padding to `m_max` rounds the way the old fixed-length scan did.
Because a while_loop cannot stack per-trip outputs, the per-round objective
trace is written into a preallocated `[B, m_max + 1]` history buffer via a
dynamic column update; unwritten slots stay NaN (the same "NaN past the
freeze point" contract the fleet result has always exposed).

The same mechanism carries the optional round trace (`EngineTrace`,
DESIGN.md section 14): per-round J_comm/J_comp split, placement churn
(live (app, partition) hosts that moved), a live/applied mask, and the
best-iterate round index — all written by the identical masked dynamic
column update, so they obey the exact NaN-past-freeze contract, add no
host syncs inside the loop, and stay bitwise-inert on frozen lanes.
`trace=False` removes the buffers entirely; the solved result is
bitwise-identical either way (the trace is written FROM the round's
values, never read by it).

Batch semantics (DESIGN.md section 11):
  * the whole round body is vmapped over the leading instance axis, so a
    stacked fleet and a single `[1, ...]`-stacked problem run the exact same
    compiled loop — sequential solving IS the engine at B=1, squeezed.
    `engine_solve(lane_chunk=k >= 1)` flips the nesting to lane-major (each
    lane's full solve inside `lax.map`, DESIGN.md section 18) with
    bitwise-identical per-lane outputs;
  * frozen instances (stalled for `patience` rounds) are masked out of every
    carry update, so extra trips driven by still-live instances leave their
    results bit-identical;
  * the early exit is batch-wide, matching the sequential per-instance
    `break` exactly at B=1 and costing live instances nothing at B>1. It is
    also *shard-safe*: the `[B]` active mask is reduced to ONE replicated
    `any_active` scalar inside the round body (where the partitioner emits a
    single all-reduce when the instance axis is laid out over a fleet mesh),
    and the while_loop predicate only ever reads that scalar — no per-trip
    host sync, no collective inside the cond.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .forwarding import forwarding_update
from .marginals import round_eval
from .placement import placement_update, structured_init
from .structs import (
    Problem,
    State,
    app_live_mask,
    partition_live_mask,
)


def _bwhere(pred, a, b):
    """Pytree select with a [B] predicate broadcast from the left."""

    def sel(x, y):
        p = pred.reshape(pred.shape + (1,) * (x.ndim - pred.ndim))
        return jnp.where(p, x, y)

    return jax.tree_util.tree_map(sel, a, b)


def _objective_of(aux):
    """The objective split alone — the best-iterate slot never carries the
    [A, K, V, V]-sized ctg tensors, which would double the loop-carry
    footprint for nothing."""
    return {"J": aux["J"], "J_comm": aux["J_comm"], "J_comp": aux["J_comp"]}


@dataclasses.dataclass(frozen=True)
class EngineTrace:
    """On-device round-trace buffers (obs layer 1, DESIGN.md section 14).

    Every `[B, m_max + 1]` buffer follows the J-history contract: column m
    is written by the masked dynamic update of round m and keeps its init
    value (NaN, or 0.0 for `live`) wherever the round was not applied —
    past an instance's freeze point a masked write stores exactly the init
    value, so frozen lanes stay bitwise-independent of later trips.

    J_comm     : [B, m_max + 1] communication objective per applied round
    J_comp     : [B, m_max + 1] computation objective per applied round
    moves      : [B, m_max + 1] placement churn — live (app, partition)
                 hosts that changed this round; column 0 is 0.0 (the init
                 has no previous placement)
    live       : [B, m_max + 1] 1.0 iff the round was applied to the
                 instance; the other buffers' NaN mask in arithmetic form
                 (host side derives per-round frozen-instance counts from it)
    best_round : [B] int32 round index of the running best iterate
    """

    J_comm: jax.Array
    J_comp: jax.Array
    moves: jax.Array
    live: jax.Array
    best_round: jax.Array


jax.tree_util.register_dataclass(
    EngineTrace,
    data_fields=["J_comm", "J_comp", "moves", "live", "best_round"],
    meta_fields=[],
)


def placement_churn(problem: Problem, new: State, old: State) -> jax.Array:
    """[B] count of live (app, partition) hosts that differ between two
    batched states — phantom apps (lambda = 0) and phantom partitions
    (p >= parts) are masked out so stage/app padding cannot leak churn."""
    live = (
        partition_live_mask(problem.apps)
        * app_live_mask(problem.apps)[..., None]
    )  # [B, A, P]
    return jnp.sum((new.hosts() != old.hosts()) * live, axis=(-2, -1))


@dataclasses.dataclass(frozen=True)
class EngineCarry:
    """The while_loop carry: everything one ALT round reads and writes.

    state      : [B, ...] current iterate (placement x + forwarding phi)
    aux        : `round_eval` output at `state` — objective split plus the
                 (q, dp, kappa, t, F, G) ctg tuple the next placement sweep
                 consumes (no re-solve of the traffic fixed point)
    best_state : [B, ...] best-iterate state seen so far
    best_obj   : {"J","J_comm","J_comp"} at `best_state`
    best_J     : [B] running minimum objective
    stall      : [B] int32 rounds since the last tol-sized improvement
    iters      : [B] int32 rounds actually applied per instance
    active     : [B] bool; False once an instance froze (stall >= patience)
    any_active : scalar bool, `jnp.any(active)` reduced once per trip in the
                 body; the while_loop predicate reads only this replicated
                 scalar, keeping the early exit shard-safe when `active` is
                 laid out over a fleet mesh axis
    m          : scalar int32 trip counter (= rounds the while_loop ran)
    history    : [B, m_max + 1] objective trace; NaN past each freeze point
    trace      : `EngineTrace` round-trace buffers, or None when tracing
                 is off (the slot vanishes from the pytree entirely)
    """

    state: State
    aux: dict
    best_state: State
    best_obj: dict
    best_J: jax.Array
    stall: jax.Array
    iters: jax.Array
    active: jax.Array
    any_active: jax.Array
    m: jax.Array
    history: jax.Array
    trace: EngineTrace | None


jax.tree_util.register_dataclass(
    EngineCarry,
    data_fields=[
        "state", "aux", "best_state", "best_obj", "best_J", "stall",
        "iters", "active", "any_active", "m", "history", "trace",
    ],
    meta_fields=[],
)


def round_step(
    problem: Problem,
    carry: EngineCarry,
    *,
    t_phi: int,
    alpha: float,
    tol: float,
    patience: int,
    colocate: bool,
    use_pallas: bool,
    solver: str,
    interpret: bool = True,
    block_apps: int = 1,
) -> EngineCarry:
    """One batched ALT round: Algorithm 1's loop body plus bookkeeping.

    Placement is fed the PREVIOUS round's evaluation (carry.aux["ctg"]),
    then T_phi forwarding sweeps run, then one `round_eval` closes the round.
    Stall is measured against the best J *before* this round's update, and
    every carry slot of a frozen instance is masked back to its old value.
    `block_apps` selects the placement sweep schedule (placement.py module
    doc): 1 = sequential scan, k > 1 / 0 = blocked sweep.
    The round body is one vmapped program over all B lanes — the layout
    choice over the instance axis (fused rounds vs lane-major chunks) lives
    in `engine_solve(lane_chunk=...)`, which decides whether this step runs
    over the whole batch per trip or inside a per-lane solve.
    """

    def one_round(p, s, ctg):
        nxt = placement_update(
            p, s, ctg, colocate=colocate, use_pallas=use_pallas,
            interpret=interpret, solver=solver, block_apps=block_apps,
        )
        nxt = forwarding_update(
            p, nxt, t_phi=t_phi, alpha=alpha, solver=solver,
            use_pallas=use_pallas, interpret=interpret,
        )
        J, aux_nxt = round_eval(
            p, nxt, solver=solver, use_pallas=use_pallas, interpret=interpret
        )
        return nxt, J, aux_nxt

    nxt, J, aux_nxt = jax.vmap(one_round)(
        problem, carry.state, carry.aux["ctg"]
    )

    improved = J < carry.best_J * (1.0 - tol)
    stall_nxt = jnp.where(improved, 0, carry.stall + 1)
    is_best = J < carry.best_J
    best_state_nxt = _bwhere(is_best, nxt, carry.best_state)
    best_obj_nxt = _bwhere(is_best, _objective_of(aux_nxt), carry.best_obj)
    best_J_nxt = jnp.minimum(J, carry.best_J)

    # Freeze masking: instances that already stalled keep every slot.
    active = carry.active
    col = carry.m + 1
    history = carry.history.at[:, col].set(jnp.where(active, J, jnp.nan))
    trace = carry.trace
    if trace is not None:
        # Same masked dynamic-column writes as the history: inactive lanes
        # store exactly the buffer's init value (NaN / 0.0), so the trace
        # inherits the freeze-point contract bit for bit. Everything here is
        # computed from values the round already produced — no extra solves,
        # no host syncs, and the main dataflow never reads a trace buffer.
        moved = placement_churn(problem, nxt, carry.state)
        trace = EngineTrace(
            J_comm=trace.J_comm.at[:, col].set(
                jnp.where(active, aux_nxt["J_comm"], jnp.nan)
            ),
            J_comp=trace.J_comp.at[:, col].set(
                jnp.where(active, aux_nxt["J_comp"], jnp.nan)
            ),
            moves=trace.moves.at[:, col].set(
                jnp.where(active, moved.astype(trace.moves.dtype), jnp.nan)
            ),
            live=trace.live.at[:, col].set(active.astype(trace.live.dtype)),
            best_round=jnp.where(
                active & is_best, col.astype(jnp.int32), trace.best_round
            ),
        )
    active_nxt = active & (stall_nxt < patience)
    return EngineCarry(
        state=_bwhere(active, nxt, carry.state),
        aux=_bwhere(active, aux_nxt, carry.aux),
        best_state=_bwhere(active, best_state_nxt, carry.best_state),
        best_obj=_bwhere(active, best_obj_nxt, carry.best_obj),
        best_J=jnp.where(active, best_J_nxt, carry.best_J),
        stall=jnp.where(active, stall_nxt, carry.stall),
        iters=carry.iters + active.astype(jnp.int32),
        active=active_nxt,
        # The only cross-instance reduction in the loop: one scalar per trip,
        # computed in the body so the predicate stays collective-free.
        any_active=jnp.any(active_nxt),
        m=carry.m + 1,
        history=history,
        trace=trace,
    )


def _engine_solve_batch(
    stacked: Problem,
    *,
    m_max: int,
    t_phi: int,
    alpha: float,
    tol: float,
    patience: int,
    colocate: bool,
    track_best: bool,
    use_pallas: bool,
    interpret: bool,
    solver: str,
    trace: bool,
    block_apps: int,
    keep_state: bool,
    init_state: State | None,
    active0: jax.Array | None,
) -> dict:
    """The fused-batch engine core: init + one lockstep `lax.while_loop`
    whose round body vmaps over every lane (see `engine_solve`)."""

    if init_state is None:

        def init_one(p):
            s = structured_init(
                p, colocate=colocate, use_pallas=use_pallas, interpret=interpret
            )
            J, aux = round_eval(
                p, s, solver=solver, use_pallas=use_pallas, interpret=interpret
            )
            return s, J, aux

        state0, J0, aux0 = jax.vmap(init_one)(stacked)
    else:
        state0 = init_state
        J0, aux0 = jax.vmap(
            lambda p, s: round_eval(
                p, s, solver=solver, use_pallas=use_pallas, interpret=interpret
            )
        )(stacked, state0)
    batch = J0.shape[0]
    history0 = jnp.full((batch, m_max + 1), jnp.nan, dtype=J0.dtype)
    trace0 = None
    if trace:
        nan_buf = jnp.full((batch, m_max + 1), jnp.nan, dtype=J0.dtype)
        trace0 = EngineTrace(
            J_comm=nan_buf.at[:, 0].set(aux0["J_comm"]),
            J_comp=nan_buf.at[:, 0].set(aux0["J_comp"]),
            moves=nan_buf.at[:, 0].set(0.0),
            live=jnp.zeros((batch, m_max + 1), J0.dtype).at[:, 0].set(1.0),
            best_round=jnp.zeros(batch, jnp.int32),
        )
    if active0 is None:
        active_init = jnp.ones(batch, bool)
    else:
        active_init = jnp.asarray(active0).reshape(batch).astype(bool)
    carry = EngineCarry(
        state=state0,
        aux=aux0,
        best_state=state0,
        best_obj=_objective_of(aux0),
        best_J=J0,
        stall=jnp.zeros(batch, jnp.int32),
        iters=jnp.zeros(batch, jnp.int32),
        active=active_init,
        any_active=jnp.any(active_init),
        m=jnp.int32(0),
        history=history0.at[:, 0].set(J0),
        trace=trace0,
    )
    step = functools.partial(
        round_step,
        stacked,
        t_phi=t_phi,
        alpha=alpha,
        tol=tol,
        patience=patience,
        colocate=colocate,
        use_pallas=use_pallas,
        solver=solver,
        interpret=interpret,
        block_apps=block_apps,
    )
    carry = jax.lax.while_loop(
        lambda c: (c.m < m_max) & c.any_active, step, carry
    )
    if track_best:
        out_state, out_obj = carry.best_state, carry.best_obj
    else:
        out_state, out_obj = carry.state, _objective_of(carry.aux)
    out = {
        "J": out_obj["J"],
        "J_comm": out_obj["J_comm"],
        "J_comp": out_obj["J_comp"],
        "hosts": out_state.hosts(),
        "history": carry.history,
        "iters": carry.iters,
        "rounds": carry.m,
        "trace": carry.trace,
    }
    if keep_state:
        out["state"] = out_state
    return out


@functools.partial(
    jax.jit,
    static_argnames=(
        "m_max", "t_phi", "alpha", "tol", "patience", "colocate",
        "track_best", "use_pallas", "interpret", "solver", "trace",
        "block_apps", "lane_chunk", "keep_state",
    ),
)
def engine_solve(
    stacked: Problem,
    *,
    m_max: int,
    t_phi: int,
    alpha: float,
    tol: float,
    patience: int,
    colocate: bool = False,
    track_best: bool = True,
    use_pallas: bool = False,
    interpret: bool = True,
    solver: str = "neumann",
    trace: bool = True,
    block_apps: int = 1,
    lane_chunk: int = 0,
    keep_state: bool = True,
    init_state: State | None = None,
    active0: jax.Array | None = None,
) -> dict:
    """Run the alternating method on a stacked `[B, ...]` problem pytree.

    Warm start (DESIGN.md section 15): `init_state` seeds the while_loop
    carry from a caller-provided `[B, ...]` State (e.g. the previous control
    epoch's placement after failure repair) instead of `structured_init`;
    `active0` is an optional [B] bool mask freezing instances from round 0 —
    a frozen-from-start lane never runs a round and returns exactly its
    init-state evaluation, so an epoch whose fault touched 2 of 64 instances
    burns rounds only on those 2. Both are traced pytree arguments (None vs
    provided changes the trace, same as `trace=`); the cold path (both None)
    is the exact pre-warm-start program. When every lane starts frozen the
    loop body never runs and the init evaluation IS the result — the
    controller's "every epoch ends with a servable placement" guarantee.

    `lane_chunk` picks the layout over the instance axis (DESIGN.md
    section 18). 0 = the fused batch: ONE lockstep while_loop whose round
    body vmaps over all B lanes — the only layout compatible with a
    committed instance-axis mesh. k >= 1 = lane-major: each lane's WHOLE
    solve (init eval + its own while_loop) runs inside `lax.map` over
    k-lane chunks, so a lane's [A, K, V, V] working set stays
    cache-resident across its rounds, the per-round slice/stack traffic of
    mapping the round body is paid once per solve instead of once per trip,
    and a converged lane stops computing immediately (the per-instance
    early exit of the sequential path, inside one compiled program).
    Per-lane outputs are bitwise-identical across layouts: each lane runs
    the same op sequence either way, freeze masking keeps lockstep trips
    inert past a lane's own stall point, and the NaN-past-freeze buffer
    contract writes the same values in both schedules.

    `keep_state=False` drops the full `[B, ...]` State from the output dict
    (the fleet path's default — it only surfaces `hosts` unless the caller
    asked for the warm-start currency), which in the lane-major layout also
    skips stacking B phi-shaped buffers on the way out.

    Returns a dict of device arrays (leading axis B throughout):
      J / J_comm / J_comp : final objective split (best iterate, or the
                            final state when `track_best=False` — the
                            OneShot semantics)
      state               : the returned State (best or final); absent
                            when `keep_state=False`
      hosts               : [B, A, P] partition hosts of the returned state
      history             : [B, m_max + 1] objective trace, NaN past freeze
      iters               : [B] int32 rounds applied per instance
      rounds              : scalar int32 while_loop trips actually executed
                            (< m_max whenever the whole batch froze early;
                            lane-major: the max over per-lane loop trips,
                            the same number by the freeze-point argument)
      trace               : `EngineTrace` round-trace buffers (None when
                            `trace=False`); every other output is
                            bitwise-identical across the two settings
    """
    kw = dict(
        m_max=m_max, t_phi=t_phi, alpha=alpha, tol=tol, patience=patience,
        colocate=colocate, track_best=track_best, use_pallas=use_pallas,
        interpret=interpret, solver=solver, trace=trace,
        block_apps=block_apps, keep_state=keep_state,
    )
    if lane_chunk == 0:
        return _engine_solve_batch(
            stacked, init_state=init_state, active0=active0, **kw
        )

    def lane_solve(args):
        p, s0, a0 = args

        def lift(t):
            return (
                None if t is None
                else jax.tree_util.tree_map(lambda x: x[None], t)
            )

        out = _engine_solve_batch(
            lift(p),
            init_state=lift(s0),
            active0=None if a0 is None else a0[None],
            **kw,
        )
        squeezed = {
            k: jax.tree_util.tree_map(lambda x: x[0], v)
            for k, v in out.items()
            if k != "rounds"
        }
        squeezed["rounds"] = out["rounds"]
        return squeezed

    out = jax.lax.map(
        lane_solve,
        (stacked, init_state, active0),
        batch_size=lane_chunk if lane_chunk > 1 else None,
    )
    # Per-lane loop trips stack to [B]; the engine contract is ONE scalar
    # (trips the batch would have executed in lockstep = the slowest lane).
    out["rounds"] = jnp.max(out["rounds"])
    return out


def stack_single(problem: Problem) -> Problem:
    """Lift one problem to a `[1, ...]` stacked pytree (engine batch of one).

    Static metadata (`hop_bound`, `CostModel.kind`) passes through untouched;
    Python-float cost scalars become rank-1 arrays like `stack_problems`
    produces, so B=1 and B>1 hit the same engine code path."""
    return jax.tree_util.tree_map(lambda x: jnp.asarray(x)[None], problem)


def engine_solve_single(problem: Problem, **kw) -> dict:
    """Sequential entry point: the engine at B=1, squeezed.

    Same return dict as `engine_solve` minus the batch axis (`rounds` was
    already a scalar; at B=1 it equals `iters`)."""
    out = engine_solve(stack_single(problem), **kw)
    squeezed = {
        k: jax.tree_util.tree_map(lambda x: x[0], v)
        for k, v in out.items()
        if k != "rounds"
    }
    squeezed["rounds"] = out["rounds"]
    return squeezed
