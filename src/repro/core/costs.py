"""Congestion-dependent communication / computation costs (paper section II).

The paper's canonical choice is the M/M/1 queue-length cost D(F) = F/(mu-F),
interpreted via Little's law as (scaled) expected delay. It is increasing,
convex, differentiable, D(0)=0 — but blows up at F = mu. During optimization
(and deliberately in the load-sweep experiment) iterates can exceed capacity,
so we continue the curve beyond rho_max * mu with the C^1 quadratic extension
that matches value, slope and curvature at the junction. The extension is
still increasing + convex, so all marginal-cost machinery stays valid.
"""
from __future__ import annotations

import jax.numpy as jnp

from .structs import CostModel


def _mm1(load, cap, rho_max):
    """Smoothed M/M/1 queue length  load/(cap-load)  with quadratic tail."""
    cap = jnp.maximum(cap, 1e-9)
    knee = rho_max * cap
    gap = cap - knee  # = (1-rho_max) * cap > 0
    # Values at the knee (value / slope / curvature of the true M/M/1 curve).
    v = knee / gap
    s = cap / (gap * gap)
    c = 2.0 * cap / (gap * gap * gap)
    d = load - knee
    ext = v + s * d + 0.5 * c * d * d
    safe = jnp.minimum(load, knee)  # avoid div-by-~0 in the untaken branch
    base = safe / (cap - safe)
    return jnp.where(load <= knee, base, ext)


def _mm1_prime(load, cap, rho_max):
    cap = jnp.maximum(cap, 1e-9)
    knee = rho_max * cap
    gap = cap - knee
    s = cap / (gap * gap)
    c = 2.0 * cap / (gap * gap * gap)
    safe = jnp.minimum(load, knee)
    base = cap / jnp.square(cap - safe)
    ext = s + c * (load - knee)
    return jnp.where(load <= knee, base, ext)


def link_cost(F, mu, cost: CostModel):
    """D_ij(F_ij) elementwise; zero where capacity is BIG-sentinel/no link."""
    if cost.kind == "linear":
        return F / jnp.maximum(mu, 1e-9)
    return _mm1(F, mu, cost.rho_max)


def link_cost_prime(F, mu, cost: CostModel):
    if cost.kind == "linear":
        return 1.0 / jnp.maximum(mu, 1e-9) * jnp.ones_like(F)
    return _mm1_prime(F, mu, cost.rho_max)


def comp_cost(G, nu, cost: CostModel):
    if cost.kind == "linear":
        return G / jnp.maximum(nu, 1e-9)
    return _mm1(G, nu, cost.rho_max)


def comp_cost_prime(G, nu, cost: CostModel):
    if cost.kind == "linear":
        return 1.0 / jnp.maximum(nu, 1e-9) * jnp.ones_like(G)
    return _mm1_prime(G, nu, cost.rho_max)
