"""Algorithm 1 (ALT) and the paper's three baselines (section IV).

  ALT         alternating congestion-aware placement + forwarding (ours)
  OneShot     same init/objective, a single placement/forwarding round
  CongUnaware shortest extended path under linear (congestion-blind) costs
  CoLocated   all partitions forced to one node, forwarding optimized

All four share the structured initialization so comparisons isolate exactly
one design axis each (alternation / congestion awareness / split flexibility).

The iterative methods (ALT, OneShot, CoLocated) are thin wrappers over the
shared device-resident round engine (core/engine.py): the whole alternating
loop — placement sweep fed by the previous round's `round_eval`, T_phi
forwarding sweeps, best-iterate tracking, tol/patience stall logic — runs as
ONE jitted `lax.while_loop` at B=1 and exits the moment the instance stalls.
There is no per-round host sync any more: the only device->host transfer is
the final result read-out. The batched fleet solver (fleet/solve.py) runs
the exact same engine at B>1, so sequential and fleet can never diverge.

`solver` selects the fixed-point path: "neumann" (default, hop-capped
propagation) or "lu" (dense reference). See DESIGN.md sections 10-11.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .engine import engine_solve_single
from .flow import objective
from .placement import structured_init
from .structs import CostModel, Problem, State


@dataclasses.dataclass
class Result:
    name: str
    state: State
    J: float
    J_comm: float
    J_comp: float
    history: list
    iters: int

    def summary(self) -> str:
        return (
            f"{self.name:12s} J={self.J:10.4f}  comm={self.J_comm:10.4f} "
            f"comp={self.J_comp:10.4f}  iters={self.iters}"
        )


def _result(problem, state, aux, name, history, iters) -> Result:
    return Result(
        name=name,
        state=state,
        J=float(aux["J"]),
        J_comm=float(aux["J_comm"]),
        J_comp=float(aux["J_comp"]),
        history=[float(h) for h in history],
        iters=iters,
    )


def _engine_result(problem: Problem, name: str, **engine_kw) -> Result:
    """Run the shared round engine at B=1 and package a sequential Result."""
    out = engine_solve_single(problem, **engine_kw)
    history = np.asarray(out["history"])
    history = history[~np.isnan(history)]
    return Result(
        name=name,
        state=out["state"],
        J=float(out["J"]),
        J_comm=float(out["J_comm"]),
        J_comp=float(out["J_comp"]),
        history=[float(h) for h in history],
        iters=int(out["iters"]),
    )


def solve_alt(
    problem: Problem,
    *,
    m_max: int = 30,
    t_phi: int = 10,
    alpha: float = 0.5,
    tol: float = 1e-3,
    patience: int = 4,
    colocate: bool = False,
    use_pallas: bool = False,
    interpret: bool = True,
    solver: str = "neumann",
    block_apps: int = 1,
    name: str = "ALT",
) -> Result:
    """The full alternating method (Algorithm 1), with best-iterate tracking.

    One outer round = placement reassignment under the current congested
    marginals, then T_phi forwarding sweeps (a cyclic rotation of Algorithm
    1's line order so J is always measured on smoothed routing). Terminates
    when the best J stops improving by tol for `patience` rounds — via the
    engine's batch-wide early exit, which at B=1 is exactly the sequential
    per-instance break.
    """
    return _engine_result(
        problem,
        name,
        m_max=m_max,
        t_phi=t_phi,
        alpha=alpha,
        tol=tol,
        patience=patience,
        colocate=colocate,
        track_best=True,
        use_pallas=use_pallas,
        interpret=interpret,
        solver=solver,
        block_apps=block_apps,
    )


def solve_oneshot(
    problem: Problem,
    *,
    t_phi: int = 10,
    alpha: float = 0.5,
    use_pallas: bool = False,
    interpret: bool = True,
    solver: str = "neumann",
    block_apps: int = 1,
) -> Result:
    """One placement/forwarding round: isolates the value of alternation.

    The engine at m_max=1 with `track_best=False` (the final — i.e. only —
    iterate is returned, matching the historical OneShot semantics)."""
    return _engine_result(
        problem,
        "OneShot",
        m_max=1,
        t_phi=t_phi,
        alpha=alpha,
        tol=1e-3,
        patience=1,
        colocate=False,
        track_best=False,
        use_pallas=use_pallas,
        interpret=interpret,
        solver=solver,
        block_apps=block_apps,
    )


def linearize(problem: Problem) -> Problem:
    """The same problem under congestion-blind linear costs (D=F/mu, C=G/nu).

    Shared by the sequential and fleet CongUnaware baselines so their
    linearization can never diverge."""
    return Problem(
        net=problem.net,
        apps=problem.apps,
        cost=CostModel(
            kind="linear",
            rho_max=problem.cost.rho_max,
            w_comm=problem.cost.w_comm,
            w_comp=problem.cost.w_comp,
        ),
        hop_bound=problem.hop_bound,
    )


def solve_congunaware(
    problem: Problem,
    *,
    use_pallas: bool = False,
    interpret: bool = True,
    solver: str = "neumann",
) -> Result:
    """Shortest extended path under linear costs, evaluated with true costs.

    Implementation note: with linear costs the zero-load marginals ARE the
    link weights (D' = 1/mu, C' = 1/nu constants), so the extended-graph
    shortest path over the stage-copy / partition-transition chain reduces
    exactly to the structured initialization's stage DP under the linear
    cost model (any partition count — DESIGN.md section 13).
    """
    state = structured_init(
        linearize(problem), use_pallas=use_pallas, interpret=interpret
    )
    J, aux = objective(
        problem, state, solver=solver, use_pallas=use_pallas,
        interpret=interpret,
    )
    return _result(problem, state, aux, "CongUnaware", [], 0)


def solve_colocated(
    problem: Problem,
    *,
    m_max: int = 30,
    t_phi: int = 10,
    alpha: float = 0.5,
    tol: float = 1e-3,
    patience: int = 4,
    use_pallas: bool = False,
    interpret: bool = True,
    solver: str = "neumann",
    block_apps: int = 1,
) -> Result:
    """All partitions at a single node; forwarding still congestion-aware."""
    return solve_alt(
        problem,
        m_max=m_max,
        t_phi=t_phi,
        alpha=alpha,
        tol=tol,
        patience=patience,
        colocate=True,
        use_pallas=use_pallas,
        interpret=interpret,
        solver=solver,
        block_apps=block_apps,
        name="CoLocated",
    )


ALL_METHODS = {
    "ALT": solve_alt,
    "OneShot": solve_oneshot,
    "CongUnaware": solve_congunaware,
    "CoLocated": solve_colocated,
}

# The one shared source of truth for which solver kwargs each method accepts.
# `compare_all` and the fleet's `solve_sequential` both filter through this,
# so the sequential and fleet baselines cannot drift apart by hand-copied
# per-method defaults (the pre-PR-3 bug: `m_max` was forwarded to CoLocated
# but `tol`/`patience` were not).
METHOD_KWARGS = {
    "ALT": (
        "m_max", "t_phi", "alpha", "tol", "patience", "use_pallas",
        "interpret", "solver", "block_apps",
    ),
    "OneShot": (
        "t_phi", "alpha", "use_pallas", "interpret", "solver", "block_apps",
    ),
    # CongUnaware runs no placement sweep (structured init only), so the
    # sweep-schedule knob does not apply to it.
    "CongUnaware": ("use_pallas", "interpret", "solver"),
    "CoLocated": (
        "m_max", "t_phi", "alpha", "tol", "patience", "use_pallas",
        "interpret", "solver", "block_apps",
    ),
}


def validate_solver_kwargs(kw: dict) -> None:
    """Reject kwargs no method accepts — a typo must raise, never silently
    run with defaults."""
    unknown = set(kw) - set().union(*METHOD_KWARGS.values())
    if unknown:
        raise TypeError(f"unknown solver kwargs {sorted(unknown)}")


def method_kwargs(method: str, kw: dict) -> dict:
    """Restrict one shared (validated) kwargs dict to what `method` accepts."""
    validate_solver_kwargs(kw)
    return {k: v for k, v in kw.items() if k in METHOD_KWARGS[method]}


def compare_all(problem: Problem, **kw) -> dict:
    """Run all four methods on one shared kwargs dict.

    Unknown kwargs raise (they would previously have been silently dropped
    for every method but ALT)."""
    return {
        name: fn(problem, **method_kwargs(name, kw))
        for name, fn in ALL_METHODS.items()
    }
