"""Algorithm 1 (ALT) and the paper's three baselines (section IV).

  ALT         alternating congestion-aware placement + forwarding (ours)
  OneShot     same init/objective, a single placement/forwarding round
  CongUnaware shortest extended path under linear (congestion-blind) costs
  CoLocated   both partitions forced to one node, forwarding optimized

All four share the structured initialization so comparisons isolate exactly
one design axis each (alternation / congestion awareness / split flexibility).

Per-round dataflow (DESIGN.md section 10): each round ends with ONE full
marginal evaluation (`round_eval`) whose objective read-out drives the
history/stall logic and whose (q, dp, kappa, t, F, G) tuple is handed to the
next round's placement sweep — placement and the round-final objective no
longer redo the same traffic solve. `solver` selects the fixed-point path:
"neumann" (default, hop-capped propagation) or "lu" (dense reference).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .flow import objective
from .forwarding import forwarding_update
from .marginals import round_eval
from .placement import placement_update, structured_init
from .structs import CostModel, Problem, State


@dataclasses.dataclass
class Result:
    name: str
    state: State
    J: float
    J_comm: float
    J_comp: float
    history: list
    iters: int

    def summary(self) -> str:
        return (
            f"{self.name:12s} J={self.J:10.4f}  comm={self.J_comm:10.4f} "
            f"comp={self.J_comp:10.4f}  iters={self.iters}"
        )


def _result(problem, state, aux, name, history, iters) -> Result:
    return Result(
        name=name,
        state=state,
        J=float(aux["J"]),
        J_comm=float(aux["J_comm"]),
        J_comp=float(aux["J_comp"]),
        history=[float(h) for h in history],
        iters=iters,
    )


def solve_alt(
    problem: Problem,
    *,
    m_max: int = 30,
    t_phi: int = 10,
    alpha: float = 0.5,
    tol: float = 1e-3,
    patience: int = 4,
    colocate: bool = False,
    use_pallas: bool = False,
    solver: str = "neumann",
    name: str = "ALT",
) -> Result:
    """The full alternating method (Algorithm 1), with best-iterate tracking.

    One outer round = placement reassignment under the current congested
    marginals, then T_phi forwarding sweeps (a cyclic rotation of Algorithm
    1's line order so J is always measured on smoothed routing). Terminates
    when the best J stops improving by tol for `patience` rounds.
    """
    state = structured_init(problem, colocate=colocate, use_pallas=use_pallas)
    J, aux = round_eval(problem, state, solver=solver, use_pallas=use_pallas)
    best_state, best_J, best_aux = state, float(J), aux
    history = [float(J)]
    iters = 0
    stall = 0
    for m in range(m_max):
        state = placement_update(
            problem,
            state,
            aux["ctg"],
            colocate=colocate,
            use_pallas=use_pallas,
            solver=solver,
        )
        state = forwarding_update(
            problem, state, t_phi=t_phi, alpha=alpha, solver=solver
        )
        J, aux = round_eval(problem, state, solver=solver, use_pallas=use_pallas)
        jf = float(J)
        history.append(jf)
        iters = m + 1
        if jf < best_J * (1.0 - tol):
            stall = 0
        else:
            stall += 1
        if jf < best_J:
            best_state, best_J, best_aux = state, jf, aux
        if stall >= patience:
            break
    return _result(problem, best_state, best_aux, name, history, iters)


def solve_oneshot(
    problem: Problem,
    *,
    t_phi: int = 10,
    alpha: float = 0.5,
    use_pallas: bool = False,
    solver: str = "neumann",
) -> Result:
    """One placement/forwarding round: isolates the value of alternation."""
    state = structured_init(problem, use_pallas=use_pallas)
    J0, aux0 = round_eval(problem, state, solver=solver, use_pallas=use_pallas)
    state = placement_update(
        problem, state, aux0["ctg"], use_pallas=use_pallas, solver=solver
    )
    state = forwarding_update(problem, state, t_phi=t_phi, alpha=alpha, solver=solver)
    J1, aux1 = round_eval(problem, state, solver=solver, use_pallas=use_pallas)
    return _result(problem, state, aux1, "OneShot", [float(J0), float(J1)], 1)


def linearize(problem: Problem) -> Problem:
    """The same problem under congestion-blind linear costs (D=F/mu, C=G/nu).

    Shared by the sequential and fleet CongUnaware baselines so their
    linearization can never diverge."""
    return Problem(
        net=problem.net,
        apps=problem.apps,
        cost=CostModel(
            kind="linear",
            rho_max=problem.cost.rho_max,
            w_comm=problem.cost.w_comm,
            w_comp=problem.cost.w_comp,
        ),
        hop_bound=problem.hop_bound,
    )


def solve_congunaware(
    problem: Problem, *, use_pallas: bool = False, solver: str = "neumann"
) -> Result:
    """Shortest extended path under linear costs, evaluated with true costs.

    Implementation note: with linear costs the zero-load marginals ARE the
    link weights (D' = 1/mu, C' = 1/nu constants), so the extended-graph
    shortest path over (stage-0 copy, partition-1 transition, stage-1 copy,
    partition-2 transition, stage-2 copy) reduces exactly to the structured
    initialization's joint (h1, h2) scan under the linear cost model.
    """
    state = structured_init(linearize(problem), use_pallas=use_pallas)
    J, aux = objective(problem, state, solver=solver)
    return _result(problem, state, aux, "CongUnaware", [], 0)


def solve_colocated(
    problem: Problem,
    *,
    m_max: int = 30,
    t_phi: int = 10,
    alpha: float = 0.5,
    tol: float = 1e-3,
    patience: int = 4,
    use_pallas: bool = False,
    solver: str = "neumann",
) -> Result:
    """Both partitions at a single node; forwarding still congestion-aware."""
    res = solve_alt(
        problem,
        m_max=m_max,
        t_phi=t_phi,
        alpha=alpha,
        tol=tol,
        patience=patience,
        colocate=True,
        use_pallas=use_pallas,
        solver=solver,
        name="CoLocated",
    )
    return res


ALL_METHODS = {
    "ALT": solve_alt,
    "OneShot": solve_oneshot,
    "CongUnaware": solve_congunaware,
    "CoLocated": solve_colocated,
}


def compare_all(problem: Problem, **kw) -> dict:
    out = {}
    out["ALT"] = solve_alt(problem, **kw)
    out["OneShot"] = solve_oneshot(
        problem,
        t_phi=kw.get("t_phi", 10),
        alpha=kw.get("alpha", 0.5),
        use_pallas=kw.get("use_pallas", False),
        solver=kw.get("solver", "neumann"),
    )
    out["CongUnaware"] = solve_congunaware(
        problem,
        use_pallas=kw.get("use_pallas", False),
        solver=kw.get("solver", "neumann"),
    )
    out["CoLocated"] = solve_colocated(
        problem,
        m_max=kw.get("m_max", 30),
        t_phi=kw.get("t_phi", 10),
        alpha=kw.get("alpha", 0.5),
        use_pallas=kw.get("use_pallas", False),
        solver=kw.get("solver", "neumann"),
    )
    return out
