"""Stage-wise traffic flow, link/node loads, and the objective J (Eqs. 3-7).

The paper's recursion (3) defines, per application a and stage k, the node
traffic t_i^{a,k}. Under loop-free forwarding (guaranteed by the blocking rule
in forwarding.py and by the shortest-path-tree initialization/repair), the
forwarding matrix Phi^{a,k} is nilpotent, hence (I - Phi^T) is invertible and

    t^{a,k} = (I - (Phi^{a,k})^T)^{-1} b^{a,k}

with stage sources

    b^{a,0} = lambda_a e_{s_a}
    b^{a,1} = x^{a,1} .* t^{a,0}    (partition 1 host converts stage 0 -> 1)
    b^{a,2} = x^{a,2} .* t^{a,1}.

TPU adaptation (DESIGN.md sections 3 and 10): the fixed point is solved
batched over applications. The default `solver="neumann"` exploits the
nilpotency directly — a hop-capped propagation x <- b + Phi^T x (O(H V^2)
per solve, kernels/neumann) — while `solver="lu"` keeps the dense
O(V^3) `jnp.linalg.solve` as the exactness reference.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import costs
from ..kernels.neumann import effective_hops, neumann_solve
from .structs import Apps, Network, Problem, State, one_hot

SOLVERS = ("neumann", "lu")


def stage_solve(
    phi_k: jax.Array,
    b: jax.Array,
    problem: Problem,
    *,
    transpose: bool,
    solver: str = "neumann",
    use_pallas: bool = False,
) -> jax.Array:
    """Batched (I - Phi^T) t = b (transpose=True) or (I - Phi) q = c solve.

    phi_k: [..., V, V] stacked over apps (and fleet instances under vmap),
    b: [..., V]. The hop cap comes from the Problem-carried bound.
    """
    if solver == "lu":
        n = phi_k.shape[-1]
        eye = jnp.eye(n, dtype=phi_k.dtype)
        a = eye - (jnp.swapaxes(phi_k, -1, -2) if transpose else phi_k)
        return jnp.linalg.solve(a, b[..., None])[..., 0]
    if solver != "neumann":
        raise ValueError(f"unknown solver {solver!r}; expected one of {SOLVERS}")
    m = jnp.swapaxes(phi_k, -1, -2) if transpose else phi_k
    hops = effective_hops(
        problem.hop_bound, problem.net.n_nodes, fixed_loop=use_pallas
    )
    # interpret=True mirrors the minplus convention (use_pallas on CPU runs
    # the kernel body under the interpreter for validation); a TPU launch
    # profile flipping interpret=False is a ROADMAP item.
    return neumann_solve(m, b, hops=hops, use_pallas=use_pallas, interpret=True)


@partial(jax.jit, static_argnames=("solver", "use_pallas"))
def stage_traffic(
    problem: Problem,
    state: State,
    *,
    solver: str = "neumann",
    use_pallas: bool = False,
) -> jax.Array:
    """[A, K, V] traffic rate t_i^{a,k} (requests/s)."""
    n = problem.net.n_nodes
    apps = problem.apps
    src_oh = one_hot(apps.src, n)  # [A, V]
    solve = partial(
        stage_solve, problem=problem, transpose=True, solver=solver,
        use_pallas=use_pallas,
    )

    b0 = apps.lam[:, None] * src_oh
    t0 = solve(state.phi[:, 0], b0)
    b1 = state.x[:, 0, :] * t0
    t1 = solve(state.phi[:, 1], b1)
    b2 = state.x[:, 1, :] * t1
    t2 = solve(state.phi[:, 2], b2)
    return jnp.stack([t0, t1, t2], axis=1)


@jax.jit
def loads(problem: Problem, state: State, t: jax.Array | None = None):
    """Link load F [V,V] (Eq. 5) and node computation load G [V] (Eq. 6)."""
    if t is None:
        t = stage_traffic(problem, state)
    apps = problem.apps
    # f^{a,k}_{ij} = t^{a,k}_i phi^{a,k}_{ij}  (Eq. 4)
    f = t[..., :, None] * state.phi  # [A, K, V, V]
    F = jnp.einsum("ak,akij->ij", apps.L, f)
    # G_i = sum_a sum_p w^{a,p} x^{a,p}_i t^{a,p-1}_i
    G = jnp.einsum("ap,apv,apv->v", apps.w, state.x, t[:, :2, :])
    return F, G


@jax.jit
def objective_from_loads(problem: Problem, F: jax.Array, G: jax.Array):
    """J and its comm/comp split from already-computed loads (Eq. 7)."""
    net, cm = problem.net, problem.cost
    D = costs.link_cost(F, net.mu, cm) * net.adj
    C = costs.comp_cost(G, net.nu, cm)
    j_comm = jnp.sum(D)
    j_comp = jnp.sum(C)
    J = cm.w_comm * j_comm + cm.w_comp * j_comp
    return J, j_comm, j_comp


@partial(jax.jit, static_argnames=("solver", "use_pallas"))
def objective(
    problem: Problem,
    state: State,
    *,
    solver: str = "neumann",
    use_pallas: bool = False,
):
    """J(x, phi) plus a breakdown dict (Eq. 7 / the Fig-5 weighted variant)."""
    t = stage_traffic(problem, state, solver=solver, use_pallas=use_pallas)
    F, G = loads(problem, state, t)
    J, j_comm, j_comp = objective_from_loads(problem, F, G)
    return J, {"J": J, "J_comm": j_comm, "J_comp": j_comp, "F": F, "G": G, "t": t}


@jax.jit
def marginal_link_weights(problem: Problem, F: jax.Array) -> jax.Array:
    """w_comm * D'_ij(F_ij) on edges, BIG elsewhere: base weights for both the
    forwarding marginals (Eq. 10) and the placement surrogate (Eqs. 12-13)."""
    from .structs import BIG

    net, cm = problem.net, problem.cost
    dp = cm.w_comm * costs.link_cost_prime(F, net.mu, cm)
    return jnp.where(net.adj > 0, dp, BIG)


@jax.jit
def marginal_comp(problem: Problem, G: jax.Array) -> jax.Array:
    """kappa^{a,p}_i = w^{a,p} * w_comp * C'_i(G_i)   [A, P, V] (Eq. 12)."""
    cm = problem.cost
    cp = cm.w_comp * costs.comp_cost_prime(G, problem.net.nu, cm)  # [V]
    return problem.apps.w[:, :, None] * cp[None, None, :]


def objective_with_injection(
    problem: Problem,
    state: State,
    a: int,
    k: int,
    inj: jax.Array,
    *,
    solver: str = "neumann",
):
    """J when an extra exogenous stage-k source `inj` [V] is added for app a.

    Used to validate the marginal machinery: Gallager's identity says
    grad_inj J |_{inj=0} = q^{a,k} (the cost-to-go from marginals.py).
    Differentiating the neumann path goes through custom_linear_solve's
    implicit transpose solve, not the hop loop.
    """
    n = problem.net.n_nodes
    apps = problem.apps
    src_oh = one_hot(apps.src, n)
    solve = partial(stage_solve, problem=problem, transpose=True, solver=solver)

    b0 = apps.lam[:, None] * src_oh
    if k == 0:
        b0 = b0.at[a].add(inj)
    t0 = solve(state.phi[:, 0], b0)
    b1 = state.x[:, 0, :] * t0
    if k == 1:
        b1 = b1.at[a].add(inj)
    t1 = solve(state.phi[:, 1], b1)
    b2 = state.x[:, 1, :] * t1
    if k == 2:
        b2 = b2.at[a].add(inj)
    t2 = solve(state.phi[:, 2], b2)
    t = jnp.stack([t0, t1, t2], axis=1)

    F, G = loads(problem, state, t)
    J, _, _ = objective_from_loads(problem, F, G)
    return J


def total_absorbed(
    problem: Problem, state: State, *, solver: str = "neumann"
) -> jax.Array:
    """[A] sanity metric: stage-2 traffic absorbed at each destination.

    Equals lambda_a when forwarding is consistent (conservation test)."""
    t = stage_traffic(problem, state, solver=solver)
    n = problem.net.n_nodes
    dst_oh = one_hot(problem.apps.dst, n)
    return jnp.sum(t[:, 2, :] * dst_oh, axis=-1)
