"""Stage-wise traffic flow, link/node loads, and the objective J (Eqs. 3-7).

The paper's recursion (3) defines, per application a and stage k, the node
traffic t_i^{a,k}. Under loop-free forwarding (guaranteed by the blocking rule
in forwarding.py and by the shortest-path-tree initialization/repair), the
forwarding matrix Phi^{a,k} is nilpotent, hence (I - Phi^T) is invertible and

    t^{a,k} = (I - (Phi^{a,k})^T)^{-1} b^{a,k}

with stage sources

    b^{a,0} = lambda_a e_{s_a}
    b^{a,1} = x^{a,1} .* t^{a,0}    (partition 1 host converts stage 0 -> 1)
    b^{a,2} = x^{a,2} .* t^{a,1}.

TPU adaptation (DESIGN.md section 3): instead of the paper's per-node recursive
evaluation, we batch the three solves over applications with vmap — dense
[V,V] solves on the MXU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import costs
from .structs import Apps, Network, Problem, State, one_hot


def _solve_t(phi_k: jax.Array, b: jax.Array) -> jax.Array:
    """t = (I - phi_k^T)^{-1} b for one app/stage. phi_k: [V,V], b: [V]."""
    n = phi_k.shape[-1]
    eye = jnp.eye(n, dtype=phi_k.dtype)
    return jnp.linalg.solve(eye - phi_k.T, b)


@jax.jit
def stage_traffic(problem: Problem, state: State) -> jax.Array:
    """[A, K, V] traffic rate t_i^{a,k} (requests/s)."""
    n = problem.net.n_nodes
    apps = problem.apps
    src_oh = one_hot(apps.src, n)  # [A, V]

    b0 = apps.lam[:, None] * src_oh
    t0 = jax.vmap(_solve_t)(state.phi[:, 0], b0)
    b1 = state.x[:, 0, :] * t0
    t1 = jax.vmap(_solve_t)(state.phi[:, 1], b1)
    b2 = state.x[:, 1, :] * t1
    t2 = jax.vmap(_solve_t)(state.phi[:, 2], b2)
    return jnp.stack([t0, t1, t2], axis=1)


@jax.jit
def loads(problem: Problem, state: State, t: jax.Array | None = None):
    """Link load F [V,V] (Eq. 5) and node computation load G [V] (Eq. 6)."""
    if t is None:
        t = stage_traffic(problem, state)
    apps = problem.apps
    # f^{a,k}_{ij} = t^{a,k}_i phi^{a,k}_{ij}  (Eq. 4)
    f = t[..., :, None] * state.phi  # [A, K, V, V]
    F = jnp.einsum("ak,akij->ij", apps.L, f)
    # G_i = sum_a sum_p w^{a,p} x^{a,p}_i t^{a,p-1}_i
    G = jnp.einsum("ap,apv,apv->v", apps.w, state.x, t[:, :2, :])
    return F, G


@jax.jit
def objective(problem: Problem, state: State):
    """J(x, phi) plus a breakdown dict (Eq. 7 / the Fig-5 weighted variant)."""
    t = stage_traffic(problem, state)
    F, G = loads(problem, state, t)
    net, cm = problem.net, problem.cost
    D = costs.link_cost(F, net.mu, cm) * net.adj
    C = costs.comp_cost(G, net.nu, cm)
    j_comm = jnp.sum(D)
    j_comp = jnp.sum(C)
    J = cm.w_comm * j_comm + cm.w_comp * j_comp
    return J, {"J": J, "J_comm": j_comm, "J_comp": j_comp, "F": F, "G": G, "t": t}


@jax.jit
def marginal_link_weights(problem: Problem, F: jax.Array) -> jax.Array:
    """w_comm * D'_ij(F_ij) on edges, BIG elsewhere: base weights for both the
    forwarding marginals (Eq. 10) and the placement surrogate (Eqs. 12-13)."""
    from .structs import BIG

    net, cm = problem.net, problem.cost
    dp = cm.w_comm * costs.link_cost_prime(F, net.mu, cm)
    return jnp.where(net.adj > 0, dp, BIG)


@jax.jit
def marginal_comp(problem: Problem, G: jax.Array) -> jax.Array:
    """kappa^{a,p}_i = w^{a,p} * w_comp * C'_i(G_i)   [A, P, V] (Eq. 12)."""
    cm = problem.cost
    cp = cm.w_comp * costs.comp_cost_prime(G, problem.net.nu, cm)  # [V]
    return problem.apps.w[:, :, None] * cp[None, None, :]


def objective_with_injection(
    problem: Problem, state: State, a: int, k: int, inj: jax.Array
):
    """J when an extra exogenous stage-k source `inj` [V] is added for app a.

    Used to validate the marginal machinery: Gallager's identity says
    grad_inj J |_{inj=0} = q^{a,k} (the cost-to-go from marginals.py).
    """
    n = problem.net.n_nodes
    apps = problem.apps
    src_oh = one_hot(apps.src, n)

    b0 = apps.lam[:, None] * src_oh
    if k == 0:
        b0 = b0.at[a].add(inj)
    t0 = jax.vmap(_solve_t)(state.phi[:, 0], b0)
    b1 = state.x[:, 0, :] * t0
    if k == 1:
        b1 = b1.at[a].add(inj)
    t1 = jax.vmap(_solve_t)(state.phi[:, 1], b1)
    b2 = state.x[:, 1, :] * t1
    if k == 2:
        b2 = b2.at[a].add(inj)
    t2 = jax.vmap(_solve_t)(state.phi[:, 2], b2)
    t = jnp.stack([t0, t1, t2], axis=1)

    F, G = loads(problem, state, t)
    net, cm = problem.net, problem.cost
    D = costs.link_cost(F, net.mu, cm) * net.adj
    C = costs.comp_cost(G, net.nu, cm)
    return cm.w_comm * jnp.sum(D) + cm.w_comp * jnp.sum(C)


def total_absorbed(problem: Problem, state: State) -> jax.Array:
    """[A] sanity metric: stage-2 traffic absorbed at each destination.

    Equals lambda_a when forwarding is consistent (conservation test)."""
    t = stage_traffic(problem, state)
    n = problem.net.n_nodes
    dst_oh = one_hot(problem.apps.dst, n)
    return jnp.sum(t[:, 2, :] * dst_oh, axis=-1)
