"""Stage-wise traffic flow, link/node loads, and the objective J (Eqs. 3-7).

The paper's recursion (3) defines, per application a and stage k, the node
traffic t_i^{a,k}. Under loop-free forwarding (guaranteed by the blocking rule
in forwarding.py and by the shortest-path-tree initialization/repair), the
forwarding matrix Phi^{a,k} is nilpotent, hence (I - Phi^T) is invertible and

    t^{a,k} = (I - (Phi^{a,k})^T)^{-1} b^{a,k}

with stage sources

    b^{a,0} = lambda_a e_{s_a}
    b^{a,k} = x^{a,k} .* t^{a,k-1}   for 1 <= k <= parts_a
              (the partition-k host converts stage k-1 -> k)
    b^{a,k} = 0                      for k > parts_a (phantom stages).

The chain is a `lax.scan` over the stage axis — one trace of the solve body
regardless of the partition count P, which is per-`Problem` data rather than
a structural constant (DESIGN.md section 13).

TPU adaptation (DESIGN.md sections 3 and 10): each fixed point is solved
batched over applications. The default `solver="neumann"` exploits the
nilpotency directly — a hop-capped propagation x <- b + Phi^T x (O(H V^2)
per solve, kernels/neumann) — while `solver="lu"` keeps the dense
O(V^3) `jnp.linalg.solve` as the exactness reference.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import costs
from ..kernels.neumann import effective_hops, neumann_solve
from .structs import Apps, Problem, State, one_hot, partition_live_mask

SOLVERS = ("neumann", "lu")


def stage_solve(
    phi_k: jax.Array,
    b: jax.Array,
    problem: Problem,
    *,
    transpose: bool,
    solver: str = "neumann",
    use_pallas: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Batched (I - Phi^T) t = b (transpose=True) or (I - Phi) q = c solve.

    phi_k: [..., V, V] stacked over apps (and fleet instances under vmap),
    b: [..., V]. The hop cap comes from the Problem-carried bound.

    `interpret=True` runs the Pallas kernel body under the interpreter
    (CPU validation); a real TPU/GPU launch passes `--use-pallas
    --no-interpret` at the CLI and the pair flows down here unchanged.
    """
    if solver == "lu":
        n = phi_k.shape[-1]
        eye = jnp.eye(n, dtype=phi_k.dtype)
        a = eye - (jnp.swapaxes(phi_k, -1, -2) if transpose else phi_k)
        return jnp.linalg.solve(a, b[..., None])[..., 0]
    if solver != "neumann":
        raise ValueError(f"unknown solver {solver!r}; expected one of {SOLVERS}")
    m = jnp.swapaxes(phi_k, -1, -2) if transpose else phi_k
    hops = effective_hops(
        problem.hop_bound, problem.net.n_nodes, fixed_loop=use_pallas
    )
    return neumann_solve(
        m, b, hops=hops, use_pallas=use_pallas, interpret=interpret
    )


def _stage_gates(state: State, apps: Apps) -> jax.Array:
    """[K, A, V] conversion gate of each stage: gate_k = x^{a,k} for live
    partitions (stage k is re-injected by partition k's host), zero for
    stage 0 (exogenous source) and for phantom stages (k > parts)."""
    gated = state.x * partition_live_mask(apps)[:, :, None]  # [A, P, V]
    gates = jnp.concatenate(
        [jnp.zeros_like(gated[:, :1]), gated], axis=1
    )  # [A, K, V]
    return jnp.moveaxis(gates, 1, 0)


def _traffic_scan(problem, state, inject, *, solver, use_pallas, interpret=True):
    """Forward stage scan: t_k = solve(phi_k, inject_k + gate_k * t_{k-1})."""
    solve = partial(
        stage_solve, problem=problem, transpose=True, solver=solver,
        use_pallas=use_pallas, interpret=interpret,
    )
    phi_s = jnp.moveaxis(state.phi, 1, 0)  # [K, A, V, V]
    gates = _stage_gates(state, problem.apps)  # [K, A, V]

    def step(t_prev, xs):
        phi_k, inj_k, gate_k = xs
        t_k = solve(phi_k, inj_k + gate_k * t_prev)
        return t_k, t_k

    _, t = jax.lax.scan(step, jnp.zeros_like(inject[0]), (phi_s, inject, gates))
    return jnp.moveaxis(t, 0, 1)  # [A, K, V]


def _source_injection(problem: Problem) -> jax.Array:
    """[K, A, V] exogenous stage sources: lambda at s_a on stage 0, 0 after."""
    n = problem.net.n_nodes
    apps = problem.apps
    b0 = apps.lam[:, None] * one_hot(apps.src, n)  # [A, V]
    k = apps.L.shape[-1]
    return jnp.concatenate(
        [b0[None], jnp.zeros((k - 1,) + b0.shape, b0.dtype)], axis=0
    )


@partial(jax.jit, static_argnames=("solver", "use_pallas", "interpret"))
def stage_traffic(
    problem: Problem,
    state: State,
    *,
    solver: str = "neumann",
    use_pallas: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """[A, K, V] traffic rate t_i^{a,k} (requests/s)."""
    return _traffic_scan(
        problem, state, _source_injection(problem),
        solver=solver, use_pallas=use_pallas, interpret=interpret,
    )


@jax.jit
def loads(problem: Problem, state: State, t: jax.Array | None = None):
    """Link load F [V,V] (Eq. 5) and node computation load G [V] (Eq. 6).

    The stage/partition axis is accumulated by a sequential scan (one
    fixed-shape per-stage contraction per step), NOT one fused (a, k)
    einsum: a fused contraction's reduction pairing depends on the
    contracted extent, so the same real stages could round differently
    under different K envelopes. Sequential accumulation keeps the real
    prefix's float associativity independent of K — appended phantom
    stages are exact-zero addends — which is what makes stage padding
    *bitwise*-inert on J (DESIGN.md section 13).
    """
    if t is None:
        t = stage_traffic(problem, state)
    apps = problem.apps
    # f^{a,k}_{ij} = t^{a,k}_i phi^{a,k}_{ij}  (Eq. 4)
    f = t[..., :, None] * state.phi  # [A, K, V, V]

    def accum_f(acc, xs):
        L_k, f_k = xs  # [A], [A, V, V]
        return acc + jnp.einsum("a,aij->ij", L_k, f_k), None

    n = state.phi.shape[-1]
    F, _ = jax.lax.scan(
        accum_f,
        jnp.zeros((n, n), f.dtype),
        (jnp.moveaxis(apps.L, 1, 0), jnp.moveaxis(f, 1, 0)),
    )

    # G_i = sum_a sum_p w^{a,p} x^{a,p}_i t^{a,p-1}_i (phantom w = 0)
    def accum_g(acc, xs):
        w_p, x_p, t_p = xs  # [A], [A, V], [A, V]
        return acc + jnp.einsum("a,av,av->v", w_p, x_p, t_p), None

    G, _ = jax.lax.scan(
        accum_g,
        jnp.zeros((n,), f.dtype),
        (
            jnp.moveaxis(apps.w, 1, 0),
            jnp.moveaxis(state.x, 1, 0),
            jnp.moveaxis(t[:, :-1, :], 1, 0),
        ),
    )
    return F, G


@jax.jit
def objective_from_loads(problem: Problem, F: jax.Array, G: jax.Array):
    """J and its comm/comp split from already-computed loads (Eq. 7)."""
    net, cm = problem.net, problem.cost
    D = costs.link_cost(F, net.mu, cm) * net.adj
    C = costs.comp_cost(G, net.nu, cm)
    j_comm = jnp.sum(D)
    j_comp = jnp.sum(C)
    J = cm.w_comm * j_comm + cm.w_comp * j_comp
    return J, j_comm, j_comp


@partial(jax.jit, static_argnames=("solver", "use_pallas", "interpret"))
def objective(
    problem: Problem,
    state: State,
    *,
    solver: str = "neumann",
    use_pallas: bool = False,
    interpret: bool = True,
):
    """J(x, phi) plus a breakdown dict (Eq. 7 / the Fig-5 weighted variant)."""
    t = stage_traffic(
        problem, state, solver=solver, use_pallas=use_pallas, interpret=interpret
    )
    F, G = loads(problem, state, t)
    J, j_comm, j_comp = objective_from_loads(problem, F, G)
    return J, {"J": J, "J_comm": j_comm, "J_comp": j_comp, "F": F, "G": G, "t": t}


@jax.jit
def marginal_link_weights(problem: Problem, F: jax.Array) -> jax.Array:
    """w_comm * D'_ij(F_ij) on edges, BIG elsewhere: base weights for both the
    forwarding marginals (Eq. 10) and the placement surrogate (Eqs. 12-13)."""
    from .structs import BIG

    net, cm = problem.net, problem.cost
    dp = cm.w_comm * costs.link_cost_prime(F, net.mu, cm)
    return jnp.where(net.adj > 0, dp, BIG)


@jax.jit
def marginal_comp(problem: Problem, G: jax.Array) -> jax.Array:
    """kappa^{a,p}_i = w^{a,p} * w_comp * C'_i(G_i)   [A, P, V] (Eq. 12)."""
    cm = problem.cost
    cp = cm.w_comp * costs.comp_cost_prime(G, problem.net.nu, cm)  # [V]
    return problem.apps.w[:, :, None] * cp[None, None, :]


def objective_with_injection(
    problem: Problem,
    state: State,
    a: int,
    k: int,
    inj: jax.Array,
    *,
    solver: str = "neumann",
):
    """J when an extra exogenous stage-k source `inj` [V] is added for app a.

    Used to validate the marginal machinery: Gallager's identity says
    grad_inj J |_{inj=0} = q^{a,k} (the cost-to-go from marginals.py).
    Differentiating the neumann path goes through custom_linear_solve's
    implicit transpose solve, not the hop loop.
    """
    inject = _source_injection(problem).at[k, a].add(inj)
    t = _traffic_scan(problem, state, inject, solver=solver, use_pallas=False)
    F, G = loads(problem, state, t)
    J, _, _ = objective_from_loads(problem, F, G)
    return J


def total_absorbed(
    problem: Problem, state: State, *, solver: str = "neumann"
) -> jax.Array:
    """[A] sanity metric: final-stage traffic absorbed at each destination.

    Stage `parts_a` is app a's final stage (per-app split depths may differ
    inside one problem); its absorbed rate equals lambda_a when forwarding
    is consistent (conservation test)."""
    t = stage_traffic(problem, state, solver=solver)
    n = problem.net.n_nodes
    apps = problem.apps
    dst_oh = one_hot(apps.dst, n)
    t_fin = jnp.take_along_axis(t, apps.parts[:, None, None], axis=1)[:, 0, :]
    return jnp.sum(t_fin * dst_oh, axis=-1)
