"""Downstream marginal costs q_i^{a,k} and link marginals delta (Eq. 10).

Gallager's cost-to-go q summarizes the marginal increase of the whole-system
cost per extra unit of stage-k traffic injected at node i, under the current
forwarding state:

  q^{a,2}_i = sum_j phi^{a,2}_{ij} (L_{a,2} D'_{ij} + q^{a,2}_j)           (=0 at d_a)
  q^{a,1}_i = sum_j phi^{a,1}_{ij} (L_{a,1} D'_{ij} + q^{a,1}_j)
              + x^{a,2}_i (kappa^{a,2}_i + q^{a,2}_i)
  q^{a,0}_i = sum_j phi^{a,0}_{ij} (L_{a,0} D'_{ij} + q^{a,0}_j)
              + x^{a,1}_i (kappa^{a,1}_i + q^{a,1}_i)

i.e. a host node absorbs the stage, pays the computation marginal kappa, and
re-injects the next stage locally. Each line is a linear fixed point
(I - Phi) q = c, solved batched over applications on the same propagation
path as the traffic solve (DESIGN.md sections 3 and 10; `solver="lu"`
keeps the dense reference).

delta^{a,k}_{ij} = L_{a,k} D'_{ij}(F_{ij}) + q^{a,k}_j  is the per-link
forwarding marginal used by both the forwarding update and its blocking rule.

`round_eval` is the once-per-outer-round evaluation shared by the round's
objective read-out and the next placement sweep: both consume the identical
(q, dp, kappa, t, F, G) tuple, so the ALT loop no longer re-solves the
traffic fixed point separately for `objective` and `placement_update`
(the per-round dataflow restructure of DESIGN.md section 10).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flow import (
    loads,
    marginal_comp,
    marginal_link_weights,
    objective_from_loads,
    stage_solve,
    stage_traffic,
)
from .structs import BIG, Problem, State


@partial(jax.jit, static_argnames=("solver", "use_pallas"))
def cost_to_go(
    problem: Problem,
    state: State,
    t: jax.Array | None = None,
    *,
    solver: str = "neumann",
    use_pallas: bool = False,
):
    """Returns (q [A,K,V], dp [V,V], kappa [A,P,V], t [A,K,V], F, G)."""
    if t is None:
        t = stage_traffic(problem, state, solver=solver, use_pallas=use_pallas)
    F, G = loads(problem, state, t)
    dp = marginal_link_weights(problem, F)  # BIG off-edges
    dp_edges = jnp.where(problem.net.adj > 0, dp, 0.0)  # safe for sums
    kappa = marginal_comp(problem, G)  # [A, P, V]
    L = problem.apps.L  # [A, 3]
    solve = partial(
        stage_solve, problem=problem, transpose=False, solver=solver,
        use_pallas=use_pallas,
    )

    def link_term(phi_k, Lk):
        # c_i = sum_j phi_{ij} * L_k * D'_{ij}
        return Lk * jnp.sum(phi_k * dp_edges[None, :, :], axis=-1)

    # Stage 2 (toward destinations).
    c2 = link_term(state.phi[:, 2], L[:, 2][:, None])
    q2 = solve(state.phi[:, 2], c2)
    # Stage 1 (toward partition-2 hosts, then continue as stage 2).
    c1 = link_term(state.phi[:, 1], L[:, 1][:, None])
    c1 = c1 + state.x[:, 1, :] * (kappa[:, 1, :] + q2)
    q1 = solve(state.phi[:, 1], c1)
    # Stage 0 (toward partition-1 hosts, then continue as stage 1).
    c0 = link_term(state.phi[:, 0], L[:, 0][:, None])
    c0 = c0 + state.x[:, 0, :] * (kappa[:, 0, :] + q1)
    q0 = solve(state.phi[:, 0], c0)

    q = jnp.stack([q0, q1, q2], axis=1)  # [A, K, V]
    return q, dp, kappa, t, F, G


@partial(jax.jit, static_argnames=("solver", "use_pallas"))
def round_eval(
    problem: Problem,
    state: State,
    *,
    solver: str = "neumann",
    use_pallas: bool = False,
):
    """One full marginal evaluation of `state`: (J, aux).

    aux carries everything the round needs downstream — the objective
    breakdown for the history/stall logic AND the (q, dp, kappa, t, F, G)
    tuple the next placement sweep consumes — computed from a single
    traffic solve instead of one per consumer.
    """
    q, dp, kappa, t, F, G = cost_to_go(
        problem, state, solver=solver, use_pallas=use_pallas
    )
    J, j_comm, j_comp = objective_from_loads(problem, F, G)
    aux = {
        "J": J,
        "J_comm": j_comm,
        "J_comp": j_comp,
        "ctg": (q, dp, kappa, t, F, G),
    }
    return J, aux


@partial(jax.jit, static_argnames=("solver", "use_pallas"))
def link_marginals(
    problem: Problem,
    state: State,
    *,
    solver: str = "neumann",
    use_pallas: bool = False,
):
    """delta^{a,k}_{ij} (Eq. 10), BIG on non-edges. Returns (delta, aux)."""
    q, dp, kappa, t, F, G = cost_to_go(
        problem, state, solver=solver, use_pallas=use_pallas
    )
    L = problem.apps.L  # [A, 3]
    # delta[a,k,i,j] = L[a,k] * dp[i,j] + q[a,k,j]
    delta = L[:, :, None, None] * dp[None, None, :, :] + q[:, :, None, :]
    delta = jnp.where(problem.net.adj[None, None] > 0, delta, BIG)
    return delta, {"q": q, "dp": dp, "kappa": kappa, "t": t, "F": F, "G": G}
