"""Downstream marginal costs q_i^{a,k} and link marginals delta (Eq. 10).

Gallager's cost-to-go q summarizes the marginal increase of the whole-system
cost per extra unit of stage-k traffic injected at node i, under the current
forwarding state. For the final stage (k = parts_a, toward the destination):

  q^{a,K-1}_i = sum_j phi^{a,K-1}_{ij} (L_{a,K-1} D'_{ij} + q^{a,K-1}_j)

and for every earlier stage, the partition-(k+1) host absorbs the stage,
pays the computation marginal kappa, and re-injects the next stage locally:

  q^{a,k}_i = sum_j phi^{a,k}_{ij} (L_{a,k} D'_{ij} + q^{a,k}_j)
              + x^{a,k+1}_i (kappa^{a,k+1}_i + q^{a,k+1}_i)

Each line is a linear fixed point (I - Phi) q = c, solved batched over
applications on the same propagation path as the traffic solve (DESIGN.md
sections 3 and 10; `solver="lu"` keeps the dense reference). The backward
chain is a *reversed* `lax.scan` over the stage axis — the mirror image of
flow.py's forward scan — so the partition count P stays per-`Problem` data
(DESIGN.md section 13). Phantom stages (k > parts) have phi = 0, kappa = 0
and gate 0, so their cost-to-go is exactly zero and the real stages see the
same recursion as an unpadded problem.

delta^{a,k}_{ij} = L_{a,k} D'_{ij}(F_{ij}) + q^{a,k}_j  is the per-link
forwarding marginal used by both the forwarding update and its blocking rule.

`round_eval` is the once-per-outer-round evaluation shared by the round's
objective read-out and the next placement sweep: both consume the identical
(q, dp, kappa, t, F, G) tuple, so the ALT loop no longer re-solves the
traffic fixed point separately for `objective` and `placement_update`
(the per-round dataflow restructure of DESIGN.md section 10).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flow import (
    loads,
    marginal_comp,
    marginal_link_weights,
    objective_from_loads,
    stage_solve,
    stage_traffic,
)
from .structs import BIG, Problem, State, partition_live_mask


@partial(jax.jit, static_argnames=("solver", "use_pallas", "interpret"))
def cost_to_go(
    problem: Problem,
    state: State,
    t: jax.Array | None = None,
    *,
    solver: str = "neumann",
    use_pallas: bool = False,
    interpret: bool = True,
):
    """Returns (q [A,K,V], dp [V,V], kappa [A,P,V], t [A,K,V], F, G)."""
    if t is None:
        t = stage_traffic(
            problem, state, solver=solver, use_pallas=use_pallas,
            interpret=interpret,
        )
    F, G = loads(problem, state, t)
    dp = marginal_link_weights(problem, F)  # BIG off-edges
    dp_edges = jnp.where(problem.net.adj > 0, dp, 0.0)  # safe for sums
    kappa = marginal_comp(problem, G)  # [A, P, V]
    apps = problem.apps
    L = apps.L  # [A, K]
    solve = partial(
        stage_solve, problem=problem, transpose=False, solver=solver,
        use_pallas=use_pallas, interpret=interpret,
    )

    def link_term(phi_k, Lk):
        # c_i = sum_j phi_{ij} * L_k * D'_{ij}
        return Lk * jnp.sum(phi_k * dp_edges[None, :, :], axis=-1)

    # Absorption gates / marginals of the *next* partition, stage-aligned:
    # stage k is absorbed by partition k+1 (gate x^{a,k+1}, cost kappa^{a,k+1})
    # for k < parts; the final and phantom stages have no absorption term.
    live = partition_live_mask(apps)[:, :, None]  # [A, P, 1]
    zeros_tail = jnp.zeros_like(state.x[:, :1])
    gates = jnp.moveaxis(
        jnp.concatenate([state.x * live, zeros_tail], axis=1), 1, 0
    )  # [K, A, V]
    kappas = jnp.moveaxis(
        jnp.concatenate([kappa * live, zeros_tail], axis=1), 1, 0
    )  # [K, A, V]
    phi_s = jnp.moveaxis(state.phi, 1, 0)  # [K, A, V, V]
    L_s = jnp.moveaxis(L, 1, 0)  # [K, A]

    def step(q_next, xs):
        phi_k, L_k, gate_k, kap_k = xs
        c = link_term(phi_k, L_k[:, None]) + gate_k * (kap_k + q_next)
        q_k = solve(phi_k, c)
        return q_k, q_k

    _, q_rev = jax.lax.scan(
        step,
        jnp.zeros_like(gates[0]),
        (phi_s, L_s, gates, kappas),
        reverse=True,
    )
    q = jnp.moveaxis(q_rev, 0, 1)  # [A, K, V]
    return q, dp, kappa, t, F, G


@partial(jax.jit, static_argnames=("solver", "use_pallas", "interpret"))
def round_eval(
    problem: Problem,
    state: State,
    *,
    solver: str = "neumann",
    use_pallas: bool = False,
    interpret: bool = True,
):
    """One full marginal evaluation of `state`: (J, aux).

    aux carries everything the round needs downstream — the objective
    breakdown for the history/stall logic AND the (q, dp, kappa, t, F, G)
    tuple the next placement sweep consumes — computed from a single
    traffic solve instead of one per consumer.
    """
    q, dp, kappa, t, F, G = cost_to_go(
        problem, state, solver=solver, use_pallas=use_pallas,
        interpret=interpret,
    )
    J, j_comm, j_comp = objective_from_loads(problem, F, G)
    aux = {
        "J": J,
        "J_comm": j_comm,
        "J_comp": j_comp,
        "ctg": (q, dp, kappa, t, F, G),
    }
    return J, aux


@partial(jax.jit, static_argnames=("solver", "use_pallas", "interpret"))
def link_marginals(
    problem: Problem,
    state: State,
    *,
    solver: str = "neumann",
    use_pallas: bool = False,
    interpret: bool = True,
):
    """delta^{a,k}_{ij} (Eq. 10), BIG on non-edges. Returns (delta, aux)."""
    q, dp, kappa, t, F, G = cost_to_go(
        problem, state, solver=solver, use_pallas=use_pallas,
        interpret=interpret,
    )
    L = problem.apps.L  # [A, K]
    # delta[a,k,i,j] = L[a,k] * dp[i,j] + q[a,k,j]
    delta = L[:, :, None, None] * dp[None, None, :, :] + q[:, :, None, :]
    delta = jnp.where(problem.net.adj[None, None] > 0, delta, BIG)
    return delta, {"q": q, "dp": dp, "kappa": kappa, "t": t, "F": F, "G": G}
