"""Core data structures for the joint placement/routing problem (paper Eq. 1-7).

All structures are registered JAX pytrees so the whole optimizer state can be
jitted / vmapped / sharded. Shapes use the conventions:

    V  = number of nodes
    A  = number of applications (DNN inference services)
    K  = 3 traffic stages (0: raw input, 1: intermediate feature, 2: output)
    P  = 2 partitions (partition p consumes stage p-1 traffic, emits stage p)
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

# A large-but-finite stand-in for +inf: safe under addition in the tropical
# (min,+) semiring without producing inf-inf NaNs inside kernels.
BIG = jnp.float32(1e18)
# Threshold above which a distance is considered unreachable.
BIG_THRESHOLD = jnp.float32(1e17)

K_STAGES = 3
N_PARTS = 2


def _register(cls, data_fields, meta_fields=()):
    jax.tree_util.register_dataclass(
        cls, data_fields=list(data_fields), meta_fields=list(meta_fields)
    )
    return cls


@dataclasses.dataclass(frozen=True)
class Network:
    """Directed multi-hop edge network G = (V, E) with heterogeneous resources.

    adj : [V, V] float {0,1} adjacency (adj[i,j]=1 iff link (i,j) in E)
    mu  : [V, V] link service rate (bit/s);  BIG where no link (never used)
    nu  : [V]    node computation service rate
    """

    adj: jax.Array
    mu: jax.Array
    nu: jax.Array

    @property
    def n_nodes(self) -> int:
        return self.adj.shape[-1]


_register(Network, ["adj", "mu", "nu"])


@dataclasses.dataclass(frozen=True)
class Apps:
    """The set A of DNN inference services.

    src : [A] int32  source node s_a
    dst : [A] int32  destination node d_a (may equal src)
    lam : [A] input request rate lambda_a (requests/s)
    L   : [A, 3] packet size of stage k in {0,1,2} (bits/request)
    w   : [A, 2] per-request computation workload of partition p in {1,2}
          (node heterogeneity is carried by nu in C_i; see DESIGN.md section 8)
    """

    src: jax.Array
    dst: jax.Array
    lam: jax.Array
    L: jax.Array
    w: jax.Array

    @property
    def n_apps(self) -> int:
        return self.src.shape[-1]


_register(Apps, ["src", "dst", "lam", "L", "w"])


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Congestion cost configuration.

    kind      : "mm1" (D(F)=F/(mu-F), C(G)=G/(nu-G)) or "linear" (F/mu, G/nu)
    rho_max   : load fraction beyond which the M/M/1 curve is continued by a
                C^1 quadratic extension (keeps J finite + differentiable for
                infeasible iterates; see DESIGN.md section 8)
    w_comm / w_comp : objective weights (eta, 1-eta) for the Fig-5 tradeoff;
                (1, 1) reproduces the paper's main unweighted objective.

    `rho_max` / `w_comm` / `w_comp` are pytree *data* leaves (scalars), so
    cost models may differ per instance inside a stacked fleet (e.g. the
    Fig-5 eta grid solved as one batch — see fleet/solve.py). Only `kind`
    is static metadata: it selects a code path, so a fleet must share it.
    """

    kind: str = "mm1"
    rho_max: float = 0.95
    w_comm: float = 1.0
    w_comp: float = 1.0


_register(CostModel, ["rho_max", "w_comm", "w_comp"], ["kind"])


@dataclasses.dataclass(frozen=True)
class Problem:
    """One placement/routing instance, plus solver-facing static metadata.

    hop_bound : static bound on the *typical* loop-free forwarding path
        (unweighted graph diameter + 2 host re-injections) — the expected
        early-exit point of the Neumann propagation solver's hop loop. The
        solver's hard cap floors this with the nilpotency-index bound V + 1
        (kernels/neumann.effective_hops), so refined multipath paths longer
        than the diameter stay exact. `None` means unknown (the floor alone
        applies). Static metadata (it sizes a loop), so fleets unify it to
        the batch max before stacking (fleet/pad.py).
    """

    net: Network
    apps: Apps
    cost: CostModel
    hop_bound: int | None = None


_register(Problem, ["net", "apps", "cost"], ["hop_bound"])


def infer_hop_bound(net: Network) -> int:
    """Unweighted graph diameter (via the existing tropical-squaring APSP)
    plus 2, covering one host re-injection per stage hand-off.

    Concrete (Python-int) by construction: call at problem build time, not
    inside traced code."""
    from ..kernels.minplus import apsp

    w = jnp.where(net.adj > 0, 1.0, BIG)
    d = apsp(w)
    diam = jnp.max(jnp.where(d < BIG_THRESHOLD, d, 0.0))
    return int(diam) + 2


def with_hop_bound(problem: Problem) -> Problem:
    """Attach the inferred hop bound (no-op if already carried)."""
    if problem.hop_bound is not None:
        return problem
    return dataclasses.replace(problem, hop_bound=infer_hop_bound(problem.net))


@dataclasses.dataclass(frozen=True)
class State:
    """Decision variables of problem (7).

    x   : [A, P, V] one-hot placement (x[a, p-1, i] = 1 iff partition p at i)
    phi : [A, K, V, V] forwarding fractions phi_{ij}^{a,k}
    """

    x: jax.Array
    phi: jax.Array

    def hosts(self) -> jax.Array:
        """[A, P] int32 host node of each partition."""
        return jnp.argmax(self.x, axis=-1)


_register(State, ["x", "phi"])


def one_hot(idx: jax.Array, n: int) -> jax.Array:
    return jax.nn.one_hot(idx, n, dtype=jnp.float32)


def app_live_mask(apps: Apps) -> jax.Array:
    """[A] 1.0 for apps with positive arrival rate, else 0.0.

    Zero-rate apps route nothing, so they carry zero forwarding mass
    (phi = 0, hence (I - Phi^T) = I on their stages). This is what keeps
    fleet padding inert: a padded phantom app must never accumulate a
    cyclic phi-support that would make the flow solve singular
    (DESIGN.md section 9)."""
    return (apps.lam > 0).astype(jnp.float32)


@partial(jax.jit, static_argnames=("n",))
def forwarding_mass(state: State, apps: Apps, n: int) -> jax.Array:
    """[A, K, V] total forwarding fraction each node must emit per stage.

    Eq. (2a): sum_j phi^{a,0}_{ij} = 1 - x^{a,1}_i  (partition-1 host absorbs)
              sum_j phi^{a,1}_{ij} = 1 - x^{a,2}_i  (partition-2 host absorbs)
    Eq. (2b): sum_j phi^{a,2}_{ij} = 0 at d_a else 1.

    Apps with lambda_a = 0 have zero mass on every stage (see app_live_mask).
    """
    dst_oh = one_hot(apps.dst, n)  # [A, V]
    m0 = 1.0 - state.x[:, 0, :]
    m1 = 1.0 - state.x[:, 1, :]
    m2 = 1.0 - dst_oh
    return jnp.stack([m0, m1, m2], axis=1) * app_live_mask(apps)[:, None, None]
