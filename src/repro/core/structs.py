"""Core data structures for the joint placement/routing problem (paper Eq. 1-7).

All structures are registered JAX pytrees so the whole optimizer state can be
jitted / vmapped / sharded. Shapes use the conventions:

    V  = number of nodes
    A  = number of applications (DNN inference services)
    P  = number of DNN partitions carried by the arrays (the *structural*
         partition axis; partition p consumes stage-p traffic, emits stage p+1)
    K  = P + 1 traffic stages (0: raw input, 1..P-1: intermediate features,
         and the final stage toward the destination)

The partition count is per-`Problem` DATA, not a structural constant: each
app carries its effective split depth in `Apps.parts` (1 <= parts <= P), and
partitions/stages past `parts` are inert phantoms (w = 0, L = 0, zero
forwarding mass — see DESIGN.md section 13). The paper's evaluation uses
P = 2 / K = 3 (`N_PARTS` / `K_STAGES` below record those defaults), but every
kernel in this package is generic over the stage axis.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# A large-but-finite stand-in for +inf: safe under addition in the tropical
# (min,+) semiring without producing inf-inf NaNs inside kernels.
BIG = jnp.float32(1e18)
# Threshold above which a distance is considered unreachable.
BIG_THRESHOLD = jnp.float32(1e17)

# The paper's evaluation defaults (section IV): two partitions, three stages.
# These are *defaults* for scenario construction, not structural invariants —
# the solver stack is generic over the stage axis (DESIGN.md section 13).
K_STAGES = 3
N_PARTS = 2


def _register(cls, data_fields, meta_fields=()):
    jax.tree_util.register_dataclass(
        cls, data_fields=list(data_fields), meta_fields=list(meta_fields)
    )
    return cls


@dataclasses.dataclass(frozen=True)
class Network:
    """Directed multi-hop edge network G = (V, E) with heterogeneous resources.

    adj : [V, V] float {0,1} adjacency (adj[i,j]=1 iff link (i,j) in E)
    mu  : [V, V] link service rate (bit/s);  BIG where no link (never used)
    nu  : [V]    node computation service rate
    """

    adj: jax.Array
    mu: jax.Array
    nu: jax.Array

    @property
    def n_nodes(self) -> int:
        return self.adj.shape[-1]


_register(Network, ["adj", "mu", "nu"])


@dataclasses.dataclass(frozen=True)
class Apps:
    """The set A of DNN inference services.

    src   : [A] int32  source node s_a
    dst   : [A] int32  destination node d_a (may equal src)
    lam   : [A] input request rate lambda_a (requests/s)
    L     : [A, K] packet size of stage k (bits/request); entries past an
            app's effective stage count (`parts` + 1) are 0
    w     : [A, P] per-request computation workload of partition p
            (node heterogeneity is carried by nu in C_i; DESIGN.md section 8)
    parts : [A] int32 effective partition count of each app (1 <= parts <= P).
            Stage `parts` is the app's final stage (absorbed at d_a); stages
            past it are phantom padding with zero forwarding mass. Defaults
            to the structural P = w.shape[-1] when omitted.
    """

    src: jax.Array
    dst: jax.Array
    lam: jax.Array
    L: jax.Array
    w: jax.Array
    parts: jax.Array | None = None

    def __post_init__(self):
        if self.parts is None:
            w = self.w
            object.__setattr__(
                self,
                "parts",
                jnp.full(w.shape[:-1], w.shape[-1], dtype=jnp.int32),
            )

    @property
    def n_apps(self) -> int:
        return self.src.shape[-1]

    @property
    def n_parts(self) -> int:
        """Structural partition-axis length P (>= every per-app `parts`)."""
        return self.w.shape[-1]

    @property
    def n_stages(self) -> int:
        """Structural stage-axis length K = P + 1."""
        return self.L.shape[-1]


_register(Apps, ["src", "dst", "lam", "L", "w", "parts"])


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Congestion cost configuration.

    kind      : "mm1" (D(F)=F/(mu-F), C(G)=G/(nu-G)) or "linear" (F/mu, G/nu)
    rho_max   : load fraction beyond which the M/M/1 curve is continued by a
                C^1 quadratic extension (keeps J finite + differentiable for
                infeasible iterates; see DESIGN.md section 8)
    w_comm / w_comp : objective weights (eta, 1-eta) for the Fig-5 tradeoff;
                (1, 1) reproduces the paper's main unweighted objective.

    `rho_max` / `w_comm` / `w_comp` are pytree *data* leaves (scalars), so
    cost models may differ per instance inside a stacked fleet (e.g. the
    Fig-5 eta grid solved as one batch — see fleet/solve.py). Only `kind`
    is static metadata: it selects a code path, so a fleet must share it.
    """

    kind: str = "mm1"
    rho_max: float = 0.95
    w_comm: float = 1.0
    w_comp: float = 1.0


_register(CostModel, ["rho_max", "w_comm", "w_comp"], ["kind"])


@dataclasses.dataclass(frozen=True)
class Problem:
    """One placement/routing instance, plus solver-facing static metadata.

    hop_bound : static bound on the *typical* loop-free forwarding path
        (unweighted graph diameter + 2 host re-injections) — the expected
        early-exit point of the Neumann propagation solver's hop loop. The
        solver's hard cap floors this with the nilpotency-index bound V + 1
        (kernels/neumann.effective_hops), so refined multipath paths longer
        than the diameter stay exact. `None` means unknown (the floor alone
        applies). Static metadata (it sizes a loop), so fleets unify it to
        the batch max before stacking (fleet/pad.py).
    """

    net: Network
    apps: Apps
    cost: CostModel
    hop_bound: int | None = None


_register(Problem, ["net", "apps", "cost"], ["hop_bound"])


@dataclasses.dataclass(frozen=True)
class HopBoundCache:
    """Host-side snapshot of the unweighted distance closure behind one
    `infer_hop_bound` answer — NOT a pytree; it lives with the controller.

    adj       : [V, V] bool adjacency the closure was computed for
    dist      : [V, V] fp32 exact unweighted hop counts (integers below
                2^24, so every entry is exact in fp32; BIG where unreachable)
    hop_bound : the derived diameter + 2
    sweeps    : re-closure squaring sweeps the last refresh took
                (0 = adjacency unchanged, -1 = cold from-scratch solve) —
                the controller's `control.hop_bound.sweeps` metric
    """

    adj: "np.ndarray"
    dist: "np.ndarray"
    hop_bound: int
    sweeps: int = -1


def _unweighted_seed(adj: jax.Array) -> jax.Array:
    """[V, V] reflexive 1/BIG hop weights for the unweighted closure."""
    v = adj.shape[-1]
    w = jnp.where(adj > 0, 1.0, BIG)
    return jnp.where(jnp.eye(v, dtype=bool), 0.0, w)


def _hop_bound_of(dist: "np.ndarray") -> int:
    diam = float(np.max(np.where(dist < BIG_THRESHOLD, dist, 0.0)))
    return int(diam) + 2


def _warm_unweighted_closure(adj_new, cache: HopBoundCache, *, use_pallas, interpret):
    """Re-close the previous epoch's distances after a local adjacency change.

    Exactness argument (DESIGN.md section 16): let S be the touched nodes
    (any row/column of the adjacency delta). An old entry can only be wrong
    if its optimal path visited S, and every such pair satisfies
    `min_{s in S} d_old[i,s] + d_old[s,j] <= d_old[i,j]` — one masked
    (min,+) product finds them all. Those entries are invalidated to BIG;
    the surviving entries are still exact path lengths in the NEW graph
    (their paths avoid S entirely), so the seed `min(filtered, w_new)`
    contains every 1-hop edge and only valid upper bounds. Its transitive
    closure is therefore the from-scratch answer — and all values are exact
    fp32 integers, so the result is bitwise identical to a cold solve. The
    closure loop exits one sweep after the fixpoint; local perturbations
    typically re-close in 1-2 sweeps.
    """
    from ..kernels.minplus import minplus_matmul, squaring_bound

    changed = adj_new != cache.adj
    touched = jnp.asarray(changed.any(axis=0) | changed.any(axis=1))  # [V]
    d_old = jnp.asarray(cache.dist)
    cols = jnp.where(touched[None, :], d_old, BIG)  # keep d_old[i, s]
    rows = jnp.where(touched[:, None], d_old, BIG)  # keep d_old[s, j]
    via = minplus_matmul(cols, rows, use_pallas=use_pallas, interpret=interpret)
    stale = via <= d_old
    seed = jnp.minimum(
        jnp.where(stale, BIG, d_old), _unweighted_seed(jnp.asarray(adj_new))
    )
    sweeps = 0
    for _ in range(squaring_bound(seed.shape[-1])):
        nxt = jnp.minimum(
            seed,
            minplus_matmul(seed, seed, use_pallas=use_pallas, interpret=interpret),
        )
        sweeps += 1
        closed = bool(jnp.all(nxt == seed))
        seed = nxt
        if closed:
            break
    return seed, sweeps


def hop_bound_cache(
    net: Network,
    cache: HopBoundCache | None = None,
    *,
    use_pallas: bool = False,
    interpret: bool = True,
) -> HopBoundCache:
    """Compute (or incrementally refresh) the unweighted closure behind
    `infer_hop_bound`.

    With a `cache` from a previous round/epoch the refresh is warm-started
    from the cached distances — bitwise identical to a cold solve (see
    `_warm_unweighted_closure`) but one or two squaring sweeps instead of a
    full APSP. An unchanged adjacency returns immediately.
    """
    adj = np.asarray(net.adj) > 0
    if cache is not None and cache.adj.shape == adj.shape:
        if np.array_equal(cache.adj, adj):
            return dataclasses.replace(cache, sweeps=0)
        d, sweeps = _warm_unweighted_closure(
            adj, cache, use_pallas=use_pallas, interpret=interpret
        )
    else:
        from ..kernels.minplus import apsp

        d = apsp(
            _unweighted_seed(jnp.asarray(adj)),
            use_pallas=use_pallas,
            interpret=interpret,
        )
        sweeps = -1
    dist = np.asarray(d)
    return HopBoundCache(
        adj=adj, dist=dist, hop_bound=_hop_bound_of(dist), sweeps=sweeps
    )


def infer_hop_bound(
    net: Network,
    cache: HopBoundCache | None = None,
    *,
    use_pallas: bool = False,
    interpret: bool = True,
) -> int:
    """Unweighted graph diameter plus 2, covering one host re-injection per
    stage hand-off.

    Concrete (Python-int) by construction: call at problem build time, not
    inside traced code. Pass the previous round's `HopBoundCache` (see
    `hop_bound_cache`) to warm-start the closure after a local topology
    change."""
    return hop_bound_cache(
        net, cache, use_pallas=use_pallas, interpret=interpret
    ).hop_bound


def with_hop_bound(problem: Problem, cache: HopBoundCache | None = None) -> Problem:
    """Attach the inferred hop bound (no-op if already carried)."""
    if problem.hop_bound is not None:
        return problem
    return dataclasses.replace(problem, hop_bound=infer_hop_bound(problem.net, cache))


@dataclasses.dataclass(frozen=True)
class State:
    """Decision variables of problem (7).

    x   : [A, P, V] one-hot placement (x[a, p, i] = 1 iff partition p+1 at i)
    phi : [A, K, V, V] forwarding fractions phi_{ij}^{a,k}
    """

    x: jax.Array
    phi: jax.Array

    def hosts(self) -> jax.Array:
        """[A, P] int32 host node of each partition (phantom partitions
        carry a harmless real-node index; see DESIGN.md section 13)."""
        return jnp.argmax(self.x, axis=-1)


_register(State, ["x", "phi"])


def one_hot(idx: jax.Array, n: int) -> jax.Array:
    return jax.nn.one_hot(idx, n, dtype=jnp.float32)


def app_live_mask(apps: Apps) -> jax.Array:
    """[A] 1.0 for apps with positive arrival rate, else 0.0.

    Zero-rate apps route nothing, so they carry zero forwarding mass
    (phi = 0, hence (I - Phi^T) = I on their stages). This is what keeps
    fleet padding inert: a padded phantom app must never accumulate a
    cyclic phi-support that would make the flow solve singular
    (DESIGN.md section 9)."""
    return (apps.lam > 0).astype(jnp.float32)


def partition_live_mask(apps: Apps) -> jax.Array:
    """[A, P] 1.0 where partition p is within the app's effective split
    depth (`p < parts`), 0.0 on phantom partitions."""
    p = jnp.arange(apps.w.shape[-1])
    return (p[None, :] < apps.parts[..., None]).astype(jnp.float32)


def stage_live_mask(apps: Apps) -> jax.Array:
    """[A, K] 1.0 where stage k exists for the app (`k <= parts`; stage
    `parts` is the final leg toward d_a), 0.0 on phantom stages."""
    k = jnp.arange(apps.L.shape[-1])
    return (k[None, :] <= apps.parts[..., None]).astype(jnp.float32)


def stage_targets(apps: Apps, hosts: jax.Array) -> jax.Array:
    """[A, K] int32 absorption target of each stage given partition `hosts`
    [A, P]: the partition-(k+1) host for k < parts, the destination for every
    later stage (phantom stages carry zero mass; their target only gives the
    repair logic a stable, never-changing anchor)."""
    k = jnp.arange(apps.L.shape[-1])
    hosts_pad = jnp.concatenate([hosts, hosts[..., -1:]], axis=-1)  # [A, K]
    return jnp.where(
        k[None, :] < apps.parts[..., None], hosts_pad, apps.dst[..., None]
    ).astype(jnp.int32)


@partial(jax.jit, static_argnames=("n",))
def forwarding_mass(state: State, apps: Apps, n: int) -> jax.Array:
    """[A, K, V] total forwarding fraction each node must emit per stage.

    Eq. (2a): sum_j phi^{a,k}_{ij} = 1 - x^{a,k+1}_i for k < parts
              (the partition-(k+1) host absorbs the stage)
    Eq. (2b): sum_j phi^{a,parts}_{ij} = 0 at d_a else 1 (final stage).
    Phantom stages (k > parts) and apps with lambda_a = 0 carry zero mass
    (see app_live_mask / stage_live_mask)."""
    dst_oh = one_hot(apps.dst, n)  # [A, V]
    k = jnp.arange(state.phi.shape[-3])[None, :, None]  # [1, K, 1]
    parts = apps.parts[:, None, None]  # [A, 1, 1]
    x_pad = jnp.concatenate(
        [state.x, jnp.zeros_like(state.x[:, :1])], axis=1
    )  # [A, K, V]
    m = jnp.where(
        k < parts,
        1.0 - x_pad,
        jnp.where(k == parts, 1.0 - dst_oh[:, None, :], 0.0),
    )
    return m * app_live_mask(apps)[:, None, None]
