"""The paper's four evaluation scenarios (section IV, Table I).

  IoT        hierarchical IoT-edge-cloud, strongly heterogeneous (Fig. 3)
  Mesh       regular 5x5 grid
  SmallWorld fixed Watts-Strogatz instance (shortcut-rich irregular)
  GEANT      real backbone-inspired topology

Applications are generated with a fixed seed so source-destination pairs and
arrival rates are reproducible across all algorithms (paper section IV).
Table I in the provided text is partially garbled; the concrete numbers used
here are recorded in DESIGN.md section 8. `load_scale` multiplies every
lambda_a (the Fig-4 x-axis).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .structs import Apps, BIG, CostModel, Network, Problem, with_hop_bound

# Stage packet sizes (L0, L1, L2): first partition acts as local compression.
DEFAULT_L = (2.0, 0.8, 0.3)
# Per-partition workloads: first partition lighter than the second (paper IV).
DEFAULT_W = (0.3, 1.0)


def stage_profile(n_parts: int) -> tuple[tuple, tuple]:
    """(L, w) profiles for a chain of `n_parts` partitions (K = P + 1 stages).

    P = 2 returns the paper's exact defaults. Other depths extend the same
    shape: packet sizes decay geometrically from the raw input (2.0) to the
    output (0.3) — every split point is a further compression stage — and
    per-partition workloads ramp linearly from 0.3 up to 1.0, rescaled so
    the app's TOTAL compute matches the P = 2 default (1.3). That keeps the
    partition count a pure split-flexibility axis: sweeping P changes where
    work can be cut, not how much work there is.
    """
    if n_parts < 1:
        raise ValueError(f"n_parts must be >= 1, got {n_parts}")
    if n_parts == 2:
        return DEFAULT_L, DEFAULT_W
    L = np.geomspace(DEFAULT_L[0], DEFAULT_L[-1], n_parts + 1)
    raw = np.linspace(0.3, 1.0, n_parts)
    w = raw * (float(sum(DEFAULT_W)) / raw.sum())
    return tuple(float(x) for x in L), tuple(float(x) for x in w)


def build_network(n, und_edges, mu_map, nu, default_mu=10.0):
    """Assemble a `Network` from an undirected edge list + rate maps.

    Public so scenario generators outside this module (fleet/generator.py)
    share one canonical construction: adj from the edge list, per-direction
    mu from `mu_map` (falling back to `default_mu`), BIG-sentinel mu on
    non-edges."""
    adj = np.zeros((n, n), dtype=np.float32)
    mu = np.full((n, n), 1.0, dtype=np.float32)  # placeholder off-edges
    for (u, v) in und_edges:
        for (i, j) in ((u, v), (v, u)):
            adj[i, j] = 1.0
            mu[i, j] = mu_map.get((i, j), mu_map.get((u, v), default_mu))
    mu = np.where(adj > 0, mu, np.float32(BIG))
    return Network(
        adj=jnp.asarray(adj), mu=jnp.asarray(mu), nu=jnp.asarray(np.asarray(nu, np.float32))
    )


def gen_apps(
    rng: np.random.RandomState,
    n_apps: int,
    src_pool,
    dst_mode: str,
    n_nodes: int,
    lam_range=(2.0, 4.0),
    L=DEFAULT_L,
    w=DEFAULT_W,
    load_scale: float = 1.0,
    n_parts: int | None = None,
):
    """`n_parts` selects the split depth (stage_profile); None keeps the
    explicitly passed L/w profiles (paper defaults: P = 2)."""
    if n_parts is not None:
        L, w = stage_profile(n_parts)
    src = rng.choice(src_pool, size=n_apps)
    if dst_mode == "same":
        dst = src.copy()
    else:
        dst = rng.randint(0, n_nodes, size=n_apps)
    lam = rng.uniform(*lam_range, size=n_apps) * load_scale
    Ls = np.tile(np.asarray(L, np.float32), (n_apps, 1))
    ws = np.tile(np.asarray(w, np.float32), (n_apps, 1))
    return Apps(
        src=jnp.asarray(src.astype(np.int32)),
        dst=jnp.asarray(dst.astype(np.int32)),
        lam=jnp.asarray(lam.astype(np.float32)),
        L=jnp.asarray(Ls),
        w=jnp.asarray(ws),
    )


def iot(load_scale: float = 1.0, seed: int = 0, cost: CostModel | None = None, n_parts: int | None = None) -> Problem:
    """17 nodes: 1 cloud (0), 4 edge servers (1-4), 12 IoT devices (5-16).

    IoT devices: weak compute, weak uplinks to two edge servers. Edge servers:
    medium compute, ring-connected, uplinked to the cloud. Cloud: strongest
    compute, but extra hops/cost to reach (the Fig-3 tension).
    """
    n = 17
    edges = []
    mu_map = {}
    # Edge ring (1-2-3-4-1), medium-fat links.
    ring = [(1, 2), (2, 3), (3, 4), (4, 1)]
    for e in ring:
        edges.append(e)
        mu_map[e] = 16.0
    # Edge <-> cloud uplinks.
    for e_srv in (1, 2, 3, 4):
        edges.append((e_srv, 0))
        mu_map[(e_srv, 0)] = 12.0
    # IoT devices 5..16, each dual-homed to adjacent edge servers, weak links.
    for idx, dev in enumerate(range(5, 17)):
        e1 = 1 + (idx % 4)
        e2 = 1 + ((idx + 1) % 4)
        for e_srv in (e1, e2):
            edges.append((dev, e_srv))
            mu_map[(dev, e_srv)] = 8.0
    nu = np.array([80.0] + [12.0] * 4 + [2.0] * 12, np.float32)
    net = build_network(n, edges, mu_map, nu)
    rng = np.random.RandomState(seed)
    apps = gen_apps(rng, 20, np.arange(5, 17), "same", n, load_scale=load_scale, n_parts=n_parts)
    return with_hop_bound(Problem(net=net, apps=apps, cost=cost or CostModel()))


def mesh(load_scale: float = 1.0, seed: int = 1, cost: CostModel | None = None, n_parts: int | None = None) -> Problem:
    """Regular 5x5 grid, homogeneous mu = nu = 10."""
    side = 5
    n = side * side
    edges = []
    for r in range(side):
        for c in range(side):
            u = r * side + c
            if c + 1 < side:
                edges.append((u, u + 1))
            if r + 1 < side:
                edges.append((u, u + side))
    nu = np.full(n, 10.0, np.float32)
    net = build_network(n, edges, {}, nu, default_mu=10.0)
    rng = np.random.RandomState(seed)
    apps = gen_apps(rng, 40, np.arange(n), "random", n, load_scale=load_scale, n_parts=n_parts)
    return with_hop_bound(Problem(net=net, apps=apps, cost=cost or CostModel()))


def smallworld(load_scale: float = 1.0, seed: int = 2, cost: CostModel | None = None, n_parts: int | None = None) -> Problem:
    """Fixed Watts-Strogatz instance: N=30, k=4, p=0.1 (seeded)."""
    import networkx as nx

    n = 30
    g = nx.connected_watts_strogatz_graph(n, 4, 0.1, seed=7)
    edges = list(g.edges())
    nu = np.full(n, 10.0, np.float32)
    net = build_network(n, edges, {}, nu, default_mu=10.0)
    rng = np.random.RandomState(seed)
    apps = gen_apps(rng, 40, np.arange(n), "random", n, load_scale=load_scale, n_parts=n_parts)
    return with_hop_bound(Problem(net=net, apps=apps, cost=cost or CostModel()))


# 22-node GEANT-inspired backbone (undirected edge list). Node indices are
# abstract PoPs; the graph reproduces the classic GEANT degree mix (a few
# high-degree hubs, several degree-2 spurs). "Backbone-inspired" per paper IV.
_GEANT_EDGES = [
    (0, 1), (0, 2), (1, 3), (1, 6), (2, 3), (2, 4), (3, 5), (4, 5),
    (4, 7), (5, 8), (6, 8), (6, 9), (7, 8), (7, 11), (8, 10), (9, 10),
    (9, 12), (10, 13), (11, 14), (12, 13), (12, 15), (13, 16), (14, 17),
    (15, 16), (15, 18), (16, 19), (17, 18), (17, 20), (18, 21), (19, 21),
    (20, 21), (3, 10), (8, 13), (5, 16), (2, 9),
]


def geant(load_scale: float = 1.0, seed: int = 3, cost: CostModel | None = None, n_parts: int | None = None) -> Problem:
    n = 22
    nu = np.full(n, 10.0, np.float32)
    net = build_network(n, _GEANT_EDGES, {}, nu, default_mu=10.0)
    rng = np.random.RandomState(seed)
    apps = gen_apps(rng, 30, np.arange(n), "random", n, load_scale=load_scale, n_parts=n_parts)
    return with_hop_bound(Problem(net=net, apps=apps, cost=cost or CostModel()))


def random_connected(
    n: int,
    n_apps: int,
    avg_degree: float = 4.0,
    seed: int = 0,
    load_scale: float = 1.0,
    cost: CostModel | None = None,
    n_parts: int | None = None,
) -> Problem:
    """Synthetic irregular scale family (used by the scale benchmarks)."""
    import networkx as nx

    k = max(2, int(round(avg_degree)))
    g = nx.connected_watts_strogatz_graph(n, k, 0.3, seed=seed)
    edges = list(g.edges())
    rng = np.random.RandomState(seed + 1)
    nu = rng.uniform(5.0, 15.0, size=n).astype(np.float32)
    mu_map = {e: float(rng.uniform(5.0, 15.0)) for e in edges}
    net = build_network(n, edges, mu_map, nu)
    apps = gen_apps(rng, n_apps, np.arange(n), "random", n, load_scale=load_scale, n_parts=n_parts)
    return with_hop_bound(Problem(net=net, apps=apps, cost=cost or CostModel()))


SCENARIOS = {
    "iot": iot,
    "mesh": mesh,
    "smallworld": smallworld,
    "geant": geant,
}
