"""Marginal-cost placement reassignment (paper Eqs. 12-16) + phi repair.

Because the stage-k edge weight L_{a,k} D'_{ij}(F_{ij}) differs across stages
only by the positive scalar L_{a,k}, a single APSP under the base weight
D'_{ij}(F_{ij}) serves every (application, stage): Gamma^{a,k}_{uv} =
L_{a,k} * dist[u, v]  (the paper's section III-B observation). On TPU the APSP
is tropical matrix squaring (kernels/minplus), not Dijkstra — DESIGN.md 3.

Candidate score of partition p (0-based; upstream comm + local comp +
downstream comm), generic over the per-app partition count `parts`:

    S_{a,p}(i) = L_{a,p} dist[up_a, i] + kappa^{a,p}_i
                 + L_{a,p+1} dist[i, down_a]

where `up_a` is the *new* host of partition p-1 (the source s_a for p = 0)
and `down_a` is the *old* host of partition p+1 (the destination d_a for the
last live partition). Partitions are updated in order p = 0..P-1 — the
generalization of the paper's footnote 5 ("partition 1 first, then partition
2 with the new host of partition 1") to arbitrary split depths. Phantom
partitions (p >= parts) are frozen in place and carry zero load, so a
stage-padded instance sweeps bit-identically to its unpadded original
(DESIGN.md section 13).

Sweep schedules (`block_apps`, DESIGN.md section 18):

  * `block_apps=1` (default) — the paper's strictly sequential Gauss-Seidel
    scan over applications: each app removes its own loads from the
    incrementally maintained compute vector G, scores, moves, and commits
    before the next app is scored. This is the historical `lax.scan` path,
    kept verbatim.
  * `block_apps=k>1` (0 = all apps in one block) — the blocked sweep: apps
    are processed in blocks of static size k. Per block, everything that
    does not depend on in-block decisions is precomputed batched (the
    downstream score legs for all k apps at once, one dense `cprime(G)`
    base at the block-entry G); the decisions themselves stay a serial
    walk in app order (footnote-5 partition chain inside each app), with
    the compute marginal corrected incrementally on the <= 2P tracked
    slots an app's own removals/choices touch — never a dense per-app
    recompute. In-block conflicts are exact: each commit folds its delta
    into the carried cprime values at the <= 2P slots it touched, so every
    decision sees the same bits the sequential scan would, and the sweep's
    result is BITWISE-invariant to the block size (pinned at k in
    {1, 4, A} by tests/test_placement_sweep.py via
    `blocked_placement_update`). Block size trades batched precompute
    against per-block dense cprime evaluations; it never changes results.

After placement changes, stale forwarding would strand traffic (the old host
no longer absorbs), so per (app, stage) whose target host changed we rebuild
phi as the shortest-path next-hop tree toward the new host under the CURRENT
congested marginals — a congestion-aware warm restart that keeps (I - Phi^T)
invertible. Stages whose host did not change keep their refined multipath phi.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels.minplus import apsp_with_nexthop
from .marginals import cost_to_go
from .structs import (
    Problem,
    State,
    app_live_mask,
    one_hot,
    partition_live_mask,
    stage_live_mask,
    stage_targets,
)


def _sp_tree_phi(nexthop_to: jax.Array, target: jax.Array, mass: jax.Array, n: int):
    """phi rows = one-hot(next hop toward `target`), scaled by row mass.

    nexthop_to: [V, V] next-hop table (column t = toward target t).
    """
    nh = nexthop_to[:, target]  # [V]
    rows = jax.nn.one_hot(nh, n, dtype=jnp.float32)  # [V, V]
    return rows * mass[:, None]


def zero_load_dp(problem: Problem) -> jax.Array:
    """[V, V] zero-load marginal link metric, gated to the live adjacency.

    The seed weight behind `structured_init` and `repair_placement`: the
    congestion-free shortest-path metric D'_{ij}(0), with non-edges (and
    every edge into/out of a pad-encoded dead node, which keeps adj = 0)
    priced at BIG. Depends only on (adj, mu, cost) — which is what makes
    the zero-load APSP cacheable across chaos epochs (chaos/repair.py
    `Apsp0Cache`); this single definition is shared by the cold and cached
    paths so parity is bitwise by construction.
    """
    from . import costs as _costs
    from .structs import BIG

    dp0 = problem.cost.w_comm * _costs.link_cost_prime(
        jnp.zeros_like(problem.net.mu), problem.net.mu, problem.cost
    )
    return jnp.where(problem.net.adj > 0, dp0, BIG)


def _sequential_sweep(problem, hosts, dist, G, cprime, *, colocate, move_margin):
    """The paper's sequential Gauss-Seidel app scan (the `block_apps=1` path).

    Kept verbatim from the pre-blocked implementation: each app removes its
    own loads from the incrementally maintained G (so kappa is the marginal
    of adding it), walks its partition chain in footnote-5 order, and
    commits its chosen hosts' loads before the next app is scored. Without
    the incremental G, every app would see the same cheapest node and
    stampede onto it (a placement 2-cycle).
    """
    n = problem.net.n_nodes
    apps = problem.apps
    n_parts = apps.n_parts
    p_idx = jnp.arange(n_parts)

    def body(Gv, inputs):
        (src_a, dst_a, h_old, lam_a, L_a, w_a, parts_a) = inputs
        loads_a = w_a * lam_a  # [P]
        live = p_idx < parts_a  # [P]
        # Remove this app's own loads so kappa is the marginal of adding it
        # (sequentially, in partition order — phantom loads are exact zeros).
        def remove(g, pin):
            h_p, load_p = pin
            return g - load_p * jax.nn.one_hot(h_p, n), None

        Gv, _ = jax.lax.scan(remove, Gv, (h_old, loads_a))

        def pick(S, h_prev):
            # Hysteresis: only move when the improvement beats move_margin
            # (damps host flapping between outer iterations).
            cand = jnp.argmin(S).astype(jnp.int32)
            better = S[cand] < (1.0 - move_margin) * S[h_prev]
            return jnp.where(better, cand, h_prev).astype(jnp.int32)

        if colocate:
            w_tot = jnp.sum(jnp.where(live, w_a, 0.0))
            load_tot = jnp.sum(jnp.where(live, loads_a, 0.0))
            L_fin = L_a[parts_a]
            S = (
                L_a[0] * dist[src_a, :]
                + w_tot * cprime(Gv)
                + L_fin * dist[:, dst_a]
            )
            h = pick(S, h_old[0])
            h_new = jnp.where(live, h, h_old)
            Gv = Gv + load_tot * jax.nn.one_hot(h, n)
            return Gv, h_new

        # Old downstream anchor of partition p: partition p+1's current host,
        # or the destination for the last live partition (and phantoms).
        down = jnp.where(
            p_idx + 1 < parts_a,
            jnp.concatenate([h_old[1:], dst_a[None]]),
            dst_a,
        )  # [P]

        def step(carry, pin):
            g, up = carry
            live_p, h_old_p, down_p, L_up, L_dn, w_p, load_p = pin
            S = L_up * dist[up, :] + w_p * cprime(g) + L_dn * dist[:, down_p]
            h = jnp.where(live_p, pick(S, h_old_p), h_old_p)
            g = g + jnp.where(live_p, load_p, 0.0) * jax.nn.one_hot(h, n)
            return (g, h), h

        (Gv, _), h_new = jax.lax.scan(
            step,
            (Gv, src_a),
            (live, h_old, down, L_a[:-1], L_a[1:], w_a, loads_a),
        )
        return Gv, h_new

    _, hosts_new = jax.lax.scan(
        body,
        G,
        (apps.src, apps.dst, hosts, apps.lam, apps.L, apps.w, apps.parts),
    )
    return hosts_new


def _blocked_sweep(
    problem, hosts, dist, G, cprime, cprime_at, *, colocate, move_margin, bk
):
    """Blocked placement sweep: batched score-row precompute, exact decisions.

    Per block of `bk` apps (static size; the app axis is padded to a block
    multiple with inert clamped repeats):

      1. PRECOMPUTE (batched): the parts of every app's candidate rows that
         do not depend on in-block decisions are built for the whole block
         at once — the downstream legs `L_dn * dist[:, down]` (old-host
         anchored, like the sequential scan) and one per-block dense
         `cprime(G)` base evaluated at the block-entry G.
      2. DECIDE + COMMIT (serial, conflict-exact): apps are walked in block
         order. App j's candidate row for partition p is assembled from the
         precomputed pieces in the sequential scan's exact operation order
         (`(L_up * dist[up, :] + w_p * cprime) + downstream`), with the
         compute marginal corrected ONLY on the <= 2P tracked slots the
         app's own removals/choices touch (`I` holds the P old hosts plus
         one slot per chain step; `gval` replays the scan's own-load op
         sequence on the gathered slots). Conflicts with apps 0..j-1 of the
         block are exact, not approximated: their committed deltas are
         folded into the carried cprime values at the <= 2P slots each
         commit touched, so every argmin + `move_margin` pick sees the same
         bits the sequential scan would. Duplicated indices in `I` always
         carry identical values, so the scatter-set is order-safe.

    Because step 2 reproduces the sequential decision sequence exactly, the
    sweep's result is BITWISE-invariant to `bk` — block size is a pure
    scheduling knob trading batched precompute against per-block dense
    cprime evaluations (A dense evaluations at bk = 1, A / bk at bk > 1,
    one at bk = 0). That is a deliberate design departure from scoring
    whole blocks against the block-entry G (Jacobi) with a revert-style
    acceptance pass: measured on the four paper topologies, Jacobi blocks
    steer the outer ALT loop to DIFFERENT local optima (end-of-solve J off
    by 0.9%-68% depending on block size), and an all-at-once acceptance
    pass livelocks when every app lands in one block. DESIGN.md section 18
    records both measurements.

    Returns (hosts_new [A, P], cert) where `cert` carries the decision
    certificates (old/final hosts, decision-context scores S_new/S_old,
    the per-partition moved mask, and the per-block entry G) for the
    monotonicity property in tests/test_placement_sweep.py. For `colocate`
    the per-app chain collapses to one joint host; cert score fields then
    have one column.
    """
    n = problem.net.n_nodes
    apps = problem.apps
    n_parts = apps.n_parts
    a_tot = apps.n_apps
    n_blocks = -(-a_tot // bk)
    a_pad = n_blocks * bk

    idx = jnp.minimum(jnp.arange(a_pad), a_tot - 1)
    valid = jnp.arange(a_pad) < a_tot  # [A_pad]
    take = lambda x: jnp.take(x, idx, axis=0)  # noqa: E731

    src = take(apps.src)
    dst = take(apps.dst)
    h_old_all = take(hosts)  # [A_pad, P]
    L = take(apps.L)  # [A_pad, P+1]
    w = take(apps.w)  # [A_pad, P]
    parts = take(apps.parts)
    p_idx = jnp.arange(n_parts)
    live_all = (p_idx[None, :] < parts[:, None]) & valid[:, None]
    # Removal amounts are the raw per-partition loads (phantom loads are
    # exact zeros, like the sequential scan); clamped pad repeats must not
    # double-remove the last real app's loads, so they are zeroed outright.
    rem_all = jnp.where(valid[:, None], w * take(apps.lam)[:, None], 0.0)
    add_all = jnp.where(live_all, rem_all, 0.0)
    down_all = jnp.where(
        p_idx[None, :] + 1 < parts[:, None],
        jnp.concatenate([h_old_all[:, 1:], dst[:, None]], axis=1),
        dst[:, None],
    )
    L_fin = jnp.take_along_axis(L, parts[:, None], axis=1)[:, 0]
    w_tot = jnp.sum(jnp.where(live_all, w, 0.0), axis=1)
    load_tot = jnp.sum(add_all, axis=1)

    blk = lambda x: x.reshape((n_blocks, bk) + x.shape[1:])  # noqa: E731
    xs = dict(
        src=blk(src), dst=blk(dst), h_old=blk(h_old_all), rem=blk(rem_all),
        add=blk(add_all), live=blk(live_all), down=blk(down_all),
        L_up=blk(L[:, :-1]), L_dn=blk(L[:, 1:]), w=blk(w), L0=blk(L[:, 0]),
        L_fin=blk(L_fin), w_tot=blk(w_tot), load_tot=blk(load_tot),
    )
    margin = 1.0 - move_margin

    def _app_chain(carry, xa):
        """Exact footnote-5 chain walk for one app (docstring step 2).

        Carry: (G, cpw) where `cpw` is the dense cprime-value vector kept
        current at every slot touched by committed apps. `DN` rides in `xa`
        precomputed (downstream legs are old-host anchored, never stale).
        """
        Gc, cpw = carry
        h_old_j = xa["h_old"]  # [P]
        I = jnp.concatenate([h_old_j, h_old_j])  # [2P] tracked slots
        gval = Gc[I]
        for p2 in range(n_parts):
            gval = gval - jnp.where(I == h_old_j[p2], xa["rem"][p2], 0.0)
        up = xa["src"]
        h_fins, s_news, s_olds = [], [], []
        for p in range(n_parts):
            h_old_p = h_old_j[p]
            # Same association as the sequential scan's dense S:
            # (upstream + compute) + downstream, compute corrected on I.
            T = xa["L_up"][p] * dist[up, :] + xa["w"][p] * cpw
            T = T.at[I].set(
                xa["L_up"][p] * dist[up, I]
                + xa["w"][p] * cprime_at(gval, I)
            )
            S = T + xa["DN"][p]
            cand = jnp.argmin(S).astype(jnp.int32)
            better = S[cand] < margin * S[h_old_p]
            h_p = jnp.where(
                xa["live"][p], jnp.where(better, cand, h_old_p), h_old_p
            ).astype(jnp.int32)
            # Retarget this step's slot to the chosen host: if already
            # tracked copy the (consistent) tracked value, else h_p is
            # untouched by the app's own ops and holds the carried G.
            match = I == h_p
            tracked = gval[jnp.argmax(match)]
            val_h = jnp.where(match.any(), tracked, Gc[h_p])
            I = I.at[n_parts + p].set(h_p)
            gval = gval.at[n_parts + p].set(val_h)
            gval = gval + jnp.where(I == h_p, xa["add"][p], 0.0)
            h_fins.append(h_p)
            s_news.append(jnp.where(xa["live"][p], S[h_p], S[h_old_p]))
            s_olds.append(S[h_old_p])
            up = h_p
        h_fin = jnp.stack(h_fins)
        # Commit: removals then additions, in partition order (the
        # sequential scan's exact scatter sequence), then refresh the
        # carried cprime values at the touched slots — which are exactly
        # the tracked I (old hosts in the first half, chosen in the second).
        for p2 in range(n_parts):
            Gc = Gc.at[h_old_j[p2]].add(-xa["rem"][p2])
        for p2 in range(n_parts):
            Gc = Gc.at[h_fin[p2]].add(xa["add"][p2])
        cpw = cpw.at[I].set(cprime_at(Gc[I], I))
        out = dict(
            h_fin=h_fin, S_new=jnp.stack(s_news), S_old=jnp.stack(s_olds)
        )
        return (Gc, cpw), out

    def _app_colo(carry, xa):
        """Exact joint-host decision for one app (colocate variant)."""
        Gc, cpw = carry
        h_old_j = xa["h_old"]  # [P]
        h_prev = h_old_j[0]
        gval = Gc[h_old_j]
        for p2 in range(n_parts):
            gval = gval - jnp.where(h_old_j == h_old_j[p2], xa["rem"][p2], 0.0)
        T = xa["L0"] * dist[xa["src"], :] + xa["w_tot"] * cpw
        T = T.at[h_old_j].set(
            xa["L0"] * dist[xa["src"], h_old_j]
            + xa["w_tot"] * cprime_at(gval, h_old_j)
        )
        S = T + xa["DN"]
        cand = jnp.argmin(S).astype(jnp.int32)
        better = S[cand] < margin * S[h_prev]
        h_1 = jnp.where(better, cand, h_prev).astype(jnp.int32)
        for p2 in range(n_parts):
            Gc = Gc.at[h_old_j[p2]].add(-xa["rem"][p2])
        Gc = Gc.at[h_1].add(xa["load_tot"])
        I_t = jnp.concatenate([h_old_j, h_1[None]])
        cpw = cpw.at[I_t].set(cprime_at(Gc[I_t], I_t))
        h_fin = jnp.where(xa["live"], h_1, h_old_j)  # [P]
        out = dict(h_fin=h_fin, S_new=S[h_1][None], S_old=S[h_prev][None])
        return (Gc, cpw), out

    def body(Gv, x):
        g_entry = Gv
        cpb = cprime(Gv)  # [V] per-block dense base (docstring step 1)
        if colocate:
            DN = x["L_fin"][:, None] * jnp.take(dist, x["dst"], axis=1).T
            xa = dict(
                src=x["src"], h_old=x["h_old"], rem=x["rem"],
                live=x["live"], L0=x["L0"], w_tot=x["w_tot"],
                load_tot=x["load_tot"], DN=DN,
            )
            (Gv, _), ys = jax.lax.scan(_app_colo, (Gv, cpb), xa)
        else:
            dcol = jnp.take(dist, x["down"].reshape(-1), axis=1)  # [V, bk*P]
            DN = x["L_dn"][:, :, None] * dcol.T.reshape(bk, n_parts, n)
            xa = dict(
                src=x["src"], h_old=x["h_old"], rem=x["rem"], add=x["add"],
                live=x["live"], L_up=x["L_up"], w=x["w"], DN=DN,
            )
            (Gv, _), ys = jax.lax.scan(_app_chain, (Gv, cpb), xa)
        ys["G_entry"] = g_entry
        return Gv, ys

    _, ys = jax.lax.scan(body, G, xs)

    unblk = lambda v: v.reshape((a_pad,) + v.shape[2:])[:a_tot]  # noqa: E731
    hosts_new = unblk(ys["h_fin"])
    cert = {
        "h_old": hosts,
        "h_fin": hosts_new,
        "moved": hosts_new != hosts,
        "S_new": unblk(ys["S_new"]),
        "S_old": unblk(ys["S_old"]),
        "G_entry": ys["G_entry"],  # [n_blocks, V]
        "block": jnp.int32(bk),
    }
    return hosts_new, cert


def _placement_update_impl(
    problem, state, ctg, *, colocate, use_pallas, interpret, move_margin,
    solver, block_apps, force_blocked,
):
    if block_apps < 0:
        raise ValueError(
            f"block_apps must be >= 0 (0 = all apps per block), "
            f"got {block_apps}"
        )
    n = problem.net.n_nodes
    apps = problem.apps
    if ctg is None:
        ctg = cost_to_go(
            problem, state, solver=solver, use_pallas=use_pallas,
            interpret=interpret,
        )
    q, dp, kappa, t, F, G = ctg
    dist, nexthop = apsp_with_nexthop(
        dp, use_pallas=use_pallas, interpret=interpret
    )
    hosts = state.hosts()  # [A, P]
    cm = problem.cost
    nu = problem.net.nu

    from . import costs as _costs

    def cprime(Gv):
        return cm.w_comp * _costs.comp_cost_prime(Gv, nu, cm)

    def cprime_at(g, idx):
        # Same elementwise marginal, evaluated at gathered node slots `idx`
        # (comp_cost_prime is elementwise in (G, nu), so gathering nu keeps
        # each slot's value bitwise-equal to the dense vector's entry).
        return cm.w_comp * _costs.comp_cost_prime(g, nu[idx], cm)

    a_tot = apps.n_apps
    bk = a_tot if (block_apps == 0 or block_apps >= a_tot) else block_apps
    cert = None
    if bk <= 1 and not force_blocked:
        hosts_new = _sequential_sweep(
            problem, hosts, dist, G, cprime,
            colocate=colocate, move_margin=move_margin,
        )
    else:
        hosts_new, cert = _blocked_sweep(
            problem, hosts, dist, G, cprime, cprime_at,
            colocate=colocate, move_margin=move_margin, bk=bk,
        )

    x_new = one_hot(hosts_new, n)  # [A, P, V]
    new_state = State(x=x_new, phi=state.phi)
    return repair_phi(problem, state, new_state, nexthop), cert


_PLACEMENT_STATICS = (
    "colocate", "use_pallas", "interpret", "move_margin", "solver",
    "block_apps",
)


@functools.partial(jax.jit, static_argnames=_PLACEMENT_STATICS)
def placement_update(
    problem: Problem,
    state: State,
    ctg=None,
    *,
    colocate: bool = False,
    use_pallas: bool = False,
    interpret: bool = True,
    move_margin: float = 0.02,
    solver: str = "neumann",
    block_apps: int = 1,
) -> State:
    """One placement reassignment sweep over all applications.

    `ctg` is an optional precomputed (q, dp, kappa, t, F, G) tuple from
    `marginals.cost_to_go` / `round_eval` evaluated at `state` — the ALT
    loop passes the round-final evaluation so placement never re-solves
    the traffic fixed point it was just measured with. Link marginals (the
    Gamma distances) stay fixed during the sweep, exactly as in the paper.

    `block_apps` selects the sweep schedule (module doc + DESIGN.md §18):
    1 = the paper's sequential Gauss-Seidel app scan (default; the
    historical path, kept verbatim), k > 1 = the blocked sweep (batched
    per-block score-row precompute around an exact serial decision core),
    0 = one block covering every app. The result is bitwise-invariant to
    `block_apps` — the knob only changes the work schedule
    (tests/test_placement_sweep.py pins bitwise equality at 1, 4 and A).
    """
    new_state, _ = _placement_update_impl(
        problem, state, ctg, colocate=colocate, use_pallas=use_pallas,
        interpret=interpret, move_margin=move_margin, solver=solver,
        block_apps=block_apps, force_blocked=False,
    )
    return new_state


@functools.partial(jax.jit, static_argnames=_PLACEMENT_STATICS)
def blocked_placement_update(
    problem: Problem,
    state: State,
    ctg=None,
    *,
    colocate: bool = False,
    use_pallas: bool = False,
    interpret: bool = True,
    move_margin: float = 0.02,
    solver: str = "neumann",
    block_apps: int = 1,
) -> State:
    """`placement_update` forced through the blocked sweep at ANY block size.

    The production entry dispatches `block_apps=1` to the sequential scan
    (it is cheaper to compile and trivially bitwise); this variant runs the
    blocked code path even at block size 1, which is what the bitwise pins
    in tests/test_placement_sweep.py actually exercise — the claim is that
    the blocked ALGORITHM reproduces the sequential scan bit-for-bit at
    EVERY block size, not that a dispatch branch picked the old code.
    """
    new_state, _ = _placement_update_impl(
        problem, state, ctg, colocate=colocate, use_pallas=use_pallas,
        interpret=interpret, move_margin=move_margin, solver=solver,
        block_apps=block_apps, force_blocked=True,
    )
    return new_state


@functools.partial(jax.jit, static_argnames=_PLACEMENT_STATICS)
def blocked_sweep_cert(
    problem: Problem,
    state: State,
    ctg=None,
    *,
    colocate: bool = False,
    use_pallas: bool = False,
    interpret: bool = True,
    move_margin: float = 0.02,
    solver: str = "neumann",
    block_apps: int = 1,
) -> dict:
    """Decision certificates of one blocked sweep (test/diagnostic entry).

    Returns the blocked sweep's internal evidence: old/final hosts, the
    decision-context scores S_new/S_old per (app, partition), the moved
    mask, and the per-block entry G. Every committed move carries
    `S_new < (1 - move_margin) * S_old` under its decision context — the
    certificate behind the "a blocked sweep never increases the
    placement-side objective" property in tests/test_placement_sweep.py.
    """
    _, cert = _placement_update_impl(
        problem, state, ctg, colocate=colocate, use_pallas=use_pallas,
        interpret=interpret, move_margin=move_margin, solver=solver,
        block_apps=block_apps, force_blocked=True,
    )
    return cert


@jax.jit
def repair_phi(
    problem: Problem,
    old: State,
    new: State,
    nexthop: jax.Array,
    force: jax.Array | None = None,
) -> State:
    """Rebuild phi for stages whose absorption target moved (see module doc).

    Generic over the stage axis: stage k targets the partition-(k+1) host
    for k < parts and the destination after that (`structs.stage_targets`),
    so the final stage — and every phantom stage — never triggers a rebuild,
    and phantom stages keep zero mass via `stage_live_mask`.

    `force` is an optional [A, K] bool mask requesting a rebuild even when
    the target did not move — the failure-repair path (`repair_placement`)
    uses it for stages whose refined multipath phi carries mass into a node
    that just died, which a target-only comparison cannot see."""
    n = problem.net.n_nodes
    apps = problem.apps
    old_t = stage_targets(apps, old.hosts())  # [A, K]
    new_t = stage_targets(apps, new.hosts())  # [A, K]
    live = stage_live_mask(apps)  # [A, K]
    if force is None:
        force = jnp.zeros(old_t.shape, bool)

    def per_stage(phi_k, ot, nt, lv, fc):
        m = (1.0 - jax.nn.one_hot(nt, n, dtype=jnp.float32)) * lv
        tree = _sp_tree_phi(nexthop, nt, m, n)
        return jnp.where((ot != nt) | fc, tree, phi_k)

    phi = jax.vmap(jax.vmap(per_stage, in_axes=(0, 0, 0, 0, 0)))(
        new.phi, old_t, new_t, live, force
    )
    phi = phi * app_live_mask(apps)[:, None, None, None]
    return State(x=new.x, phi=phi)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def repair_placement(
    problem: Problem,
    state: State,
    node_mask: jax.Array,
    *,
    use_pallas: bool = False,
    interpret: bool = True,
    sp=None,
) -> State:
    """Evict partitions from masked-out hosts to the best live node.

    The failure-repair primitive (DESIGN.md section 15): `node_mask` is a
    [V] validity mask (1.0 = live) over a problem whose dead nodes already
    carry the pad encoding (adj = 0, mu = BIG, nu = NU_PAD — see
    chaos/events.py). Partitions hosted on dead nodes are rescored under
    the ZERO-LOAD marginals — the same metric as `structured_init`, because
    the post-fault congestion pattern is unknown until the next solve — and
    moved to the argmin live host, walking the partition chain in order so
    partition p sees the repaired host of p-1 (footnote-5 semantics, as in
    `placement_update`). Partitions on live hosts do not move: repair is a
    minimal eviction, not a re-optimization — the warm-started engine does
    the re-optimization afterwards.

    `sp` optionally injects a precomputed `(dist, nexthop)` pair for the
    zero-load metric `zero_load_dp(problem)` — the chaos controller's
    `Apsp0Cache` (chaos/repair.py) passes the cached APSP here so an
    epoch whose (adj, mu, cost) did not change skips the from-scratch
    `apsp_with_nexthop`. The cached arrays are produced by the identical
    computation on identical inputs, so parity with sp=None is bitwise
    (asserted per epoch by `launch.control --verify-apsp0` in CI).

    phi is then repaired by `repair_phi`, with a `force` rebuild for every
    stage whose current multipath phi carries mass INTO a dead node: once
    the node's links are BIG-rate, traffic routed there would otherwise be
    costed as if those links were free (zero incident traffic => zero D
    contribution), silently hiding an unservable route.

    Identity contract: with node_mask all-ones this returns `state`
    bitwise — no host is dead so no eviction happens, `one_hot(argmax(x))`
    round-trips the one-hot x exactly, and no stage is force-rebuilt.
    """
    n = problem.net.n_nodes
    apps = problem.apps
    n_parts = apps.n_parts
    from . import costs as _costs
    from .structs import BIG

    # Zero-load marginal link metric on the surviving subgraph. Dead nodes
    # keep adj = 0, so the `adj > 0` gate prices every edge into (or out of)
    # them at BIG and the SP trees route around the failure automatically.
    if sp is None:
        dist, nexthop = apsp_with_nexthop(
            zero_load_dp(problem), use_pallas=use_pallas, interpret=interpret
        )
    else:
        dist, nexthop = sp

    cp0 = problem.cost.w_comp * _costs.comp_cost_prime(
        jnp.zeros_like(problem.net.nu), problem.net.nu, problem.cost
    )
    # Hard eviction barrier: a dead candidate host scores BIG on top of its
    # already-prohibitive 1/NU_PAD compute marginal (belt and braces — the
    # braces matter when w_a,p is tiny).
    node_pen = jnp.where(node_mask > 0, 0.0, BIG)

    hosts = state.hosts()  # [A, P]
    p_idx = jnp.arange(n_parts)

    def per_app(src_a, dst_a, h_old, L_a, w_a, parts_a):
        live = p_idx < parts_a  # [P]
        dead_host = node_mask[h_old] <= 0  # [P]
        # Old downstream anchor: partition p+1's current host, or the
        # destination for the last live partition (and phantoms).
        down = jnp.where(
            p_idx + 1 < parts_a,
            jnp.concatenate([h_old[1:], dst_a[None]]),
            dst_a,
        )  # [P]

        def step(up, pin):
            live_p, h_old_p, down_p, L_up, L_dn, w_p, dead_p = pin
            S = (
                L_up * dist[up, :]
                + w_p * cp0
                + L_dn * dist[:, down_p]
                + node_pen
            )
            h = jnp.where(
                live_p & dead_p, jnp.argmin(S).astype(jnp.int32), h_old_p
            )
            return jnp.where(live_p, h, up), h

        _, h_new = jax.lax.scan(
            step,
            src_a,
            (live, h_old, down, L_a[:-1], L_a[1:], w_a, dead_host),
        )
        return h_new

    hosts_new = jax.vmap(per_app)(
        apps.src, apps.dst, hosts, apps.L, apps.w, apps.parts
    )

    # Stages whose refined phi still pushes mass into a dead node must be
    # rebuilt even if their absorption target did not move (docstring).
    dead = (node_mask <= 0).astype(state.phi.dtype)  # [V]
    force = jnp.einsum("akuv,v->ak", state.phi, dead) > 0  # [A, K]
    new_state = State(x=one_hot(hosts_new, n), phi=state.phi)
    return repair_phi(problem, state, new_state, nexthop, force)


@functools.partial(
    jax.jit, static_argnames=("colocate", "use_pallas", "interpret")
)
def structured_init(
    problem: Problem,
    *,
    colocate: bool = False,
    use_pallas: bool = False,
    interpret: bool = True,
    sp=None,
) -> State:
    """Feasible structured initialization (paper section IV, method a).

    Zero-load marginal weights D'_{ij}(0) give the uncongested shortest-path
    metric; the placement scores (14)-(15) under these weights pick initial
    hosts, and phi is initialized to the corresponding SP next-hop trees.
    `sp` optionally injects a precomputed `(dist, nexthop)` pair for the
    `zero_load_dp` metric (same contract as `repair_placement`); the engine's
    jitted init path passes None and fuses the APSP into its program.

    The joint host selection is an O(K V^2) Viterbi-style DP over the stage
    chain (cost-to-come M_p per candidate host, argmin backpointers, final
    leg to the destination) rather than the O(V^P) joint enumeration the
    P = 2 pair scan would become. At P = 2 the DP *is* the pair scan: the
    per-path float sums associate identically, and the final tie-break key
    (last backpointer, then host index) reproduces the row-major flat-argmin
    pair choice exactly. Phantom partitions (p >= parts) contribute identity
    transitions, so a stage-padded instance initializes bit-identically to
    its unpadded original (DESIGN.md section 13).
    """
    n = problem.net.n_nodes
    apps = problem.apps
    n_parts = apps.n_parts
    from . import costs as _costs

    if sp is None:
        dist, nexthop = apsp_with_nexthop(
            zero_load_dp(problem), use_pallas=use_pallas, interpret=interpret
        )
    else:
        dist, nexthop = sp

    cp0 = problem.cost.w_comp * _costs.comp_cost_prime(
        jnp.zeros_like(problem.net.nu), problem.net.nu, problem.cost
    )
    kappa0 = apps.w[:, :, None] * cp0[None, None, :]  # [A, P, V]

    L = apps.L
    dist_from_src = dist[apps.src, :]  # [A, V]
    dist_to_dst = dist[:, apps.dst].T  # [A, V]
    live = partition_live_mask(apps)  # [A, P]
    # L_{a, parts_a}: the packet size of each app's final (destination) leg.
    L_fin = jnp.take_along_axis(L, apps.parts[:, None], axis=1)[:, 0]  # [A]

    if colocate:
        S = L[:, 0][:, None] * dist_from_src
        for p in range(n_parts):
            S = S + kappa0[:, p, :] * live[:, p, None]
        S = S + L_fin[:, None] * dist_to_dst
        h = jnp.argmin(S, axis=-1).astype(jnp.int32)
        hosts = jnp.broadcast_to(h[:, None], (apps.n_apps, n_parts))
    else:
        # Forward DP over the partition chain: M_p(j) = cost-to-come of
        # hosting partition p at j, with smallest-index argmin backpointers.
        M = L[:, 0][:, None] * dist_from_src + kappa0[:, 0, :]  # [A, V]
        ptrs = []
        idx_j = jnp.arange(n, dtype=jnp.int32)[None, :]
        for p in range(1, n_parts):
            cand = M[:, :, None] + L[:, p][:, None, None] * dist[None]  # [A,V,V]
            ptr = jnp.argmin(cand, axis=1).astype(jnp.int32)  # [A, V]
            M_new = jnp.min(cand, axis=1) + kappa0[:, p, :]
            live_p = live[:, p] > 0  # [A]
            # Phantom transition: identity (cost-to-come and position pass
            # through unchanged), keeping the real chain's values bitwise.
            M = jnp.where(live_p[:, None], M_new, M)
            ptrs.append(jnp.where(live_p[:, None], ptr, idx_j))
        total = M + L_fin[:, None] * dist_to_dst  # [A, V]

        # Tie-break compatible with the historical P = 2 row-major flat
        # argmin over (h1, h2): among minimizing final hosts j, prefer the
        # one whose last *real* backpointer is smallest, then smallest j.
        m = jnp.min(total, axis=-1, keepdims=True)
        if ptrs:
            ptrs_arr = jnp.stack(ptrs, axis=1)  # [A, P-1, V]
            t_idx = jnp.clip(apps.parts - 2, 0, n_parts - 2)
            ptr_last = jnp.take_along_axis(
                ptrs_arr, t_idx[:, None, None], axis=1
            )[:, 0, :]
            ptr_last = jnp.where(apps.parts[:, None] >= 2, ptr_last, idx_j)
        else:
            ptr_last = jnp.broadcast_to(idx_j, total.shape)
        key = jnp.where(total == m, ptr_last * n + idx_j, n * n)
        h_last = jnp.argmin(key, axis=-1).astype(jnp.int32)

        hs = [None] * n_parts
        hs[n_parts - 1] = h_last
        for p in range(n_parts - 1, 0, -1):
            hs[p - 1] = jnp.take_along_axis(
                ptrs[p - 1], hs[p][:, None], axis=1
            )[:, 0]
        hosts = jnp.stack(hs, axis=1)  # [A, P]

    x = one_hot(hosts, n)  # [A, P, V]
    targets = stage_targets(apps, hosts)  # [A, K]
    stage_live = stage_live_mask(apps)  # [A, K]

    def per_stage(tgt, lv):
        m = (1.0 - jax.nn.one_hot(tgt, n, dtype=jnp.float32)) * lv
        return _sp_tree_phi(nexthop, tgt, m, n)

    phi = jax.vmap(jax.vmap(per_stage))(targets, stage_live)
    phi = phi * app_live_mask(apps)[:, None, None, None]
    return State(x=x, phi=phi)
