"""Marginal-cost placement reassignment (paper Eqs. 12-16) + phi repair.

Because the stage-k edge weight L_{a,k} D'_{ij}(F_{ij}) differs across stages
only by the positive scalar L_{a,k}, a single APSP under the base weight
D'_{ij}(F_{ij}) serves every (application, stage): Gamma^{a,k}_{uv} =
L_{a,k} * dist[u, v]  (the paper's section III-B observation). On TPU the APSP
is tropical matrix squaring (kernels/minplus), not Dijkstra — DESIGN.md 3.

Candidate scores (upstream comm + local comp + downstream comm):

    S_{a,1}(i) = L_{a,0} dist[s_a, i] + kappa^{a,1}_i + L_{a,1} dist[i, h^2_a]
    S_{a,2}(i) = L_{a,1} dist[h^1_a, i] + kappa^{a,2}_i + L_{a,2} dist[i, d_a]

Partition 1 is updated first (with the current host of partition 2), then
partition 2 with the *new* host of partition 1 (paper footnote 5).

After placement changes, stale forwarding would strand traffic (the old host
no longer absorbs), so per (app, stage) whose target host changed we rebuild
phi as the shortest-path next-hop tree toward the new host under the CURRENT
congested marginals — a congestion-aware warm restart that keeps (I - Phi^T)
invertible. Stages whose host did not change keep their refined multipath phi.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels.minplus import apsp_with_nexthop
from .marginals import cost_to_go
from .structs import Problem, State, app_live_mask, one_hot


def _sp_tree_phi(nexthop_to: jax.Array, target: jax.Array, mass: jax.Array, n: int):
    """phi rows = one-hot(next hop toward `target`), scaled by row mass.

    nexthop_to: [V, V] next-hop table (column t = toward target t).
    """
    nh = nexthop_to[:, target]  # [V]
    rows = jax.nn.one_hot(nh, n, dtype=jnp.float32)  # [V, V]
    return rows * mass[:, None]


@functools.partial(
    jax.jit, static_argnames=("colocate", "use_pallas", "move_margin", "solver")
)
def placement_update(
    problem: Problem,
    state: State,
    ctg=None,
    *,
    colocate: bool = False,
    use_pallas: bool = False,
    move_margin: float = 0.02,
    solver: str = "neumann",
) -> State:
    """One placement reassignment sweep over all applications.

    `ctg` is an optional precomputed (q, dp, kappa, t, F, G) tuple from
    `marginals.cost_to_go` / `round_eval` evaluated at `state` — the ALT
    loop passes the round-final evaluation so placement never re-solves
    the traffic fixed point it was just measured with.

    The paper's "sequentially update" (footnote 5 + Eq. 16) is implemented as
    a lax.scan over applications with an *incrementally maintained* compute
    load G: each reassignment removes the app's own load from its old host
    and adds it at the chosen host before the next app is scored. Without
    this, every app sees the same cheapest node and stampedes onto it
    (a placement 2-cycle); with it, the sweep is a genuine sequential greedy
    descent on the placement-side objective. Link marginals (the Gamma
    distances) stay fixed during the sweep, exactly as in the paper.

    Under consistent forwarding, all stage-(p-1) traffic of app a is absorbed
    at its partition-p host, so the app's own compute contribution at the
    host is w_{a,p} * lambda_a (conservation), which is what we shift.
    """
    n = problem.net.n_nodes
    apps = problem.apps
    if ctg is None:
        ctg = cost_to_go(problem, state, solver=solver, use_pallas=use_pallas)
    q, dp, kappa, t, F, G = ctg
    dist, nexthop = apsp_with_nexthop(dp, use_pallas=use_pallas)

    hosts = state.hosts()  # [A, 2]
    L = apps.L
    cm = problem.cost
    nu = problem.net.nu

    from . import costs as _costs

    def cprime(Gv):
        return cm.w_comp * _costs.comp_cost_prime(Gv, nu, cm)

    dist_from_src = dist[apps.src, :]  # [A, V]
    dist_to_dst = dist[:, apps.dst].T  # [A, V]

    def body(Gv, inputs):
        (a_src_d, a_dst_d, h1_old, h2_old, lam_a, L_a, w_a) = inputs
        load1 = w_a[0] * lam_a
        load2 = w_a[1] * lam_a
        # Remove this app's own loads so kappa is the marginal of adding it.
        Gv = Gv - load1 * jax.nn.one_hot(h1_old, n) - load2 * jax.nn.one_hot(h2_old, n)

        def pick(S, h_old):
            # Hysteresis: only move when the improvement beats move_margin
            # (damps host flapping between outer iterations).
            cand = jnp.argmin(S).astype(jnp.int32)
            better = S[cand] < (1.0 - move_margin) * S[h_old]
            return jnp.where(better, cand, h_old).astype(jnp.int32)

        if colocate:
            S = (
                L_a[0] * a_src_d
                + (w_a[0] + w_a[1]) * cprime(Gv)
                + L_a[2] * a_dst_d
            )
            h1 = pick(S, h1_old)
            h2 = h1
            Gv = Gv + (load1 + load2) * jax.nn.one_hot(h1, n)
        else:
            S1 = L_a[0] * a_src_d + w_a[0] * cprime(Gv) + L_a[1] * dist[:, h2_old]
            h1 = pick(S1, h1_old)
            Gv = Gv + load1 * jax.nn.one_hot(h1, n)
            S2 = L_a[1] * dist[h1, :] + w_a[1] * cprime(Gv) + L_a[2] * a_dst_d
            h2 = pick(S2, h2_old)
            Gv = Gv + load2 * jax.nn.one_hot(h2, n)
        return Gv, (h1, h2)

    _, (h1, h2) = jax.lax.scan(
        body,
        G,
        (
            dist_from_src,
            dist_to_dst,
            hosts[:, 0],
            hosts[:, 1],
            apps.lam,
            L,
            apps.w,
        ),
    )

    x_new = jnp.stack([one_hot(h1, n), one_hot(h2, n)], axis=1)
    new_state = State(x=x_new, phi=state.phi)
    return repair_phi(problem, state, new_state, nexthop)


@jax.jit
def repair_phi(
    problem: Problem, old: State, new: State, nexthop: jax.Array
) -> State:
    """Rebuild phi for stages whose absorption target moved (see module doc)."""
    n = problem.net.n_nodes
    apps = problem.apps
    old_hosts = old.hosts()
    new_hosts = new.hosts()

    def per_app(phi_a, oh, nh, dst):
        h1, h2 = nh[0], nh[1]
        # Stage 0 -> toward h1; mass 1 everywhere except the host itself.
        m0 = 1.0 - jax.nn.one_hot(h1, n, dtype=jnp.float32)
        tree0 = _sp_tree_phi(nexthop, h1, m0, n)
        m1 = 1.0 - jax.nn.one_hot(h2, n, dtype=jnp.float32)
        tree1 = _sp_tree_phi(nexthop, h2, m1, n)
        changed1 = oh[0] != nh[0]
        changed2 = oh[1] != nh[1]
        phi0 = jnp.where(changed1, tree0, phi_a[0])
        phi1 = jnp.where(changed2, tree1, phi_a[1])
        # Stage 2 target (the destination) never moves.
        return jnp.stack([phi0, phi1, phi_a[2]], axis=0)

    phi = jax.vmap(per_app)(new.phi, old_hosts, new_hosts, apps.dst)
    phi = phi * app_live_mask(apps)[:, None, None, None]
    return State(x=new.x, phi=phi)


@functools.partial(jax.jit, static_argnames=("colocate", "use_pallas"))
def structured_init(
    problem: Problem, *, colocate: bool = False, use_pallas: bool = False
) -> State:
    """Feasible structured initialization (paper section IV, method a).

    Zero-load marginal weights D'_{ij}(0) give the uncongested shortest-path
    metric; the placement scores (14)-(15) under these weights pick initial
    hosts, and phi is initialized to the corresponding SP next-hop trees.
    """
    n = problem.net.n_nodes
    apps = problem.apps
    from . import costs as _costs
    from .structs import BIG

    dp0 = problem.cost.w_comm * _costs.link_cost_prime(
        jnp.zeros_like(problem.net.mu), problem.net.mu, problem.cost
    )
    dp0 = jnp.where(problem.net.adj > 0, dp0, BIG)
    dist, nexthop = apsp_with_nexthop(dp0, use_pallas=use_pallas)

    cp0 = problem.cost.w_comp * _costs.comp_cost_prime(
        jnp.zeros_like(problem.net.nu), problem.net.nu, problem.cost
    )
    kappa0 = apps.w[:, :, None] * cp0[None, None, :]  # [A, 2, V]

    L = apps.L
    dist_from_src = dist[apps.src, :]
    dist_to_dst = dist[:, apps.dst].T

    if colocate:
        S = (
            L[:, 0][:, None] * dist_from_src
            + kappa0[:, 0, :]
            + kappa0[:, 1, :]
            + L[:, 2][:, None] * dist_to_dst
        )
        h1 = jnp.argmin(S, axis=-1).astype(jnp.int32)
        h2 = h1
    else:
        # Joint (h1, h2) zero-load scan: S[a, i, j] over candidate pairs.
        S_pair = (
            L[:, 0][:, None, None] * dist_from_src[:, :, None]
            + kappa0[:, 0, :, None]
            + L[:, 1][:, None, None] * dist[None, :, :]
            + kappa0[:, 1, None, :]
            + L[:, 2][:, None, None] * dist_to_dst[:, None, :]
        )
        flat = jnp.argmin(S_pair.reshape(S_pair.shape[0], -1), axis=-1)
        h1 = (flat // n).astype(jnp.int32)
        h2 = (flat % n).astype(jnp.int32)

    x = jnp.stack([one_hot(h1, n), one_hot(h2, n)], axis=1)

    def per_app(h1a, h2a, dsta):
        m0 = 1.0 - jax.nn.one_hot(h1a, n, dtype=jnp.float32)
        m1 = 1.0 - jax.nn.one_hot(h2a, n, dtype=jnp.float32)
        m2 = 1.0 - jax.nn.one_hot(dsta, n, dtype=jnp.float32)
        return jnp.stack(
            [
                _sp_tree_phi(nexthop, h1a, m0, n),
                _sp_tree_phi(nexthop, h2a, m1, n),
                _sp_tree_phi(nexthop, dsta, m2, n),
            ],
            axis=0,
        )

    phi = jax.vmap(per_app)(h1, h2, apps.dst)
    phi = phi * app_live_mask(apps)[:, None, None, None]
    return State(x=x, phi=phi)
