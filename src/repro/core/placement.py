"""Marginal-cost placement reassignment (paper Eqs. 12-16) + phi repair.

Because the stage-k edge weight L_{a,k} D'_{ij}(F_{ij}) differs across stages
only by the positive scalar L_{a,k}, a single APSP under the base weight
D'_{ij}(F_{ij}) serves every (application, stage): Gamma^{a,k}_{uv} =
L_{a,k} * dist[u, v]  (the paper's section III-B observation). On TPU the APSP
is tropical matrix squaring (kernels/minplus), not Dijkstra — DESIGN.md 3.

Candidate score of partition p (0-based; upstream comm + local comp +
downstream comm), generic over the per-app partition count `parts`:

    S_{a,p}(i) = L_{a,p} dist[up_a, i] + kappa^{a,p}_i
                 + L_{a,p+1} dist[i, down_a]

where `up_a` is the *new* host of partition p-1 (the source s_a for p = 0)
and `down_a` is the *old* host of partition p+1 (the destination d_a for the
last live partition). Partitions are updated in order p = 0..P-1 — the
generalization of the paper's footnote 5 ("partition 1 first, then partition
2 with the new host of partition 1") to arbitrary split depths, implemented
as a lax.scan over the partition axis inside the application scan. Phantom
partitions (p >= parts) are frozen in place and carry zero load, so a
stage-padded instance sweeps bit-identically to its unpadded original
(DESIGN.md section 13).

After placement changes, stale forwarding would strand traffic (the old host
no longer absorbs), so per (app, stage) whose target host changed we rebuild
phi as the shortest-path next-hop tree toward the new host under the CURRENT
congested marginals — a congestion-aware warm restart that keeps (I - Phi^T)
invertible. Stages whose host did not change keep their refined multipath phi.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..kernels.minplus import apsp_with_nexthop
from .marginals import cost_to_go
from .structs import (
    Problem,
    State,
    app_live_mask,
    one_hot,
    partition_live_mask,
    stage_live_mask,
    stage_targets,
)


def _sp_tree_phi(nexthop_to: jax.Array, target: jax.Array, mass: jax.Array, n: int):
    """phi rows = one-hot(next hop toward `target`), scaled by row mass.

    nexthop_to: [V, V] next-hop table (column t = toward target t).
    """
    nh = nexthop_to[:, target]  # [V]
    rows = jax.nn.one_hot(nh, n, dtype=jnp.float32)  # [V, V]
    return rows * mass[:, None]


@functools.partial(
    jax.jit,
    static_argnames=(
        "colocate", "use_pallas", "interpret", "move_margin", "solver"
    ),
)
def placement_update(
    problem: Problem,
    state: State,
    ctg=None,
    *,
    colocate: bool = False,
    use_pallas: bool = False,
    interpret: bool = True,
    move_margin: float = 0.02,
    solver: str = "neumann",
) -> State:
    """One placement reassignment sweep over all applications.

    `ctg` is an optional precomputed (q, dp, kappa, t, F, G) tuple from
    `marginals.cost_to_go` / `round_eval` evaluated at `state` — the ALT
    loop passes the round-final evaluation so placement never re-solves
    the traffic fixed point it was just measured with.

    The paper's "sequentially update" (footnote 5 + Eq. 16) is implemented as
    a lax.scan over applications with an *incrementally maintained* compute
    load G: each reassignment removes the app's own load from its old hosts
    and adds it at the chosen hosts before the next app is scored. Without
    this, every app sees the same cheapest node and stampedes onto it
    (a placement 2-cycle); with it, the sweep is a genuine sequential greedy
    descent on the placement-side objective. Link marginals (the Gamma
    distances) stay fixed during the sweep, exactly as in the paper.

    Inside each app, a second lax.scan walks the partition axis p = 0..P-1
    (footnote 5 generalized): partition p is scored against the new host of
    p-1 and the old host of p+1, and its load is added at the chosen host
    before p+1 is scored. Under consistent forwarding, all stage-p traffic
    of app a is absorbed at its partition-(p+1) host, so the app's own
    compute contribution at the host is w_{a,p} * lambda_a (conservation),
    which is what we shift.
    """
    n = problem.net.n_nodes
    apps = problem.apps
    n_parts = apps.n_parts
    if ctg is None:
        ctg = cost_to_go(
            problem, state, solver=solver, use_pallas=use_pallas,
            interpret=interpret,
        )
    q, dp, kappa, t, F, G = ctg
    dist, nexthop = apsp_with_nexthop(
        dp, use_pallas=use_pallas, interpret=interpret
    )

    hosts = state.hosts()  # [A, P]
    cm = problem.cost
    nu = problem.net.nu
    p_idx = jnp.arange(n_parts)

    from . import costs as _costs

    def cprime(Gv):
        return cm.w_comp * _costs.comp_cost_prime(Gv, nu, cm)

    def body(Gv, inputs):
        (src_a, dst_a, h_old, lam_a, L_a, w_a, parts_a) = inputs
        loads_a = w_a * lam_a  # [P]
        live = p_idx < parts_a  # [P]
        # Remove this app's own loads so kappa is the marginal of adding it
        # (sequentially, in partition order — phantom loads are exact zeros).
        def remove(g, pin):
            h_p, load_p = pin
            return g - load_p * jax.nn.one_hot(h_p, n), None

        Gv, _ = jax.lax.scan(remove, Gv, (h_old, loads_a))

        def pick(S, h_prev):
            # Hysteresis: only move when the improvement beats move_margin
            # (damps host flapping between outer iterations).
            cand = jnp.argmin(S).astype(jnp.int32)
            better = S[cand] < (1.0 - move_margin) * S[h_prev]
            return jnp.where(better, cand, h_prev).astype(jnp.int32)

        if colocate:
            w_tot = jnp.sum(jnp.where(live, w_a, 0.0))
            load_tot = jnp.sum(jnp.where(live, loads_a, 0.0))
            L_fin = L_a[parts_a]
            S = (
                L_a[0] * dist[src_a, :]
                + w_tot * cprime(Gv)
                + L_fin * dist[:, dst_a]
            )
            h = pick(S, h_old[0])
            h_new = jnp.where(live, h, h_old)
            Gv = Gv + load_tot * jax.nn.one_hot(h, n)
            return Gv, h_new

        # Old downstream anchor of partition p: partition p+1's current host,
        # or the destination for the last live partition (and phantoms).
        down = jnp.where(
            p_idx + 1 < parts_a,
            jnp.concatenate([h_old[1:], dst_a[None]]),
            dst_a,
        )  # [P]

        def step(carry, pin):
            g, up = carry
            live_p, h_old_p, down_p, L_up, L_dn, w_p, load_p = pin
            S = L_up * dist[up, :] + w_p * cprime(g) + L_dn * dist[:, down_p]
            h = jnp.where(live_p, pick(S, h_old_p), h_old_p)
            g = g + jnp.where(live_p, load_p, 0.0) * jax.nn.one_hot(h, n)
            return (g, h), h

        (Gv, _), h_new = jax.lax.scan(
            step,
            (Gv, src_a),
            (live, h_old, down, L_a[:-1], L_a[1:], w_a, loads_a),
        )
        return Gv, h_new

    _, hosts_new = jax.lax.scan(
        body,
        G,
        (apps.src, apps.dst, hosts, apps.lam, apps.L, apps.w, apps.parts),
    )

    x_new = one_hot(hosts_new, n)  # [A, P, V]
    new_state = State(x=x_new, phi=state.phi)
    return repair_phi(problem, state, new_state, nexthop)


@jax.jit
def repair_phi(
    problem: Problem,
    old: State,
    new: State,
    nexthop: jax.Array,
    force: jax.Array | None = None,
) -> State:
    """Rebuild phi for stages whose absorption target moved (see module doc).

    Generic over the stage axis: stage k targets the partition-(k+1) host
    for k < parts and the destination after that (`structs.stage_targets`),
    so the final stage — and every phantom stage — never triggers a rebuild,
    and phantom stages keep zero mass via `stage_live_mask`.

    `force` is an optional [A, K] bool mask requesting a rebuild even when
    the target did not move — the failure-repair path (`repair_placement`)
    uses it for stages whose refined multipath phi carries mass into a node
    that just died, which a target-only comparison cannot see."""
    n = problem.net.n_nodes
    apps = problem.apps
    old_t = stage_targets(apps, old.hosts())  # [A, K]
    new_t = stage_targets(apps, new.hosts())  # [A, K]
    live = stage_live_mask(apps)  # [A, K]
    if force is None:
        force = jnp.zeros(old_t.shape, bool)

    def per_stage(phi_k, ot, nt, lv, fc):
        m = (1.0 - jax.nn.one_hot(nt, n, dtype=jnp.float32)) * lv
        tree = _sp_tree_phi(nexthop, nt, m, n)
        return jnp.where((ot != nt) | fc, tree, phi_k)

    phi = jax.vmap(jax.vmap(per_stage, in_axes=(0, 0, 0, 0, 0)))(
        new.phi, old_t, new_t, live, force
    )
    phi = phi * app_live_mask(apps)[:, None, None, None]
    return State(x=new.x, phi=phi)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def repair_placement(
    problem: Problem,
    state: State,
    node_mask: jax.Array,
    *,
    use_pallas: bool = False,
    interpret: bool = True,
) -> State:
    """Evict partitions from masked-out hosts to the best live node.

    The failure-repair primitive (DESIGN.md section 15): `node_mask` is a
    [V] validity mask (1.0 = live) over a problem whose dead nodes already
    carry the pad encoding (adj = 0, mu = BIG, nu = NU_PAD — see
    chaos/events.py). Partitions hosted on dead nodes are rescored under
    the ZERO-LOAD marginals — the same metric as `structured_init`, because
    the post-fault congestion pattern is unknown until the next solve — and
    moved to the argmin live host, walking the partition chain in order so
    partition p sees the repaired host of p-1 (footnote-5 semantics, as in
    `placement_update`). Partitions on live hosts do not move: repair is a
    minimal eviction, not a re-optimization — the warm-started engine does
    the re-optimization afterwards.

    phi is then repaired by `repair_phi`, with a `force` rebuild for every
    stage whose current multipath phi carries mass INTO a dead node: once
    the node's links are BIG-rate, traffic routed there would otherwise be
    costed as if those links were free (zero incident traffic => zero D
    contribution), silently hiding an unservable route.

    Identity contract: with node_mask all-ones this returns `state`
    bitwise — no host is dead so no eviction happens, `one_hot(argmax(x))`
    round-trips the one-hot x exactly, and no stage is force-rebuilt.
    """
    n = problem.net.n_nodes
    apps = problem.apps
    n_parts = apps.n_parts
    from . import costs as _costs
    from .structs import BIG

    # Zero-load marginal link metric on the surviving subgraph. Dead nodes
    # keep adj = 0, so the `adj > 0` gate prices every edge into (or out of)
    # them at BIG and the SP trees route around the failure automatically.
    dp0 = problem.cost.w_comm * _costs.link_cost_prime(
        jnp.zeros_like(problem.net.mu), problem.net.mu, problem.cost
    )
    dp0 = jnp.where(problem.net.adj > 0, dp0, BIG)
    dist, nexthop = apsp_with_nexthop(
        dp0, use_pallas=use_pallas, interpret=interpret
    )

    cp0 = problem.cost.w_comp * _costs.comp_cost_prime(
        jnp.zeros_like(problem.net.nu), problem.net.nu, problem.cost
    )
    # Hard eviction barrier: a dead candidate host scores BIG on top of its
    # already-prohibitive 1/NU_PAD compute marginal (belt and braces — the
    # braces matter when w_a,p is tiny).
    node_pen = jnp.where(node_mask > 0, 0.0, BIG)

    hosts = state.hosts()  # [A, P]
    p_idx = jnp.arange(n_parts)

    def per_app(src_a, dst_a, h_old, L_a, w_a, parts_a):
        live = p_idx < parts_a  # [P]
        dead_host = node_mask[h_old] <= 0  # [P]
        # Old downstream anchor: partition p+1's current host, or the
        # destination for the last live partition (and phantoms).
        down = jnp.where(
            p_idx + 1 < parts_a,
            jnp.concatenate([h_old[1:], dst_a[None]]),
            dst_a,
        )  # [P]

        def step(up, pin):
            live_p, h_old_p, down_p, L_up, L_dn, w_p, dead_p = pin
            S = (
                L_up * dist[up, :]
                + w_p * cp0
                + L_dn * dist[:, down_p]
                + node_pen
            )
            h = jnp.where(
                live_p & dead_p, jnp.argmin(S).astype(jnp.int32), h_old_p
            )
            return jnp.where(live_p, h, up), h

        _, h_new = jax.lax.scan(
            step,
            src_a,
            (live, h_old, down, L_a[:-1], L_a[1:], w_a, dead_host),
        )
        return h_new

    hosts_new = jax.vmap(per_app)(
        apps.src, apps.dst, hosts, apps.L, apps.w, apps.parts
    )

    # Stages whose refined phi still pushes mass into a dead node must be
    # rebuilt even if their absorption target did not move (docstring).
    dead = (node_mask <= 0).astype(state.phi.dtype)  # [V]
    force = jnp.einsum("akuv,v->ak", state.phi, dead) > 0  # [A, K]
    new_state = State(x=one_hot(hosts_new, n), phi=state.phi)
    return repair_phi(problem, state, new_state, nexthop, force)


@functools.partial(
    jax.jit, static_argnames=("colocate", "use_pallas", "interpret")
)
def structured_init(
    problem: Problem,
    *,
    colocate: bool = False,
    use_pallas: bool = False,
    interpret: bool = True,
) -> State:
    """Feasible structured initialization (paper section IV, method a).

    Zero-load marginal weights D'_{ij}(0) give the uncongested shortest-path
    metric; the placement scores (14)-(15) under these weights pick initial
    hosts, and phi is initialized to the corresponding SP next-hop trees.

    The joint host selection is an O(K V^2) Viterbi-style DP over the stage
    chain (cost-to-come M_p per candidate host, argmin backpointers, final
    leg to the destination) rather than the O(V^P) joint enumeration the
    P = 2 pair scan would become. At P = 2 the DP *is* the pair scan: the
    per-path float sums associate identically, and the final tie-break key
    (last backpointer, then host index) reproduces the row-major flat-argmin
    pair choice exactly. Phantom partitions (p >= parts) contribute identity
    transitions, so a stage-padded instance initializes bit-identically to
    its unpadded original (DESIGN.md section 13).
    """
    n = problem.net.n_nodes
    apps = problem.apps
    n_parts = apps.n_parts
    from . import costs as _costs
    from .structs import BIG

    dp0 = problem.cost.w_comm * _costs.link_cost_prime(
        jnp.zeros_like(problem.net.mu), problem.net.mu, problem.cost
    )
    dp0 = jnp.where(problem.net.adj > 0, dp0, BIG)
    dist, nexthop = apsp_with_nexthop(
        dp0, use_pallas=use_pallas, interpret=interpret
    )

    cp0 = problem.cost.w_comp * _costs.comp_cost_prime(
        jnp.zeros_like(problem.net.nu), problem.net.nu, problem.cost
    )
    kappa0 = apps.w[:, :, None] * cp0[None, None, :]  # [A, P, V]

    L = apps.L
    dist_from_src = dist[apps.src, :]  # [A, V]
    dist_to_dst = dist[:, apps.dst].T  # [A, V]
    live = partition_live_mask(apps)  # [A, P]
    # L_{a, parts_a}: the packet size of each app's final (destination) leg.
    L_fin = jnp.take_along_axis(L, apps.parts[:, None], axis=1)[:, 0]  # [A]

    if colocate:
        S = L[:, 0][:, None] * dist_from_src
        for p in range(n_parts):
            S = S + kappa0[:, p, :] * live[:, p, None]
        S = S + L_fin[:, None] * dist_to_dst
        h = jnp.argmin(S, axis=-1).astype(jnp.int32)
        hosts = jnp.broadcast_to(h[:, None], (apps.n_apps, n_parts))
    else:
        # Forward DP over the partition chain: M_p(j) = cost-to-come of
        # hosting partition p at j, with smallest-index argmin backpointers.
        M = L[:, 0][:, None] * dist_from_src + kappa0[:, 0, :]  # [A, V]
        ptrs = []
        idx_j = jnp.arange(n, dtype=jnp.int32)[None, :]
        for p in range(1, n_parts):
            cand = M[:, :, None] + L[:, p][:, None, None] * dist[None]  # [A,V,V]
            ptr = jnp.argmin(cand, axis=1).astype(jnp.int32)  # [A, V]
            M_new = jnp.min(cand, axis=1) + kappa0[:, p, :]
            live_p = live[:, p] > 0  # [A]
            # Phantom transition: identity (cost-to-come and position pass
            # through unchanged), keeping the real chain's values bitwise.
            M = jnp.where(live_p[:, None], M_new, M)
            ptrs.append(jnp.where(live_p[:, None], ptr, idx_j))
        total = M + L_fin[:, None] * dist_to_dst  # [A, V]

        # Tie-break compatible with the historical P = 2 row-major flat
        # argmin over (h1, h2): among minimizing final hosts j, prefer the
        # one whose last *real* backpointer is smallest, then smallest j.
        m = jnp.min(total, axis=-1, keepdims=True)
        if ptrs:
            ptrs_arr = jnp.stack(ptrs, axis=1)  # [A, P-1, V]
            t_idx = jnp.clip(apps.parts - 2, 0, n_parts - 2)
            ptr_last = jnp.take_along_axis(
                ptrs_arr, t_idx[:, None, None], axis=1
            )[:, 0, :]
            ptr_last = jnp.where(apps.parts[:, None] >= 2, ptr_last, idx_j)
        else:
            ptr_last = jnp.broadcast_to(idx_j, total.shape)
        key = jnp.where(total == m, ptr_last * n + idx_j, n * n)
        h_last = jnp.argmin(key, axis=-1).astype(jnp.int32)

        hs = [None] * n_parts
        hs[n_parts - 1] = h_last
        for p in range(n_parts - 1, 0, -1):
            hs[p - 1] = jnp.take_along_axis(
                ptrs[p - 1], hs[p][:, None], axis=1
            )[:, 0]
        hosts = jnp.stack(hs, axis=1)  # [A, P]

    x = one_hot(hosts, n)  # [A, P, V]
    targets = stage_targets(apps, hosts)  # [A, K]
    stage_live = stage_live_mask(apps)  # [A, K]

    def per_stage(tgt, lv):
        m = (1.0 - jax.nn.one_hot(tgt, n, dtype=jnp.float32)) * lv
        return _sp_tree_phi(nexthop, tgt, m, n)

    phi = jax.vmap(jax.vmap(per_stage))(targets, stage_live)
    phi = phi * app_live_mask(apps)[:, None, None, None]
    return State(x=x, phi=phi)
