"""Gallager-style congestion-aware forwarding update (paper Eq. 11).

Each sweep moves forwarding mass at every (application, stage, node) away from
high-marginal-cost out-links toward the minimum-marginal-cost out-link j*.
The paper's Eq. (11) uses an absolute step alpha * (delta_ij - delta_min); in
the deeply congested regime the marginals are enormous (quadratic-extension
slopes), so any absolute step overshoots and flaps. We use the
scale-invariant relative form (the paper defers exact scheduling to [9],[11];
recorded in DESIGN.md section 8):

    rate_ij = alpha * (delta_ij - delta_min) / (|delta_min| + delta_ij - delta_min)
    phi_ij <- phi_ij * (1 - rate_ij)                      (j != j*)
    phi_ij* <- mass_i - sum_{j != j*} phi_ij

so at an equalized optimum (gap = 0 on active links) the update is a no-op,
and mass drains geometrically — no overshoot, no renormalization guard.

Loop-freedom ("node-blocking mechanism"): out-links with q_j >= q_i
("improper" links) are drained at the maximal rate alpha instead of receiving
the Eq.-11 step. Why this keeps the flow solve well-posed:

  * the argmin link always has q_{j*} < q_i (q_i is a phi-weighted average of
    delta_ij >= delta_min = L D'_{i j*} + q_{j*} > q_{j*} since D' > 0), so
    mass always has a proper link to go to;
  * any directed cycle in the phi-support must contain >= 1 improper link
    (q strictly decreases along proper links), and improper links shrink
    geometrically, so every cycle's gain stays < 1 and (I - Phi^T) remains
    invertible (Neumann series converges).

The whole sweep is dense and vectorized over (A, K, V) — the TPU-native
reshaping of the per-node distributed update (DESIGN.md section 3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .marginals import link_marginals
from .structs import BIG_THRESHOLD, Problem, State, forwarding_mass

_PRUNE = 1e-9  # forwarding fractions below this are swept into j*


@functools.partial(
    jax.jit, static_argnames=("alpha", "solver", "use_pallas", "interpret")
)
def forwarding_sweep(
    problem: Problem,
    state: State,
    alpha: float = 0.5,
    *,
    solver: str = "neumann",
    use_pallas: bool = False,
    interpret: bool = True,
    mass: jax.Array | None = None,
) -> State:
    """One full congestion-aware forwarding sweep (all apps/stages/nodes).

    `mass` (the per-node emission totals, Eq. 2) depends only on the
    placement x and the destinations — both fixed across the T_phi inner
    sweeps — so `forwarding_update` computes it once and passes it in;
    standalone callers may omit it.
    """
    n = problem.net.n_nodes
    delta, aux = link_marginals(
        problem, state, solver=solver, use_pallas=use_pallas,
        interpret=interpret,
    )  # [A, K, V, V]
    q = aux["q"]

    if mass is None:
        mass = forwarding_mass(state, problem.apps, n)  # [A, K, V]

    delta_min = jnp.min(delta, axis=-1, keepdims=True)  # [A, K, V, 1]
    jstar = jnp.argmin(delta, axis=-1)  # [A, K, V]
    jstar_oh = jax.nn.one_hot(jstar, n, dtype=state.phi.dtype)

    edge = delta < BIG_THRESHOLD
    gap = jnp.where(edge, delta - delta_min, 0.0)
    rel = gap / (jnp.abs(delta_min) + gap + 1e-12)
    rate = alpha * rel

    # Blocking: improper links (q_j >= q_i) drain at the maximal rate.
    q_i = q[..., :, None]
    q_j = q[..., None, :]
    improper = ~(q_j < q_i)
    rate = jnp.where(improper, alpha, rate)

    phi = state.phi * (1.0 - rate)
    phi = jnp.where(phi < _PRUNE, 0.0, phi)

    # Re-assign the freed mass to j*.
    phi = phi * (1.0 - jstar_oh)
    others = jnp.sum(phi, axis=-1)
    phi = phi + jstar_oh * jnp.maximum(mass - others, 0.0)[..., None]

    return State(x=state.x, phi=phi)


@functools.partial(
    jax.jit,
    static_argnames=("t_phi", "alpha", "solver", "use_pallas", "interpret"),
)
def forwarding_update(
    problem: Problem,
    state: State,
    *,
    t_phi: int = 8,
    alpha: float = 0.5,
    solver: str = "neumann",
    use_pallas: bool = False,
    interpret: bool = True,
) -> State:
    """T_phi inner forwarding sweeps (the paper's forwarding subproblem 8).

    A fori_loop rather than a Python loop so the update stays a single XLA
    while-op when embedded in outer lax.scan bodies (the batched fleet
    solver traces this once per outer round, not t_phi times). The emission
    mass is hoisted out of the loop: it changes only when x or the absorbed
    (destination) mass changes, never across forwarding micro-steps.
    """
    mass = forwarding_mass(state, problem.apps, problem.net.n_nodes)

    def body(_, s):
        return forwarding_sweep(
            problem, s, alpha=alpha, solver=solver, use_pallas=use_pallas,
            interpret=interpret, mass=mass,
        )

    return jax.lax.fori_loop(0, t_phi, body, state)
