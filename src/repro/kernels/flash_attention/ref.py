"""Pure-jnp oracle for blockwise (flash) attention with GQA / causal / SWA.

Materializes the full [B, H, Sq, Sk] score tensor — correct but memory-bound;
used only as the test oracle and the small-shape fallback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e30


def attention_ref(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, Kv, Sk, D]
    v: jax.Array,  # [B, Kv, Sk, D]
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Softmax attention; q head h attends kv head h // (H // Kv).

    q_offset: absolute position of q[..., 0, :] (for decode/chunked prefill).
    """
    b, h, sq, d = q.shape
    _, kv, sk, _ = k.shape
    group = h // kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    qf = q.astype(jnp.float32).reshape(b, kv, group, sq, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf) * scale

    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
    return o.reshape(b, h, sq, d).astype(q.dtype)


M_INIT = -1e29


def attention_chunked(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, Kv, Sk, D]
    v: jax.Array,  # [B, Kv, Sk, D]
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Flash-style streaming softmax in pure jnp (double lax.scan).

    The memory-bounded full-attention path used by the models on long
    sequences: peak intermediate is [B, H, q_chunk, kv_chunk] instead of
    [B, H, Sq, Sk]. Numerically equals attention_ref (tests enforce it);
    on TPU the Pallas kernel replaces it.
    """
    b, h, sq, d = q.shape
    _, kv, sk, _ = k.shape
    group = h // kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    qc = min(q_chunk, sq)
    kc = min(kv_chunk, sk)
    pad_q = (-sq) % qc
    pad_k = (-sk) % kc
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq, nk = (sq + pad_q) // qc, (sk + pad_k) // kc

    qs = jnp.moveaxis(qp.reshape(b, kv, group, nq, qc, d), 3, 0)  # [nq,b,kv,g,qc,d]
    ks = jnp.moveaxis(kp.reshape(b, kv, nk, kc, d), 2, 0)  # [nk,b,kv,kc,d]
    vs = jnp.moveaxis(vp.reshape(b, kv, nk, kc, d), 2, 0)

    def q_step(_, iq_and_q):
        iq, qblk = iq_and_q
        qf = qblk.astype(jnp.float32)

        def kv_step(carry, ik_and_kv):
            m_run, l_run, acc = carry
            ik, kblk, vblk = ik_and_kv
            s = jnp.einsum("bkgqd,bksd->bkgqs", qf, kblk.astype(jnp.float32)) * scale
            q_pos = q_offset + iq * qc + jnp.arange(qc)
            k_pos = ik * kc + jnp.arange(kc)
            msk = k_pos[None, :] < sk
            if causal:
                msk &= k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                msk &= k_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(msk[None, None, None], s, NEG)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            corr = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", p, vblk.astype(jnp.float32)
            )
            return (m_new, l_new, acc), None

        init = (
            jnp.full((b, kv, group, qc), M_INIT, jnp.float32),
            jnp.zeros((b, kv, group, qc), jnp.float32),
            jnp.zeros((b, kv, group, qc, d), jnp.float32),
        )
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, init, (jnp.arange(nk), ks, vs)
        )
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, blocks = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    # blocks: [nq, b, kv, g, qc, d] -> [b, h, sq, d]
    out = jnp.moveaxis(blocks, 0, 3).reshape(b, kv, group, nq * qc, d)
    return out.reshape(b, h, nq * qc, d)[:, :, :sq, :]
