"""Pallas TPU kernel: blockwise (flash) attention, GQA / causal / SWA.

The model zoo's dominant compute op. Streaming softmax over KV blocks with
running (max, denominator, accumulator) state held in VMEM scratch — the
FlashAttention recurrence laid out for the TPU memory hierarchy:

  grid = (B * H, Sq / bq, Sk / bk), KV innermost ("arbitrary"), so the
  (bq, d) accumulator tile is revisited across KV steps while q/k/v tiles
  stream HBM -> VMEM. The two matmuls per step ([bq,d]x[d,bk] and
  [bq,bk]x[bk,d]) hit the MXU with 128-aligned dims.

GQA is handled in the BlockSpec index maps: the kv-head index is derived
arithmetically from the q-head grid coordinate (kvh = h // group), so no
KV replication is materialized.

VMEM per step (fp32, bq=bk=128, d<=256):
  q/k/v tiles 3 * 128 KiB + acc 128 KiB + scores 64 KiB  << 16 MiB.

Numerics: masked scores use NEG = -1e30 with the running max initialized to
M_INIT = -1e29 > NEG, so fully-masked blocks contribute exp(NEG - M_INIT) ~ 0
rather than exp(0) = 1, and rows that never see a valid key produce zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Renamed TPUCompilerParams -> CompilerParams across JAX releases.
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG = -1e30
M_INIT = -1e29


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    bq: int,
    bk: int,
    nk: int,
    q_offset: int,
    kv_len: int,
):
    ik = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, M_INIT)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Block-level mask bounds: skip fully-masked KV blocks entirely (the
    # causal upper triangle / outside the sliding-window band / padding).
    # Halves causal-attention work and makes SWA cost O(window), at runtime,
    # with no change to the streamed-softmax state.
    q_lo = q_offset + iq * bq
    q_hi = q_lo + bq - 1
    k_lo = ik * bk
    k_hi = k_lo + bk - 1
    live = k_lo < kv_len
    if causal:
        live &= k_lo <= q_hi
    if window is not None:
        live &= k_hi > q_lo - window

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # [bq, d]
        k = k_ref[0].astype(jnp.float32)  # [bk, d]
        v = v_ref[0].astype(jnp.float32)  # [bk, d]

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]

        q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < kv_len  # KV padding
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s_m = jnp.where(mask, s, NEG)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s_m, axis=-1))
        corr = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s_m - m_cur[:, None])
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_cur

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "bq", "bk", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, Kv, Sk, D]
    v: jax.Array,  # [B, Kv, Sk, D]
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, kv, sk, _ = k.shape
    assert h % kv == 0, (h, kv)
    group = h // kv
    scale = 1.0 / float(d) ** 0.5

    bq_ = min(bq, max(8, sq))
    bk_ = min(bk, max(128, 1))
    pad_q = (-sq) % bq_
    pad_k = (-sk) % bk_
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sqp, skp = sq + pad_q, sk + pad_k

    qp = qp.reshape(b * h, sqp, d)
    kp = kp.reshape(b * kv, skp, d)
    vp = vp.reshape(b * kv, skp, d)

    nq = sqp // bq_
    nk = skp // bk_
    grid = (b * h, nq, nk)

    def q_index(bh, iq, ik):
        return (bh, iq, 0)

    def kv_index(bh, iq, ik):
        batch = bh // h
        head = bh % h
        return (batch * kv + head // group, ik, 0)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale,
            causal=causal,
            window=window,
            bq=bq_,
            bk=bk_,
            nk=nk,
            q_offset=q_offset,
            kv_len=sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, d), q_index),
            pl.BlockSpec((1, bk_, d), kv_index),
            pl.BlockSpec((1, bk_, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq_, d), q_index),
        out_shape=jax.ShapeDtypeStruct((b * h, sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_, d), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(qp, kp, vp)
    return out.reshape(b, h, sqp, d)[:, :, :sq, :]
