"""Public attention op: dispatches between the Pallas flash kernel and the
jnp oracle.

The models call `flash_attention(...)`; the `use_pallas` flag comes from the
model config (default False on this CPU container — the dry-run lowers the
jnp path; the kernel is validated in interpret mode by tests/test_kernels.py
and is the intended TPU path)."""
from __future__ import annotations

import jax

from .kernel import flash_attention_pallas
from .ref import attention_chunked, attention_ref

# Above this q*kv sequence product, the jnp path streams over chunks
# (the [B, H, Sq, Sk] score tensor would not fit HBM).
_CHUNKED_THRESHOLD = 2048 * 2048


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    use_pallas: bool = False,
    interpret: bool = True,
) -> jax.Array:
    if use_pallas:
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, q_offset=q_offset, interpret=interpret
        )
    if q.shape[2] * k.shape[2] > _CHUNKED_THRESHOLD:
        return attention_chunked(
            q, k, v, causal=causal, window=window, q_offset=q_offset
        )
    return attention_ref(q, k, v, causal=causal, window=window, q_offset=q_offset)
