from .ops import (  # noqa: F401
    BIG,
    BIG_THRESHOLD,
    apsp,
    apsp_with_nexthop,
    minplus_closure,
    minplus_matmul,
    squaring_bound,
)
