from .ops import minplus_matmul, apsp, apsp_with_nexthop  # noqa: F401
