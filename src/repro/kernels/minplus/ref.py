"""Pure-jnp oracle for the tropical (min,+) matmul and APSP.

(A (x) B)[i, j] = min_k A[i, k] + B[k, j]

This is the reference the Pallas kernel is tested against (tests/test_kernels
sweeps shapes/dtypes with interpret=True).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def minplus_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """[M,K] (x) [K,N] -> [M,N] in fp32. Memory O(M*K*N) — oracle only."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def apsp_ref(w: jax.Array) -> jax.Array:
    """All-pairs shortest path by repeated tropical squaring of [V,V] weights.

    w must already contain BIG on non-edges and 0 on the diagonal.
    """
    n = w.shape[-1]
    d = w
    # After ceil(log2(n-1)) squarings, paths of any length are covered.
    import math
    n_iter = max(1, math.ceil(math.log2(max(n - 1, 2))))
    for _ in range(n_iter):
        d = jnp.minimum(d, minplus_matmul_ref(d, d))
    return d
