"""Pure-jnp oracle + blocked (k-chunked) tropical matmul and APSP.

(A (x) B)[i, j] = min_k A[i, k] + B[k, j]

`minplus_matmul_ref` is the one-broadcast oracle the Pallas kernel is tested
against (tests/test_kernels sweeps shapes/dtypes with interpret=True). Its
[M, K, N] intermediate is O(V^3) memory for APSP squaring — 512 MiB per
matmul at V=512 — which is the scaling cliff PR 8 removes. The default
non-Pallas compute path is `minplus_matmul_blocked`: the same reduction
streamed over K chunks with a lax.scan, peak memory O(M * block_k * N),
bitwise-identical results (min is associative/commutative and the chunk
padding candidates equal the oracle's own all-non-edge sums).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

BIG = 1e18

# Broadcast-intermediate budget for the blocked path: block_k is sized so the
# [M, block_k, N] candidate tensor stays near 64 MiB fp32 (2^24 elements).
_BLOCK_ELEMS = 1 << 24


def minplus_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """[M,K] (x) [K,N] -> [M,N] in fp32. Memory O(M*K*N) — oracle only."""
    return jnp.min(a[:, :, None] + b[None, :, :], axis=1)


def default_block_k(m: int, k: int, n: int) -> int:
    """Largest multiple-of-8 K chunk whose broadcast fits the element budget."""
    bk = max(1, _BLOCK_ELEMS // max(m * n, 1))
    bk = max(8, (bk // 8) * 8)
    return min(k, bk)


def minplus_matmul_blocked(
    a: jax.Array, b: jax.Array, *, block_k: int | None = None
) -> jax.Array:
    """Tropical matmul with the K reduction streamed in `block_k` chunks.

    Bitwise-equal to `minplus_matmul_ref` for any inputs (padding chunks
    contribute BIG+BIG candidates, exactly what the oracle computes for
    all-non-edge rows; the running min starts at +inf so padding can never
    shadow a real candidate). Peak memory O(M * block_k * N) instead of
    O(M*K*N).
    """
    (m, k), (k2, n) = a.shape, b.shape
    assert k == k2, (a.shape, b.shape)
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    bk = default_block_k(m, k, n) if block_k is None else min(int(block_k), k)
    if bk >= k:
        return minplus_matmul_ref(a, b)
    pad_k = (-k) % bk
    nk = (k + pad_k) // bk
    a_p = jnp.pad(a, ((0, 0), (0, pad_k)), constant_values=BIG)
    b_p = jnp.pad(b, ((0, pad_k), (0, 0)), constant_values=BIG)
    a3 = jnp.moveaxis(a_p.reshape(m, nk, bk), 1, 0)  # [nk, M, bk]
    b3 = b_p.reshape(nk, bk, n)                      # [nk, bk, N]

    def body(acc, chunk):
        a_c, b_c = chunk
        cand = jnp.min(a_c[:, :, None] + b_c[None, :, :], axis=1)
        return jnp.minimum(acc, cand), None

    acc0 = jnp.full((m, n), jnp.inf, jnp.float32)
    acc, _ = jax.lax.scan(body, acc0, (a3, b3))
    return acc


def apsp_ref(w: jax.Array) -> jax.Array:
    """All-pairs shortest path by repeated tropical squaring of [V,V] weights.

    w must already contain BIG on non-edges and 0 on the diagonal.
    """
    n = w.shape[-1]
    d = w
    # After ceil(log2(n-1)) squarings, paths of any length are covered.
    n_iter = max(1, math.ceil(math.log2(max(n - 1, 2))))
    for _ in range(n_iter):
        d = jnp.minimum(d, minplus_matmul_ref(d, d))
    return d
