"""Pallas TPU kernel: tropical (min,+) matmul, the APSP inner loop.

TPU adaptation of the paper's Dijkstra-based placement step (DESIGN.md
section 3): shortest paths under the marginal link weights D'_ij(F_ij) are
computed by tropical matrix squaring. The (min,+) semiring has no MXU support
(the systolic array is multiply-accumulate only), so the kernel targets the
VPU: each grid step loads MXU-aligned 128x128 tiles of A and B into VMEM and
reduces min over the K tile in KINNER-wide chunks, keeping the broadcast
intermediate ([bm, KINNER, bn]) small enough to live comfortably in VMEM.

Grid: (M/bm, N/bn, K/bk) with K innermost ("arbitrary") so the output tile is
revisited and used as the running-min accumulator — the standard Pallas
matmul accumulation pattern, with (+, *) replaced by (min, +).

VMEM budget per grid step (fp32, bm=bn=bk=128, KINNER=8):
    A tile 64 KiB + B tile 64 KiB + out tile 64 KiB + broadcast 512 KiB
    well under the ~16 MiB VMEM of a TPU core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Renamed TPUCompilerParams -> CompilerParams across JAX releases.
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

BIG = 1e18
DEFAULT_BLOCK = 128
KINNER = 8


def _minplus_kernel(a_ref, b_ref, o_ref, *, bk: int, k_steps: int):
    """One (i, j, k) grid step: o[i,j] = min(o[i,j], min_k a[i,k]+b[k,j])."""

    # +inf (not BIG) is the accumulator identity: BIG-padding chunks then
    # contribute BIG+BIG candidates, exactly what the pure-jnp oracle
    # computes for all-non-edge rows, so kernel == oracle bitwise.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, jnp.inf)

    a = a_ref[...]  # [bm, bk]
    b = b_ref[...]  # [bk, bn]

    def body(c, acc):
        # [bm, KINNER, 1] + [1, KINNER, bn] -> reduce min over KINNER.
        a_chunk = jax.lax.dynamic_slice_in_dim(a, c * KINNER, KINNER, axis=1)
        b_chunk = jax.lax.dynamic_slice_in_dim(b, c * KINNER, KINNER, axis=0)
        cand = jnp.min(a_chunk[:, :, None] + b_chunk[None, :, :], axis=1)
        return jnp.minimum(acc, cand)

    acc = jnp.full_like(o_ref[...], jnp.inf)
    acc = jax.lax.fori_loop(0, bk // KINNER, body, acc)
    o_ref[...] = jnp.minimum(o_ref[...], acc)


def _minplus_argmin_kernel(a_ref, b_ref, o_ref, ix_ref, *, bk: int):
    """Fused (min, argmin_k) grid step for the next-hop table.

    Tie-break contract: FIRST minimizing k, matching `jnp.argmin` on the
    full candidate tensor. Within a chunk `jnp.argmin` already returns the
    first minimum; across chunks and K tiles the strict `<` update keeps
    the earliest, because k advances monotonically (K is the innermost
    "arbitrary" grid dim and chunks walk the tile in order).
    """
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, jnp.inf)
        ix_ref[...] = jnp.zeros_like(ix_ref)

    a = a_ref[...]  # [bm, bk]
    b = b_ref[...]  # [bk, bn]

    def body(c, carry):
        acc, idx = carry
        a_chunk = jax.lax.dynamic_slice_in_dim(a, c * KINNER, KINNER, axis=1)
        b_chunk = jax.lax.dynamic_slice_in_dim(b, c * KINNER, KINNER, axis=0)
        cand = a_chunk[:, :, None] + b_chunk[None, :, :]  # [bm, KINNER, bn]
        cmin = jnp.min(cand, axis=1)
        carg = jnp.argmin(cand, axis=1).astype(jnp.int32) + c * KINNER
        upd = cmin < acc
        return jnp.where(upd, cmin, acc), jnp.where(upd, carg, idx)

    acc = jnp.full_like(o_ref[...], jnp.inf)
    idx = jnp.zeros_like(ix_ref[...])
    acc, idx = jax.lax.fori_loop(0, bk // KINNER, body, (acc, idx))
    upd = acc < o_ref[...]
    o_ref[...] = jnp.where(upd, acc, o_ref[...])
    ix_ref[...] = jnp.where(upd, idx + kk * bk, ix_ref[...])


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def minplus_matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Tropical matmul C[i,j] = min_k A[i,k] + B[k,j] via pallas_call.

    Inputs are padded with BIG (the (min,+) identity) to block multiples, so
    padding never affects the valid region.
    """
    (m, k), (k2, n) = a.shape, b.shape
    assert k == k2, (a.shape, b.shape)
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)

    pad_m = (-m) % block
    pad_k = (-k) % block
    pad_n = (-n) % block
    a_p = jnp.pad(a, ((0, pad_m), (0, pad_k)), constant_values=BIG)
    b_p = jnp.pad(b, ((0, pad_k), (0, pad_n)), constant_values=BIG)
    mp, kp, np_ = m + pad_m, k + pad_k, n + pad_n

    grid = (mp // block, np_ // block, kp // block)
    out = pl.pallas_call(
        functools.partial(_minplus_kernel, bk=block, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block, block), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def minplus_matmul_argmin_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused tropical matmul + argmin: (min_k A[i,k]+B[k,j], argmin_k ...).

    This is the next-hop table of `apsp_with_nexthop` computed tile-resident:
    the [M, K, N] candidate tensor never exists, only [bm, KINNER, bn] chunks
    in VMEM. Padding uses BIG so padded k indices lose every strict-< update
    against real candidates (and on all-non-edge ties the first — real —
    index wins, matching `jnp.argmin`).
    """
    (m, k), (k2, n) = a.shape, b.shape
    assert k == k2, (a.shape, b.shape)
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)

    pad_m = (-m) % block
    pad_k = (-k) % block
    pad_n = (-n) % block
    a_p = jnp.pad(a, ((0, pad_m), (0, pad_k)), constant_values=BIG)
    b_p = jnp.pad(b, ((0, pad_k), (0, pad_n)), constant_values=BIG)
    mp, kp, np_ = m + pad_m, k + pad_k, n + pad_n

    grid = (mp // block, np_ // block, kp // block)
    val, idx = pl.pallas_call(
        functools.partial(_minplus_argmin_kernel, bk=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block, block), lambda i, j, kk: (kk, j)),
        ],
        out_specs=[
            pl.BlockSpec((block, block), lambda i, j, kk: (i, j)),
            pl.BlockSpec((block, block), lambda i, j, kk: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        ],
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a_p, b_p)
    return val[:m, :n], idx[:m, :n]
