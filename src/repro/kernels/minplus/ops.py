"""Jitted public wrappers around the min-plus kernel.

`use_pallas` selects the Pallas kernel (TPU target; `interpret=True` executes
the kernel body on CPU for validation). The default pure-jnp path is used by
the CPU test/bench/dry-run flows; on a real TPU deployment the kernel path is
enabled by the launcher when V is large enough to matter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import minplus_matmul_pallas
from .ref import minplus_matmul_ref

BIG = 1e18
BIG_THRESHOLD = 1e17


def minplus_matmul(a, b, *, use_pallas: bool = False, interpret: bool = True):
    if use_pallas:
        return minplus_matmul_pallas(a, b, interpret=interpret)
    return minplus_matmul_ref(a, b)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def apsp(w: jax.Array, *, use_pallas: bool = False, interpret: bool = True):
    """All-pairs shortest-path distances by tropical squaring.

    w: [V, V] nonnegative marginal link weights, BIG on non-edges. The
    diagonal is forced to 0 (paths may stay put). Returns [V, V] distances
    (BIG-ish where unreachable).
    """
    import math

    n = w.shape[-1]
    d = jnp.where(jnp.eye(n, dtype=bool), 0.0, w)
    n_iter = max(1, math.ceil(math.log2(max(n - 1, 2))))
    for _ in range(n_iter):
        d = jnp.minimum(d, minplus_matmul(d, d, use_pallas=use_pallas, interpret=interpret))
    return d


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def apsp_with_nexthop(w: jax.Array, *, use_pallas: bool = False, interpret: bool = True):
    """APSP distances + next-hop table.

    nexthop[i, t] = argmin_j  w[i, j] + dist[j, t]   (j over out-links of i)

    Following next-hops strictly decreases dist[., t], so the induced
    forwarding is loop-free by construction (used for phi repair/init).
    """
    dist = apsp(w, use_pallas=use_pallas, interpret=interpret)
    # cand[i, j, t] = w[i, j] + dist[j, t]
    cand = w[:, :, None] + dist[None, :, :]
    nexthop = jnp.argmin(cand, axis=1).astype(jnp.int32)  # [V, V] -> per target
    return dist, nexthop
