"""Jitted public wrappers around the min-plus kernel.

`use_pallas` selects the Pallas kernel (TPU target; `interpret=True` executes
the kernel body on CPU for validation). The default pure-jnp path is the
k-blocked streaming matmul (peak memory O(V * block_k * V)), used by the CPU
test/bench/dry-run flows; on a real TPU deployment the kernel path is enabled
by one launch flag (`--use-pallas --no-interpret`, see launch/fleet.py).

APSP has two strategies:

  * the jnp default is one exact Floyd-Warshall pass — V rank-1 relaxations
    `d <- min(d, d[:, k] + d[k, :])` that XLA fuses into a single streaming
    update per step, no O(V^3) candidate tensor and no log(V) sweep factor
    (~36x over the old one-broadcast squaring at V=512 on one CPU core);
  * the Pallas path (and any `n_iter`/warm-start caller) squares to a
    transitive fixpoint via `minplus_closure`, with an early exit: most
    topologies close in far fewer than the ceil(log2(V-1)) worst-case
    sweeps, and an extra squaring of a closed matrix is a bitwise no-op, so
    the early exit never changes the result — it only skips sweeps that
    would not have changed it.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .kernel import minplus_matmul_argmin_pallas, minplus_matmul_pallas
from .ref import minplus_matmul_blocked, minplus_matmul_ref  # noqa: F401

BIG = 1e18
BIG_THRESHOLD = 1e17

# Target-column block width for the next-hop fallback: the per-block carries
# ([V, block] value + index) stay cache-resident on the CPU path.
_NEXTHOP_BLOCK_T = 128


def minplus_matmul(a, b, *, use_pallas: bool = False, interpret: bool = True):
    if use_pallas:
        return minplus_matmul_pallas(a, b, interpret=interpret)
    return minplus_matmul_blocked(a, b)


def squaring_bound(n: int) -> int:
    """Sweeps that provably close any [n, n] seed: paths double per sweep."""
    return max(1, math.ceil(math.log2(max(n - 1, 2))))


def minplus_closure(
    d: jax.Array,
    *,
    n_iter: int | None = None,
    early_exit: bool = True,
    use_pallas: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Close `d` to its transitive (min,+) fixpoint by repeated squaring.

    `d` must be reflexive (zero diagonal) so squaring only ever shortens:
    d <- min(d, d (x) d). With `early_exit` the loop stops one sweep after
    the matrix stops changing (a fixpoint stays fixed, so the skipped sweeps
    are bitwise no-ops); `n_iter` overrides the worst-case sweep cap.
    Also the warm-start re-closure primitive for incremental hop bounds
    (core/structs.hop_bound_cache): a seed that already contains every
    1-hop edge closes under the same doubling argument.
    """
    n = d.shape[-1]
    sweeps = squaring_bound(n) if n_iter is None else max(1, int(n_iter))

    def sweep(x):
        return jnp.minimum(
            x, minplus_matmul(x, x, use_pallas=use_pallas, interpret=interpret)
        )

    if not early_exit:
        for _ in range(sweeps):
            d = sweep(d)
        return d

    def cond(carry):
        _, i, changed = carry
        return jnp.logical_and(i < sweeps, changed)

    def body(carry):
        x, i, _ = carry
        x_new = sweep(x)
        return x_new, i + 1, jnp.any(x_new != x)

    d, _, _ = jax.lax.while_loop(cond, body, (d, jnp.int32(0), jnp.bool_(True)))
    return d


def _apsp_fw(d: jax.Array) -> jax.Array:
    """One exact Floyd-Warshall pass: V fused rank-1 (min,+) relaxations."""
    v = d.shape[-1]

    def body(k, d):
        row = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=0)  # [1, V]
        col = jax.lax.dynamic_slice_in_dim(d, k, 1, axis=1)  # [V, 1]
        return jnp.minimum(d, col + row)

    return jax.lax.fori_loop(0, v, body, d)


@functools.partial(
    jax.jit, static_argnames=("n_iter", "early_exit", "use_pallas", "interpret")
)
def apsp(
    w: jax.Array,
    *,
    n_iter: int | None = None,
    early_exit: bool = True,
    use_pallas: bool = False,
    interpret: bool = True,
):
    """All-pairs shortest-path distances.

    w: [V, V] nonnegative marginal link weights, BIG on non-edges. The
    diagonal is forced to 0 (paths may stay put). Returns [V, V] distances
    (BIG-ish where unreachable).

    The jnp default runs Floyd-Warshall (exact, single pass, O(V^2) memory).
    `use_pallas` — or an explicit `n_iter` sweep override — selects the
    tropical-squaring closure instead (the blocked Pallas kernel's native
    shape); `early_exit` then stops squaring once the matrix is closed.
    """
    n = w.shape[-1]
    d = jnp.where(jnp.eye(n, dtype=bool), 0.0, w.astype(jnp.float32))
    if not use_pallas and n_iter is None:
        return _apsp_fw(d)
    return minplus_closure(
        d,
        n_iter=n_iter,
        early_exit=early_exit,
        use_pallas=use_pallas,
        interpret=interpret,
    )


def _nexthop_blocked(w: jax.Array, dist: jax.Array) -> jax.Array:
    """argmin_j w[i, j] + dist[j, t] without the [V, V, V] candidate tensor.

    Target columns are scanned in `_NEXTHOP_BLOCK_T`-wide blocks; within a
    block, j advances as V fused rank-1 relaxations carrying (best, idx) —
    no argmin reduction ever runs, only elementwise compare/select on
    cache-resident [V, block] carries (the reduce-based argmin is ~4x
    slower on CPU). Strict `<` with ascending j reproduces the full-tensor
    `jnp.argmin` first-minimum tie-break exactly. Peak memory O(V^2).
    """
    v = w.shape[-1]
    bt = min(v, _NEXTHOP_BLOCK_T)
    pad = (-v) % bt
    nb = (v + pad) // bt
    d_cols = jnp.pad(dist, ((0, 0), (0, pad)), constant_values=jnp.inf)
    d3 = jnp.moveaxis(d_cols.reshape(v, nb, bt), 1, 0)  # [nb, V, bt]

    def block(_, d_b):  # d_b = dist[:, t0:t0+bt]
        def body(j, carry):
            best, idx = carry
            cand = jax.lax.dynamic_slice_in_dim(
                w, j, 1, axis=1
            ) + jax.lax.dynamic_slice_in_dim(d_b, j, 1, axis=0)
            upd = cand < best
            return jnp.where(upd, cand, best), jnp.where(upd, j, idx)

        best0 = jnp.full((v, bt), jnp.inf, jnp.float32)
        idx0 = jnp.zeros((v, bt), jnp.int32)
        _, idx = jax.lax.fori_loop(0, v, body, (best0, idx0))
        return None, idx

    _, nh = jax.lax.scan(block, None, d3)  # [nb, V, bt]
    nh = jnp.moveaxis(nh, 0, 1).reshape(v, nb * bt)
    return nh[:, :v]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def apsp_with_nexthop(w: jax.Array, *, use_pallas: bool = False, interpret: bool = True):
    """APSP distances + next-hop table.

    nexthop[i, t] = argmin_j  w[i, j] + dist[j, t]   (j over out-links of i)

    Following next-hops strictly decreases dist[., t], so the induced
    forwarding is loop-free by construction (used for phi repair/init).
    On the Pallas path the table comes from the fused min+argmin kernel
    (kernel.py); the fallback scans target-column blocks. Both paths are
    O(V^2) peak memory and share the first-minimum tie-break.
    """
    dist = apsp(w, use_pallas=use_pallas, interpret=interpret)
    if use_pallas:
        _, nexthop = minplus_matmul_argmin_pallas(w, dist, interpret=interpret)
    else:
        nexthop = _nexthop_blocked(w, dist)
    return dist, nexthop
