"""Pallas TPU kernels for the perf-critical compute hot-spots.

  minplus          tropical (min,+) matmul — the APSP inner loop of the
                   paper's placement step (TPU-native Dijkstra replacement)
  neumann          fused batched Neumann propagation hops — the loop-free
                   flow / cost-to-go fixed points of the ALT hot loop
                   (replaces the dense LU solves; DESIGN.md section 10)
  flash_attention  blockwise GQA attention for the model zoo's dominant op

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec), ops.py
(jitted wrapper + jnp dispatch), ref.py (pure-jnp oracle used by tests).
"""
