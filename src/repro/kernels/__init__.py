"""Pallas TPU kernels for the perf-critical compute hot-spots.

  minplus          tropical (min,+) matmul — the APSP inner loop of the
                   paper's placement step (TPU-native Dijkstra replacement)
  flash_attention  blockwise GQA attention for the model zoo's dominant op

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec), ops.py
(jitted wrapper + jnp dispatch), ref.py (pure-jnp oracle used by tests).
"""
