"""Pure-jnp oracle for the truncated-Neumann propagation solve.

Under loop-free forwarding Phi is nilpotent (Phi^p = 0 with p bounded by the
longest forwarding path + 1), so

    (I - M) x = b        ==>        x = sum_{m=0}^{H} M^m b

exactly, for any H >= p - 1. The oracle below evaluates the series by the
equivalent propagation recurrence x_{m+1} = b + M x_m (x_0 = b), which is
what the production paths (ops.py / kernel.py) implement with an early-exit
residual check. This file keeps the fixed-hop, no-early-exit form so tests
can compare both production paths against a dead-simple reference and
against `jnp.linalg.solve`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def neumann_solve_ref(m: jax.Array, b: jax.Array, hops: int) -> jax.Array:
    """x = sum_{k=0}^{hops} m^k b via `hops` propagation steps.

    m: [..., V, V] propagation operator, b: [..., V]. Batch dims broadcast.
    """
    x = b
    for _ in range(hops):
        x = b + jnp.einsum("...ij,...j->...i", m, x)
    return x


def lu_solve_ref(m: jax.Array, b: jax.Array) -> jax.Array:
    """(I - m)^{-1} b by dense LU — the pre-propagation reference path."""
    n = m.shape[-1]
    eye = jnp.eye(n, dtype=m.dtype)
    return jnp.linalg.solve(eye - m, b[..., None])[..., 0]
