"""Public wrappers around the Neumann propagation solve.

`neumann_solve(m, b)` solves (I - m) x = b for stacked batches of
propagation operators (m: [..., V, V], b: [..., V]) by the truncated Neumann
recurrence x <- b + m x, wrapped in `jax.lax.custom_linear_solve` so that

  * reverse-mode differentiation works without unrolling the hop loop
    (the cotangent solve is itself a Neumann solve on m^T, via
    `transpose_solve`), keeping Gallager's identity test (grad == q) on the
    propagation path;
  * the forward pass is free to use a genuine early-exit `while_loop`
    (not reverse-differentiable on its own) or the fused Pallas kernel.

Hop budget: the exact part of the series is bounded by the longest
forwarding path (<= graph diameter + 2 host re-injections for loop-free
phi; `Problem.hop_bound` carries that). Mid-refinement, the blocking rule
tolerates transient cycles whose gain shrinks geometrically (DESIGN.md
section 10), so `effective_hops` adds a fixed slack that the early-exit
check makes free whenever phi is already nilpotent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import neumann_solve_pallas

# Extra hops past the nilpotent bound, absorbing the geometric tail of
# blocking-rule transient cycles (gain <= 1 - alpha per sweep; see
# DESIGN.md section 10 for the derivation). Early exit makes the slack
# cost nothing once phi is loop-free.
NEUMANN_SLACK = 32

# Early-exit threshold: consecutive iterates agreeing to this relative
# tolerance terminate the hop loop (fp32 headroom below the 1e-5 parity
# contract with the LU path).
DEFAULT_TOL = 1e-6


def effective_hops(
    hop_bound: int | None, n_nodes: int, fixed_loop: bool = False
) -> int:
    """Hop cap for one solve.

    With `fixed_loop=False` (the XLA while_loop path) the floor is the
    nilpotency-index bound V + 1 — refined multipath forwarding may route
    along loop-free paths longer than the diameter, so the Problem-carried
    bound alone is the *expected* exit point (where the early-exit check
    typically fires), not a hard guarantee. Maxing with V + 1 makes the cap
    exact for every truly nilpotent phi, and costs nothing: the while_loop
    exits on the residual. The slack then only has to absorb the geometric
    tail of transient blocking-rule cycles (gain <= 1 - alpha per sweep —
    at very small alpha that tail thins slowly and the cap can truncate;
    parity with LU is then governed by the residual tolerance, see
    DESIGN.md section 10).

    With `fixed_loop=True` (the fused Pallas kernel, whose fori_loop always
    executes every hop — 'done' only freezes the carry) the V + 1 floor
    would cost O(V^3) wasted matvecs, so the cap is hop_bound + slack: the
    kernel trades exactness on longer-than-diameter multipath chains for
    the O(V/H) roofline advantage it exists for."""
    base = int(hop_bound) if hop_bound is not None else n_nodes + 1
    if not fixed_loop:
        base = max(base, n_nodes + 1)
    return base + NEUMANN_SLACK


def _bmv(m: jax.Array, x: jax.Array) -> jax.Array:
    """Batched matvec (m x) over arbitrary shared leading dims."""
    return jnp.einsum("...ij,...j->...i", m, x)


def _propagate_xla(m: jax.Array, b: jax.Array, hops: int, tol: float) -> jax.Array:
    """Early-exit propagation: x <- b + m x until every iterate settles.

    One while_loop drives the whole stacked solve — each hop is a single
    batched matvec (BLAS-3 shaped on TPU, one fused einsum on CPU). The
    convergence test is PER batch element (residual vs that element's own
    magnitude): a batch-global relative residual would let a large
    fast-converging element mask a small slow-converging one and truncate
    its series arbitrarily early. The loop runs until the slowest element
    converges; already-settled elements keep iterating but their iterates
    are fixed points, so extra hops leave them bitwise unchanged.
    """

    def cond(carry):
        _, k, unconverged = carry
        return jnp.logical_and(k < hops, unconverged)

    def body(carry):
        x, k, _ = carry
        x_new = b + _bmv(m, x)
        resid = jnp.max(jnp.abs(x_new - x), axis=-1)   # [...batch]
        scale = jnp.max(jnp.abs(x_new), axis=-1)       # [...batch]
        unconverged = jnp.any(resid > tol * scale + 1e-30)
        return x_new, k + 1, unconverged

    init = (b, jnp.int32(0), jnp.bool_(True))
    x, _, _ = jax.lax.while_loop(cond, body, init)
    return x


def _propagate_pallas(
    m: jax.Array, b: jax.Array, hops: int, tol: float, interpret: bool,
    block_k: int | None = None, operand_dtype=None,
) -> jax.Array:
    """Flatten leading batch dims and run the fused kernel."""
    batch_shape = b.shape[:-1]
    v = b.shape[-1]
    m2 = m.reshape((-1, v, v))
    b2 = b.reshape((-1, v))
    out = neumann_solve_pallas(
        m2, b2, hops=hops, tol=tol, interpret=interpret,
        block_k=block_k, operand_dtype=operand_dtype,
    )
    return out.reshape(batch_shape + (v,))


def neumann_solve(
    m: jax.Array,
    b: jax.Array,
    *,
    hops: int,
    tol: float = DEFAULT_TOL,
    use_pallas: bool = False,
    interpret: bool = True,
    block_k: int | None = None,
    operand_dtype=None,
) -> jax.Array:
    """Solve (I - m) x = b by truncated Neumann propagation.

    m: [..., V, V] propagation operator (pass phi^T for the traffic fixed
    point (I - Phi^T) t = b, phi for the cost-to-go (I - Phi) q = c);
    b: [..., V] with matching batch dims. Differentiable in both m and b.

    `block_k` / `operand_dtype` select the K-tiled Pallas kernel explicitly
    (V > MAX_VMEM_V auto-tiles); `operand_dtype=jnp.bfloat16` streams the
    operator in bf16 with fp32 accumulation (kernel.py). Both are ignored
    on the XLA path.
    """

    def run(op, rhs):
        if use_pallas:
            return _propagate_pallas(
                op, rhs, hops, tol, interpret,
                block_k=block_k, operand_dtype=operand_dtype,
            )
        return _propagate_xla(op, rhs, hops, tol)

    mt = jnp.swapaxes(m, -1, -2)
    return jax.lax.custom_linear_solve(
        lambda x: x - _bmv(m, x),
        b,
        solve=lambda _, rhs: run(m, rhs),
        transpose_solve=lambda _, rhs: run(mt, rhs),
    )
