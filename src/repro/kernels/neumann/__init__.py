from .ops import (  # noqa: F401
    DEFAULT_TOL,
    NEUMANN_SLACK,
    effective_hops,
    neumann_solve,
)
from .ref import lu_solve_ref, neumann_solve_ref  # noqa: F401
