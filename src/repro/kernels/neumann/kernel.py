"""Pallas TPU kernel: fused batched Neumann propagation hops.

Solves (I - M) x = b for a batch of independent [V, V] operators by keeping
each operator resident in VMEM and iterating the propagation recurrence

    x <- b + M x

for a fixed (Problem-derived) hop cap, with an early-exit residual check
folded into the loop: once two consecutive iterates agree to `tol`
(relative), the carry freezes and the remaining hops are no-ops. On the LU
path every solve re-factorizes a [V, V] matrix from HBM at O(V^3) MXU-hostile
work; here the operator is loaded once and each hop is a single [1, Vp] x
[Vp, Vp] MXU matvec at O(V^2), so a hop cap H gives an O(V/H) flop advantage
and a single-load memory profile (the roofline argument in DESIGN.md
section 10).

Layout: the caller passes the *transposed* operator W = M^T so the iterate
can live as a row vector — x_new = b + x @ W — which keeps the V axis on the
lane dimension (128-aligned) and the matvec on the MXU. Batch is the grid's
only dimension; each grid step owns one operator.

VMEM budget per grid step (fp32): W tile Vp^2 * 4 B + three [1, Vp] rows.
Vp = 512 -> 1 MiB, Vp = 1024 -> 4 MiB. Past `MAX_VMEM_V` the operator no
longer fits VMEM whole, so the wrapper switches to the K-TILED kernel: the
grid grows (hops, k_tiles) axes, W streams through VMEM as [block_k, Vp]
row tiles, and the iterate x plus the hop accumulator live in VMEM scratch
that persists across the sequential grid steps (the standard Pallas-TPU
revisiting pattern — scratch carries state between grid iterations of the
same batch element). An opt-in `operand_dtype=jnp.bfloat16` streams W (and
feeds the MXU) in bf16 while the accumulator, iterate, and residual check
stay fp32 — the mixed-precision contract of DESIGN.md section 16.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Renamed TPUCompilerParams -> CompilerParams across JAX releases.
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

LANE = 128
MAX_VMEM_V = 1024
# Contraction-axis tile of the K-tiled kernel: [block_k, Vp] W row tiles.
# 512 keeps the streamed tile at Vp = 2048 under 4 MiB in fp32.
DEFAULT_BLOCK_K = 512


def _neumann_kernel(w_ref, b_ref, o_ref, *, hops: int, tol: float):
    """One grid step: propagate one batch element's RHS through W = M^T."""
    b = b_ref[...]  # [1, Vp]
    w = w_ref[...]  # [Vp, Vp]

    def body(_, carry):
        x, done = carry
        x_new = b + jnp.dot(x, w, preferred_element_type=jnp.float32)
        resid = jnp.max(jnp.abs(x_new - x))
        scale = jnp.max(jnp.abs(x_new)) + 1e-30
        done_new = jnp.logical_or(done, resid <= tol * scale)
        return jnp.where(done, x, x_new), done_new

    x, _ = jax.lax.fori_loop(0, hops, body, (b, jnp.bool_(False)))
    o_ref[...] = x


def _neumann_tiled_kernel(
    w_ref, b_ref, o_ref, x_ref, acc_ref, done_ref, *,
    hops: int, tol: float, nk: int, bk: int,
):
    """K-tiled grid step: one [block_k, Vp] W row tile of one hop.

    Grid (batch, hops, k_tiles), K innermost. Scratch persists across the
    sequential (hops, k_tiles) steps of one batch element:

      x_ref    [1, Vp] fp32 VMEM — the current iterate
      acc_ref  [1, Vp] fp32 VMEM — this hop's b + x @ W partial sum
      done_ref [1] int32 SMEM    — the residual-freeze flag

    The hop closes on the last K tile with the exact done-before-freeze
    semantics of `_neumann_kernel`: the converging iteration's x_new IS
    applied, later hops keep the frozen carry. When W streams in bf16 the
    x chunk is cast to match, but the dot always accumulates fp32
    (`preferred_element_type`) and the residual test runs on the fp32
    scratch values.
    """
    h = pl.program_id(1)
    kk = pl.program_id(2)

    @pl.when(jnp.logical_and(h == 0, kk == 0))
    def _init():
        x_ref[...] = b_ref[...]
        done_ref[0] = 0

    @pl.when(kk == 0)
    def _reset():
        acc_ref[...] = b_ref[...]

    x_chunk = x_ref[:, pl.ds(kk * bk, bk)]  # [1, bk]
    w_tile = w_ref[...]  # [bk, Vp], possibly bf16
    acc_ref[...] += jnp.dot(
        x_chunk.astype(w_tile.dtype), w_tile,
        preferred_element_type=jnp.float32,
    )

    @pl.when(kk == nk - 1)
    def _finish():
        x_old = x_ref[...]
        x_new = acc_ref[...]
        resid = jnp.max(jnp.abs(x_new - x_old))
        scale = jnp.max(jnp.abs(x_new)) + 1e-30
        done = done_ref[0] > 0
        x_ref[...] = jnp.where(done, x_old, x_new)
        done_ref[0] = jnp.logical_or(done, resid <= tol * scale).astype(
            jnp.int32
        )

    @pl.when(jnp.logical_and(h == hops - 1, kk == nk - 1))
    def _out():
        o_ref[...] = x_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("hops", "tol", "interpret", "block_k", "operand_dtype"),
)
def neumann_solve_pallas(
    m: jax.Array,
    b: jax.Array,
    *,
    hops: int,
    tol: float = 1e-6,
    interpret: bool = False,
    block_k: int | None = None,
    operand_dtype=None,
) -> jax.Array:
    """x = (I - m)^{-1} b (truncated Neumann) for m: [N, V, V], b: [N, V].

    The V axis is zero-padded to a lane multiple; padded coordinates carry
    zero source and zero coupling, so they stay exactly zero through every
    hop and never contaminate the valid region (bf16 casts preserve exact
    zeros, so the invariant survives mixed precision too).

    Dispatch: V <= MAX_VMEM_V with default precision keeps the original
    single-tile kernel (operator resident in VMEM, fori_loop over hops).
    Larger V — or an explicit `block_k` / `operand_dtype` — selects the
    K-tiled kernel: grid (batch, hops, k_tiles) with W streamed as
    [block_k, Vp] row tiles and the iterate carried in VMEM scratch.
    `operand_dtype=jnp.bfloat16` halves the streamed W traffic; the
    accumulator and residual check stay fp32.
    """
    n_batch, v, v2 = m.shape
    assert v == v2 and b.shape == (n_batch, v), (m.shape, b.shape)
    assert hops >= 1, hops
    m = m.astype(jnp.float32)
    b = b.astype(jnp.float32)

    if v <= MAX_VMEM_V and block_k is None and operand_dtype is None:
        pad_v = (-v) % LANE
        vp = v + pad_v
        w = jnp.pad(
            jnp.swapaxes(m, -1, -2), ((0, 0), (0, pad_v), (0, pad_v))
        )
        b_p = jnp.pad(b, ((0, 0), (0, pad_v)))
        out = pl.pallas_call(
            functools.partial(_neumann_kernel, hops=hops, tol=tol),
            grid=(n_batch,),
            in_specs=[
                pl.BlockSpec((None, vp, vp), lambda i: (i, 0, 0)),
                pl.BlockSpec((1, vp), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((1, vp), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((n_batch, vp), jnp.float32),
            compiler_params=_COMPILER_PARAMS_CLS(
                dimension_semantics=("parallel",)
            ),
            interpret=interpret,
        )(w, b_p)
        return out[:, :v]

    bk = DEFAULT_BLOCK_K if block_k is None else int(block_k)
    if bk % LANE:
        raise ValueError(f"block_k must be a multiple of {LANE}, got {bk}")
    bk = min(bk, -(-v // LANE) * LANE)
    vp = -(-v // bk) * bk  # pad V to a whole number of K tiles
    nk = vp // bk
    pad_v = vp - v
    w = jnp.pad(jnp.swapaxes(m, -1, -2), ((0, 0), (0, pad_v), (0, pad_v)))
    if operand_dtype is not None:
        w = w.astype(operand_dtype)
    b_p = jnp.pad(b, ((0, 0), (0, pad_v)))

    out = pl.pallas_call(
        functools.partial(
            _neumann_tiled_kernel, hops=hops, tol=tol, nk=nk, bk=bk
        ),
        grid=(n_batch, hops, nk),
        in_specs=[
            pl.BlockSpec((None, bk, vp), lambda i, h, k: (i, k, 0)),
            pl.BlockSpec((1, vp), lambda i, h, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, vp), lambda i, h, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_batch, vp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, vp), jnp.float32),
            pltpu.VMEM((1, vp), jnp.float32),
            pltpu.SMEM((1,), jnp.int32),
        ],
        compiler_params=_COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")
        ),
        interpret=interpret,
    )(w, b_p)
    return out[:, :v]
