"""Pallas TPU kernel: fused batched Neumann propagation hops.

Solves (I - M) x = b for a batch of independent [V, V] operators by keeping
each operator resident in VMEM and iterating the propagation recurrence

    x <- b + M x

for a fixed (Problem-derived) hop cap, with an early-exit residual check
folded into the loop: once two consecutive iterates agree to `tol`
(relative), the carry freezes and the remaining hops are no-ops. On the LU
path every solve re-factorizes a [V, V] matrix from HBM at O(V^3) MXU-hostile
work; here the operator is loaded once and each hop is a single [1, Vp] x
[Vp, Vp] MXU matvec at O(V^2), so a hop cap H gives an O(V/H) flop advantage
and a single-load memory profile (the roofline argument in DESIGN.md
section 10).

Layout: the caller passes the *transposed* operator W = M^T so the iterate
can live as a row vector — x_new = b + x @ W — which keeps the V axis on the
lane dimension (128-aligned) and the matvec on the MXU. Batch is the grid's
only dimension; each grid step owns one operator.

VMEM budget per grid step (fp32): W tile Vp^2 * 4 B + three [1, Vp] rows.
Vp = 512 -> 1 MiB, Vp = 1024 -> 4 MiB; beyond that the operator must be
tiled over K like the minplus kernel (not needed at the paper's scales —
guarded by an assert).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Renamed TPUCompilerParams -> CompilerParams across JAX releases.
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

LANE = 128
MAX_VMEM_V = 1024


def _neumann_kernel(w_ref, b_ref, o_ref, *, hops: int, tol: float):
    """One grid step: propagate one batch element's RHS through W = M^T."""
    b = b_ref[...]  # [1, Vp]
    w = w_ref[...]  # [Vp, Vp]

    def body(_, carry):
        x, done = carry
        x_new = b + jnp.dot(x, w, preferred_element_type=jnp.float32)
        resid = jnp.max(jnp.abs(x_new - x))
        scale = jnp.max(jnp.abs(x_new)) + 1e-30
        done_new = jnp.logical_or(done, resid <= tol * scale)
        return jnp.where(done, x, x_new), done_new

    x, _ = jax.lax.fori_loop(0, hops, body, (b, jnp.bool_(False)))
    o_ref[...] = x


@functools.partial(jax.jit, static_argnames=("hops", "tol", "interpret"))
def neumann_solve_pallas(
    m: jax.Array,
    b: jax.Array,
    *,
    hops: int,
    tol: float = 1e-6,
    interpret: bool = False,
) -> jax.Array:
    """x = (I - m)^{-1} b (truncated Neumann) for m: [N, V, V], b: [N, V].

    The V axis is zero-padded to a lane multiple; padded coordinates carry
    zero source and zero coupling, so they stay exactly zero through every
    hop and never contaminate the valid region.
    """
    n_batch, v, v2 = m.shape
    assert v == v2 and b.shape == (n_batch, v), (m.shape, b.shape)
    assert v <= MAX_VMEM_V, (
        f"V={v} exceeds the single-tile VMEM budget (max {MAX_VMEM_V}); "
        "tile the operator over K before raising this limit"
    )
    m = m.astype(jnp.float32)
    b = b.astype(jnp.float32)

    pad_v = (-v) % LANE
    vp = v + pad_v
    w = jnp.pad(jnp.swapaxes(m, -1, -2), ((0, 0), (0, pad_v), (0, pad_v)))
    b_p = jnp.pad(b, ((0, 0), (0, pad_v)))

    out = pl.pallas_call(
        functools.partial(_neumann_kernel, hops=hops, tol=tol),
        grid=(n_batch,),
        in_specs=[
            pl.BlockSpec((None, vp, vp), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, vp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, vp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_batch, vp), jnp.float32),
        compiler_params=_COMPILER_PARAMS_CLS(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(w, b_p)
    return out[:, :v]
