"""Scenario-fleet generation beyond the paper's four fixed topologies.

core/scenarios.py reproduces the paper's evaluation set (IoT, Mesh,
SmallWorld, GEANT). A production control plane re-optimizes over whatever
the field serves up, so this module samples *families* of instances —
reproducibly, from a single integer seed — for the batched fleet solver:

  erdos_renyi       G(n, p) with heterogeneous link/compute rates
  barabasi_albert   preferential attachment (hub-heavy edge cores)
  iot_hierarchy     randomized cloud / edge-ring / device trees in the
                    style of the paper's Fig. 3, with jittered fan-outs,
                    tiers and rates
  perturbed_geant   degree-preserving rewirings + rate jitter around the
                    GEANT backbone (robustness of the Fig-2 conclusions to
                    topology measurement noise)

plus grid helpers (`load_grid`, `eta_grid`) that turn one base scenario
into the Fig-4 load sweep or the Fig-5 comm/comp operating-point sweep as a
single fleet, and `sample_fleet` which mixes families into one ensemble of
hundreds of distinct instances.

Every function returns an ordinary `Problem`; nothing here knows about
padding or batching (fleet/pad.py handles shape heterogeneity).
"""
from __future__ import annotations

import numpy as np

from ..core import scenarios as S
from ..core.scenarios import build_network, gen_apps
from ..core.structs import CostModel, Problem, with_hop_bound


def _hetero_rates(rng, edges, n, mu_range=(5.0, 15.0), nu_range=(5.0, 15.0)):
    nu = rng.uniform(*nu_range, size=n).astype(np.float32)
    mu_map = {e: float(rng.uniform(*mu_range)) for e in edges}
    return mu_map, nu


def erdos_renyi(
    n: int,
    n_apps: int,
    p: float | None = None,
    seed: int = 0,
    load_scale: float = 1.0,
    cost: CostModel | None = None,
    n_parts: int | None = None,
) -> Problem:
    """Connected G(n, p); defaults to expected degree ~4. Retries with a
    densified p on the rare disconnected draw so the seed fully determines
    the instance."""
    import networkx as nx

    if p is None:
        p = min(1.0, 4.0 / max(n - 1, 1))
    g = None
    for attempt in range(64):
        cand = nx.gnp_random_graph(n, min(1.0, p * (1.15**attempt)), seed=seed + 7919 * attempt)
        if nx.is_connected(cand):
            g = cand
            break
    if g is None:  # pragma: no cover - p has been pushed to ~1 by now
        raise RuntimeError(f"could not draw a connected G({n}, {p})")
    edges = list(g.edges())
    rng = np.random.RandomState(seed + 1)
    mu_map, nu = _hetero_rates(rng, edges, n)
    net = build_network(n, edges, mu_map, nu)
    apps = gen_apps(
        rng, n_apps, np.arange(n), "random", n, load_scale=load_scale,
        n_parts=n_parts,
    )
    return with_hop_bound(Problem(net=net, apps=apps, cost=cost or CostModel()))


def barabasi_albert(
    n: int,
    n_apps: int,
    m_attach: int = 2,
    seed: int = 0,
    load_scale: float = 1.0,
    cost: CostModel | None = None,
    n_parts: int | None = None,
) -> Problem:
    """Preferential attachment: connected by construction, hub-heavy — the
    opposite degree mix of the regular mesh."""
    import networkx as nx

    g = nx.barabasi_albert_graph(n, max(1, m_attach), seed=seed)
    edges = list(g.edges())
    rng = np.random.RandomState(seed + 1)
    mu_map, nu = _hetero_rates(rng, edges, n)
    # Hubs get proportionally stronger compute (they are the natural edge
    # servers of an attachment-grown deployment).
    deg = np.asarray([d for _, d in sorted(g.degree())], np.float32)
    nu = (nu * (0.5 + deg / deg.mean())).astype(np.float32)
    net = build_network(n, edges, mu_map, nu)
    apps = gen_apps(
        rng, n_apps, np.arange(n), "random", n, load_scale=load_scale,
        n_parts=n_parts,
    )
    return with_hop_bound(Problem(net=net, apps=apps, cost=cost or CostModel()))


def iot_hierarchy(
    n_edge: int | None = None,
    devices_per_edge: int | None = None,
    n_apps: int | None = None,
    seed: int = 0,
    load_scale: float = 1.0,
    cost: CostModel | None = None,
    n_parts: int | None = None,
) -> Problem:
    """Randomized cloud / edge-ring / IoT-device hierarchy (Fig.-3 style).

    Node 0 is the cloud; nodes 1..E are ring-connected edge servers with
    cloud uplinks; devices hang off 1-2 randomly chosen edge servers.
    Capacities are jittered around the fixed scenario's values, preserving
    the cloud >> edge >> device compute ordering that creates the paper's
    split-placement tension. Apps source (and sink) at devices.
    """
    rng = np.random.RandomState(seed)
    e = int(n_edge if n_edge is not None else rng.randint(3, 7))
    dpe = int(
        devices_per_edge if devices_per_edge is not None else rng.randint(2, 5)
    )
    n_dev = e * dpe
    n = 1 + e + n_dev
    edges, mu_map = [], {}
    for i in range(e):  # edge ring
        a, b = 1 + i, 1 + ((i + 1) % e)
        edges.append((a, b))
        mu_map[(a, b)] = float(rng.uniform(12.0, 20.0))
    for srv in range(1, e + 1):  # cloud uplinks
        edges.append((srv, 0))
        mu_map[(srv, 0)] = float(rng.uniform(9.0, 15.0))
    first_dev = 1 + e
    for d in range(n_dev):  # dual-homed devices on weak links
        dev = first_dev + d
        homes = {1 + (d % e)}
        if rng.rand() < 0.7:
            homes.add(1 + rng.randint(e))
        for srv in sorted(homes):
            edges.append((dev, srv))
            mu_map[(dev, srv)] = float(rng.uniform(5.0, 10.0))
    nu = np.concatenate(
        [
            rng.uniform(60.0, 100.0, size=1),  # cloud
            rng.uniform(9.0, 15.0, size=e),  # edge servers
            rng.uniform(1.5, 3.0, size=n_dev),  # devices
        ]
    ).astype(np.float32)
    net = build_network(n, edges, mu_map, nu)
    a = int(n_apps if n_apps is not None else max(4, int(1.5 * n_dev)))
    apps = gen_apps(
        rng, a, np.arange(first_dev, n), "same", n, load_scale=load_scale,
        n_parts=n_parts,
    )
    return with_hop_bound(Problem(net=net, apps=apps, cost=cost or CostModel()))


def perturbed_geant(
    seed: int = 0,
    rewire_frac: float = 0.15,
    rate_jitter: float = 0.25,
    n_apps: int = 30,
    load_scale: float = 1.0,
    cost: CostModel | None = None,
    n_parts: int | None = None,
) -> Problem:
    """Degree-preserving rewiring + multiplicative rate jitter around GEANT.

    `connected_double_edge_swap` keeps the graph connected and every node's
    degree fixed, so the family isolates *wiring* robustness from capacity
    and degree effects."""
    import networkx as nx

    g = nx.Graph(S._GEANT_EDGES)
    n = g.number_of_nodes()
    nswap = max(1, int(rewire_frac * g.number_of_edges()))
    # connected_double_edge_swap mutates in place and needs its own seed.
    nx.connected_double_edge_swap(g, nswap, seed=seed + 13)
    edges = list(g.edges())
    rng = np.random.RandomState(seed + 1)
    jit = lambda size: rng.uniform(1.0 - rate_jitter, 1.0 + rate_jitter, size)
    nu = (10.0 * jit(n)).astype(np.float32)
    mu_map = {e: float(10.0 * jit(1)[0]) for e in edges}
    net = build_network(n, edges, mu_map, nu)
    apps = gen_apps(
        rng, n_apps, np.arange(n), "random", n, load_scale=load_scale,
        n_parts=n_parts,
    )
    return with_hop_bound(Problem(net=net, apps=apps, cost=cost or CostModel()))


FAMILIES = {
    "erdos_renyi": erdos_renyi,
    "barabasi_albert": barabasi_albert,
    "iot_hierarchy": iot_hierarchy,
    "perturbed_geant": perturbed_geant,
}


def load_grid(base, scales, cost: CostModel | None = None, **kw) -> list[Problem]:
    """One fleet = one scenario under a grid of load scales (Fig-4 axis)."""
    return [base(load_scale=float(f), cost=cost, **kw) for f in scales]


def eta_grid(base, etas, **kw) -> list[Problem]:
    """One fleet = one scenario under a grid of comm/comp weightings
    (Fig-5 axis): J_eta = eta * J_comm + (1 - eta) * J_comp."""
    return [
        base(cost=CostModel(w_comm=float(eta), w_comp=1.0 - float(eta)), **kw)
        for eta in etas
    ]


def sample_fleet(
    n_instances: int,
    families=None,
    seed: int = 0,
    n_range=(12, 28),
    apps_range=(6, 20),
    load_range=(0.5, 1.2),
    cost: CostModel | None = None,
    partitions=None,
) -> list[Problem]:
    """Sample a mixed ensemble of `n_instances` distinct problems.

    Families are cycled round-robin; per-instance sizes, loads, and family
    seeds are drawn from one master RandomState so the whole fleet is a pure
    function of `seed`. Suitable for fleets of hundreds of instances: the
    padded envelope is independent of fleet size — bounded by
    `n_range`/`apps_range` for the ER/BA families and by the (fixed) size
    distributions of iot_hierarchy (<= 31 nodes / 36 apps at defaults) and
    perturbed_geant (22 nodes).

    `partitions` is an optional sequence of split depths (e.g. (1, 2, 3))
    cycled round-robin across instances, so the sampled fleet exercises
    heterogeneous P — padded to one K envelope with phantom stages by
    `fleet.stack_problems` (DESIGN.md section 13). None keeps the paper's
    P = 2 profile everywhere.
    """
    if families is None:
        families = list(FAMILIES)
    unknown = [f for f in families if f not in FAMILIES]
    if unknown:
        raise ValueError(
            f"unknown families {unknown}; expected a subset of {sorted(FAMILIES)}"
        )
    if partitions is not None and not all(int(p) >= 1 for p in partitions):
        raise ValueError(f"partitions must all be >= 1, got {partitions}")
    master = np.random.RandomState(seed)
    fleet = []
    for i in range(n_instances):
        fam = families[i % len(families)]
        sub = int(master.randint(0, 2**31 - 1))
        load = float(master.uniform(*load_range))
        parts = (
            None if partitions is None else int(partitions[i % len(partitions)])
        )
        if fam == "iot_hierarchy":
            fleet.append(
                iot_hierarchy(seed=sub, load_scale=load, cost=cost, n_parts=parts)
            )
        elif fam == "perturbed_geant":
            fleet.append(
                perturbed_geant(seed=sub, load_scale=load, cost=cost, n_parts=parts)
            )
        else:
            n = int(master.randint(n_range[0], n_range[1] + 1))
            a = int(master.randint(apps_range[0], apps_range[1] + 1))
            fleet.append(
                FAMILIES[fam](
                    n, a, seed=sub, load_scale=load, cost=cost, n_parts=parts
                )
            )
    return fleet
