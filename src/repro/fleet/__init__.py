"""Fleet engine: batched multi-scenario ALT solving over padded ensembles.

Pads heterogeneous `Problem` instances to a common (V, A) envelope with
validity masks (pad.py), stacks them into one pytree, and runs the whole
ALT pipeline vmapped over the instance axis as a single jitted computation
(solve.py). generator.py samples reproducible scenario fleets well beyond
the paper's four fixed topologies. See DESIGN.md section 9.
"""
from .pad import (  # noqa: F401
    NU_PAD,
    EmptyFleetError,
    PadInfo,
    fleet_envelope,
    fleet_part_envelope,
    pad_apps,
    pad_batch_to_multiple,
    pad_network,
    pad_problem,
    pad_problem_parts,
    stack_problems,
    unify_hop_bound,
)
from ..obs.roundtrace import FleetTrace  # noqa: F401
from .solve import (  # noqa: F401
    METHODS,
    FleetResult,
    ShardPlan,
    envelope_cap_chunk,
    solve_fleet,
    solve_sequential,
)
from .generator import (  # noqa: F401
    FAMILIES,
    barabasi_albert,
    erdos_renyi,
    eta_grid,
    iot_hierarchy,
    load_grid,
    perturbed_geant,
    sample_fleet,
)
