"""Batched multi-scenario ALT solving over padded problem ensembles.

`solve_fleet` pads a heterogeneous list of `Problem`s to a common (V, A)
envelope (fleet/pad.py), stacks them into a single pytree, and hands the
stack to the shared device-resident round engine (core/engine.py): the whole
ALT pipeline — structured init, placement reassignment, forwarding sweeps,
objective, best-iterate/stall/freeze bookkeeping — runs as ONE jitted
program over the instance axis (a lockstep while_loop vmapped over lanes
when a mesh is committed, lane-major `lax.map` chunks otherwise — see
`lane_chunk`). There is no fleet-local copy of the loop body any more; the
sequential solvers in core/alt.py run the exact same engine at B=1, so the
two paths share every future fix.

Equivalence contract: for every instance, the returned J matches the
sequential `solve_alt` on the unpadded problem (same m_max / t_phi / alpha /
tol / patience / solver) up to float32 rounding — trivially so, since both
run the same compiled loop. Early stopping is per-instance freeze masking
inside the engine; on top of that, the while_loop predicate ("any live
instance below m_max") exits the whole batch early once every instance has
stalled, instead of burning all `m_max` rounds like the old fixed-length
scan (`FleetResult.rounds` records the trips actually executed).

Scaling hooks (DESIGN.md sections 9-12):

  * `shard=True` runs the engine over a real instance-axis mesh: the stacked
    batch is committed to `NamedSharding(mesh, P("fleet"))`, padded up to a
    device multiple with inert repeats when it doesn't divide (trimmed on
    gather), and the engine outputs are verified to still carry the fleet
    layout. Every layout decision is explicit: `FleetResult.shard` records
    what happened (`ShardPlan`), and a fallback (single device) is logged —
    never silent. `devices=` caps the mesh to the first N local devices.
  * `chunk_size=B` splits very large ensembles into fixed-B chunks that all
    pad to the *global* (V, A) envelope and unified hop bound, so arbitrary
    fleet sizes reuse ONE compiled program per (V, A, B) signature instead
    of compiling one giant batch. Each chunk early-exits independently.
  * `envelope_cap_gb=G` bounds the per-device footprint of the engine's
    phi-shaped `[B, A, K, V, V]` buffers by auto-capping the chunk size for
    the (V, A) tier at hand — `chunk_size` alone caps B globally but not
    the per-device envelope, which is what blows up first at V >= 512.

Observability (DESIGN.md section 14): `trace=True` (default) carries the
engine's on-device round trace through the gather as `FleetResult.trace`
(per-round J split, placement churn, live mask, best-round index — same
NaN-past-freeze contract as `history`); the host-side stack/commit/execute/
gather boundaries are bracketed by `obs.trace` spans, and per-solve
telemetry (chunks, pad overhead, rounds vs budget, warm/cold compiles)
lands in `obs.metrics.registry`.
"""
from __future__ import annotations

import dataclasses
import functools
import logging

import jax
import jax.numpy as jnp
import numpy as np

from ..core.alt import ALL_METHODS, linearize, method_kwargs
from ..core.engine import engine_solve
from ..core.flow import objective
from ..core.placement import structured_init
from ..core.structs import Problem, State
from ..distributed.sharding import carries_fleet_sharding, shard_fleet
from ..obs.metrics import registry as obs_registry
from ..obs.roundtrace import FleetTrace
from ..obs.trace import span, tracer_enabled
from .pad import (
    NU_PAD,
    fleet_envelope,
    fleet_part_envelope,
    stack_problems,
    unify_hop_bound,
)

METHODS = ("ALT", "OneShot", "CongUnaware", "CoLocated")

logger = logging.getLogger("repro.fleet")

# Static accounting for the envelope cap: how many phi-shaped [A, K, V, V]
# float32 buffers one engine lane keeps alive at the round-body peak —
# carry.state + carry.best_state + the round-local next iterate, the
# placement sweep's delta tensor, and headroom for the forwarding sweeps'
# XLA temporaries. Deliberately conservative: the cap is a guard rail, not
# an allocator.
_PHI_COPIES = 8

# Process-local approximation of XLA's compile cache, keyed on what actually
# decides the compiled program: padded shapes, hop bound, device count, and
# the static solve kwargs. Drives the fleet.compile.{cold,warm} counters; it
# can undercount colds after `jax.clear_caches()` (we never see that), which
# the metrics consumers accept as the cost of staying sync-free.
_COMPILE_CACHE_KEYS: set = set()


def _validate_problems(problems) -> None:
    """Reject inputs that would push NaN/inf through the fixed point.

    The quadratic cost extension keeps J *finite* past rho_max, but a
    non-finite rate or capacity anywhere poisons every downstream reduction
    silently — by the time the caller sees J = NaN the provenance is gone.
    Checks are host-side numpy over the raw (unpadded) instances, so error
    messages can name the instance/app/stage; `solve_fleet(validate=False)`
    skips them for hot inner loops that re-solve already-validated fleets.

    A node with nu <= NU_PAD is DEAD under the §9/§15 encoding (padding and
    chaos both use it), so "the live-host set is empty" and "a live app's
    endpoint is dead" are both input errors here, not solver NaNs later.
    """
    for i, p in enumerate(problems):
        arrays = {
            "adj": np.asarray(p.net.adj),
            "mu": np.asarray(p.net.mu),
            "nu": np.asarray(p.net.nu),
            "lam": np.asarray(p.apps.lam),
            "L": np.asarray(p.apps.L),
            "w": np.asarray(p.apps.w),
        }
        for name, arr in arrays.items():
            if not np.isfinite(arr).all():
                raise ValueError(
                    f"solve_fleet: instance {i}: non-finite values in "
                    f"{name!r} — refusing to propagate NaN/inf through the "
                    "traffic fixed point"
                )
        if (arrays["lam"] < 0).any():
            raise ValueError(
                f"solve_fleet: instance {i}: negative arrival rate lam"
            )
        if (arrays["mu"] <= 0).any():
            raise ValueError(
                f"solve_fleet: instance {i}: non-positive link rate mu"
            )
        if (arrays["nu"] <= 0).any():
            raise ValueError(
                f"solve_fleet: instance {i}: non-positive compute rate nu"
            )
        live = arrays["nu"] > NU_PAD
        lam = arrays["lam"]
        if not live.any():
            a = int(np.argmax(lam > 0)) if (lam > 0).any() else 0
            raise ValueError(
                f"solve_fleet: instance {i}, app {a}, stage 0: live-host "
                f"set is empty — all {live.size} nodes are dead "
                f"(nu <= NU_PAD = {NU_PAD:g}), no node can host any stage"
            )
        src = np.asarray(p.apps.src)
        dst = np.asarray(p.apps.dst)
        for a in np.flatnonzero(lam > 0):
            for role, node in (("src", int(src[a])), ("dst", int(dst[a]))):
                if not live[node]:
                    raise ValueError(
                        f"solve_fleet: instance {i}, app {int(a)}: {role} "
                        f"node {node} is dead — its traffic cannot be "
                        + ("injected" if role == "src" else "absorbed")
                    )


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Explicit record of one solve's instance-axis layout decision.

    The pre-PR-4 `shard=True` was a `device_put` hint that silently no-oped
    whenever the batch didn't divide the device count or only one device was
    visible. Layout is now always an explicit decision: `reason` says what
    was chosen and why, `solve_fleet` logs any fallback, and the plan rides
    on `FleetResult.shard` so callers (CLI, benchmarks, tests) can assert on
    it instead of guessing from timings.

    requested      : the caller passed shard=True
    n_devices      : devices in the fleet mesh actually used (1 = unsharded)
    batch          : real instances handed to solve_fleet
    padded_batch   : engine lanes actually run, summed over chunks (>= batch;
                     the excess is inert repeats, trimmed on gather)
    reason         : "sharded" | "single-device" | "not-requested"
    output_sharded : every chunk's engine outputs were verified to carry the
                     fleet NamedSharding (False whenever n_devices == 1, or
                     on the fallback a silent layout change used to hide)
    """

    requested: bool
    n_devices: int = 1
    batch: int = 0
    padded_batch: int = 0
    reason: str = "not-requested"
    output_sharded: bool = False

    @property
    def sharded(self) -> bool:
        return self.n_devices > 1

    def describe(self) -> str:
        """One-liner for summaries/CLIs: devices, lane padding, reason."""
        return (
            f"{self.n_devices}dev B={self.batch}->{self.padded_batch} "
            f"{self.reason}"
        )


@dataclasses.dataclass
class FleetResult:
    """Per-instance results of one batched fleet solve.

    J / J_comm / J_comp : [B] final (best-iterate) objective values
    history             : [B, m_max + 1] outer-iteration J trace; entries
                          after an instance froze are NaN
    iters               : [B] outer iterations actually applied per instance
    rounds              : outer while_loop trips actually executed (max over
                          chunks); < m_max whenever every instance froze early
    hosts               : [B, A, P] chosen partition hosts over the fleet's
                          partition envelope (padded apps and phantom
                          partitions hold meaningless-but-harmless indices)
    parts               : [B, A] effective per-app partition counts (phantom
                          partitions past these are padding)
    node_mask/app_mask  : [B, V] / [B, A] validity masks from padding
    shard               : the instance-axis layout decision (`ShardPlan`)
    m_max               : the effective round budget this solve ran under
                          (0 for CongUnaware, 1 for OneShot) — lets
                          `summary()` report "rounds executed vs budget"
    trace               : host-side `FleetTrace` of the engine's on-device
                          round diagnostics (None when trace=False or for
                          the zero-iteration CongUnaware baseline)
    state               : the solved stacked `State` over the fleet envelope
                          (device arrays, pad lanes trimmed) when the caller
                          passed `keep_state=True`; the warm-start currency
                          — feed it back as `solve_fleet(warm_start=...)`
                          next epoch. None by default: the [B, A, K, V, V]
                          phi buffers are too big to keep alive casually.
    """

    method: str
    J: np.ndarray
    J_comm: np.ndarray
    J_comp: np.ndarray
    history: np.ndarray
    iters: np.ndarray
    rounds: int
    hosts: np.ndarray
    parts: np.ndarray
    node_mask: np.ndarray
    app_mask: np.ndarray
    shard: ShardPlan = dataclasses.field(
        default_factory=lambda: ShardPlan(requested=False)
    )
    m_max: int = 0
    trace: FleetTrace | None = None
    state: State | None = None

    @property
    def n_instances(self) -> int:
        return int(self.J.shape[0])

    def per_instance(self) -> list[dict]:
        out = []
        for b in range(self.n_instances):
            hist = self.history[b]
            n_real = int(self.node_mask[b].sum())
            real = self.app_mask[b] > 0
            hosts = self.hosts[b][real]
            parts = self.parts[b][real].astype(int)
            # Only the real partitions of real apps count: phantom-partition
            # hosts are padding, trimmed before the leak check below.
            real_hosts = [h[:pa] for h, pa in zip(hosts, parts)]
            # Padded-envelope indices must never leak to consumers: a host
            # beyond the real-node block would be a solver bug (padded
            # nodes carry a prohibitive marginal compute cost), so flag it
            # and clamp into the valid range either way.
            leaked = int(sum(np.sum(h >= n_real) for h in real_hosts))
            row = {
                "J": float(self.J[b]),
                "J_comm": float(self.J_comm[b]),
                "J_comp": float(self.J_comp[b]),
                "history": [float(h) for h in hist[~np.isnan(hist)]],
                "iters": int(self.iters[b]),
                "hosts": [
                    np.minimum(h, n_real - 1).tolist() for h in real_hosts
                ],
                # The instance's split depth(s): one int when uniform, else
                # the per-app list (heterogeneous per-app splits are legal).
                "partitions": (
                    int(parts[0]) if len(set(parts.tolist())) <= 1
                    else parts.tolist()
                ),
            }
            if leaked:
                row["padded_host_leaks"] = leaked
            out.append(row)
        return out

    def summary(self) -> str:
        rounds = f"rounds={self.rounds}"
        if self.m_max:
            tag = " early-exit" if self.rounds < self.m_max else ""
            rounds = f"rounds={self.rounds}/{self.m_max}{tag}"
        churn = (
            f"  churn={self.trace.mean_churn():.2f}/round"
            if self.trace is not None else ""
        )
        return (
            f"fleet[{self.method}] B={self.n_instances} "
            f"J: min={self.J.min():.3f} med={np.median(self.J):.3f} "
            f"max={self.J.max():.3f}  iters: {self.iters.min()}-{self.iters.max()}"
            f"  {rounds}{churn}  shard[{self.shard.describe()}]"
        )


def _solve_one_congunaware(
    problem: Problem, *, use_pallas: bool, interpret: bool, solver: str
) -> dict:
    """Zero-iteration baseline: linear-cost init scored under true costs."""
    state = structured_init(
        linearize(problem), use_pallas=use_pallas, interpret=interpret
    )
    J, aux = objective(
        problem, state, solver=solver, use_pallas=use_pallas,
        interpret=interpret,
    )
    return {
        "J": J,
        "J_comm": aux["J_comm"],
        "J_comp": aux["J_comp"],
        "hosts": state.hosts(),
        "history": J[None],
        "iters": jnp.int32(0),
    }


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret", "solver"))
def _solve_fleet_congunaware(
    stacked: Problem, *, use_pallas: bool, interpret: bool, solver: str
):
    return jax.vmap(
        functools.partial(
            _solve_one_congunaware, use_pallas=use_pallas,
            interpret=interpret, solver=solver,
        )
    )(stacked)


def _solve_fleet_stacked(
    stacked: Problem,
    *,
    method: str,
    m_max: int,
    t_phi: int,
    alpha: float,
    tol: float,
    patience: int,
    use_pallas: bool,
    solver: str,
    interpret: bool = True,
    trace: bool = True,
    keep_state: bool = False,
    block_apps: int = 1,
    lane_chunk: int = 0,
    init_state: State | None = None,
    active0=None,
) -> dict:
    """Dispatch one stacked batch onto the shared round engine."""
    if method == "CongUnaware":
        out = dict(
            _solve_fleet_congunaware(
                stacked, use_pallas=use_pallas, interpret=interpret,
                solver=solver,
            )
        )
        out["rounds"] = jnp.int32(0)
        out["trace"] = None
        return out
    # keep_state=False drops the full [B, A, K, V, V] State inside the
    # engine: the fleet result only surfaces hosts, a chunked solve would
    # otherwise keep every chunk's phi buffers alive until the final
    # gather, and the lane-major layout would stack B of them for nothing.
    out = dict(
        engine_solve(
            stacked,
            m_max=1 if method == "OneShot" else m_max,
            t_phi=t_phi,
            alpha=alpha,
            tol=tol,
            patience=patience,
            colocate=method == "CoLocated",
            track_best=method != "OneShot",
            use_pallas=use_pallas,
            interpret=interpret,
            solver=solver,
            trace=trace,
            block_apps=block_apps,
            lane_chunk=lane_chunk,
            keep_state=keep_state,
            init_state=init_state,
            active0=active0,
        )
    )
    return out


def _plan_mesh(shard: bool, devices: int | None):
    """Decide the instance-axis layout up front — explicit and logged.

    Returns (mesh_or_None, n_devices, reason). The old `_shard_over_devices`
    hint silently kept the single-device layout whenever the batch didn't
    divide the device count; now a non-divisible batch is padded (see
    `_run_chunk`) and the only remaining fallback — a single visible device
    — is surfaced in the plan and the log."""
    if not shard:
        if devices is not None:
            raise ValueError("devices= only applies with shard=True")
        return None, 1, "not-requested"
    from ..launch.mesh import make_fleet_mesh

    mesh = make_fleet_mesh(devices)
    n_dev = int(mesh.devices.size)
    if n_dev < 2:
        logger.warning(
            "solve_fleet(shard=True): only one device in the mesh; "
            "running unsharded (reason=single-device)"
        )
        return None, 1, "single-device"
    return mesh, n_dev, "sharded"


def _run_chunk(
    problems, *, envelope, hop_bound, n_parts, round_to, mesh, batch_to,
    solve_kw, warm=None,
):
    """Stack (and, when sharding, pad + commit) one chunk and solve it.

    batch_to : pad the lane count up to this target with inert repeats (the
        chunked path passes `chunk_size` so every chunk compiles to the same
        program); a fleet mesh additionally rounds the target up to a device
        multiple. Returns (engine_out, stacked_info, n_real, n_lanes,
        outputs_sharded).
    warm : optional (State, active_mask_or_None) pair seeding the engine
        carry — the State covers the `real` instances over the already-
        padded fleet envelope; pad lanes repeat lane 0 with active=False so
        a warm pad lane costs a single init eval, and a mesh commits the
        warm arrays alongside the stacked problem."""
    real = len(problems)
    target = max(real, batch_to or 0)
    if mesh is not None:
        n_dev = int(mesh.devices.size)
        target = -(-target // n_dev) * n_dev
    if target > real:
        problems = list(problems) + [problems[0]] * (target - real)
    with span("solve_fleet.stack", batch=target, real=real):
        stacked, info = stack_problems(
            problems, round_to=round_to, envelope=envelope, hop_bound=hop_bound,
            n_parts=n_parts,
        )
    if mesh is not None:
        with span("solve_fleet.commit", devices=int(mesh.devices.size)):
            stacked, info = shard_fleet((stacked, info), mesh)
    init_state = active0 = None
    if warm is not None:
        w_state, w_active = warm
        exp = (real,) + tuple(stacked.apps.w.shape[1:]) + (
            int(stacked.net.adj.shape[-1]),
        )
        if tuple(w_state.x.shape) != exp:
            raise ValueError(
                f"solve_fleet: warm_start placement shape "
                f"{tuple(w_state.x.shape)} does not match this fleet's "
                f"stacked envelope {exp} — the (V, A, K) envelope drifted "
                "since the state was produced; re-solve cold"
            )
        if target > real:
            w_state = jax.tree_util.tree_map(
                lambda x: jnp.concatenate(
                    [x, jnp.repeat(x[:1], target - real, axis=0)]
                ),
                w_state,
            )
        act = (
            jnp.ones(real, bool)
            if w_active is None
            else jnp.asarray(np.asarray(w_active)).reshape(real).astype(bool)
        )
        act = jnp.concatenate([act, jnp.zeros(target - real, bool)])
        if mesh is not None:
            w_state, act = shard_fleet((w_state, act), mesh)
        init_state, active0 = w_state, act
    key = (
        stacked.net.adj.shape,
        stacked.apps.L.shape,
        stacked.hop_bound,
        1 if mesh is None else int(mesh.devices.size),
        init_state is not None,
        tuple(sorted(solve_kw.items())),
    )
    cold = key not in _COMPILE_CACHE_KEYS
    _COMPILE_CACHE_KEYS.add(key)
    obs_registry.counter(
        "fleet.compile.cold" if cold else "fleet.compile.warm"
    ).inc()
    with span("solve_fleet.execute", batch=target, cold_compile=cold):
        out = _solve_fleet_stacked(
            stacked, init_state=init_state, active0=active0, **solve_kw
        )
        if tracer_enabled():
            # Only when tracing: make the span cover the device work, not
            # just the dispatch. Untraced solves keep async dispatch.
            jax.block_until_ready(out["J"])
    out["parts"] = stacked.apps.parts
    sharded_out = mesh is not None and carries_fleet_sharding(out["J"])
    if mesh is not None and not sharded_out:
        # The whole point of PR 4: a layout change must never be silent.
        logger.warning(
            "solve_fleet: engine outputs lost the fleet sharding "
            "(B=%d over %d devices) — recording output_sharded=False",
            target, int(mesh.devices.size),
        )
    return out, info, real, target, sharded_out


def envelope_cap_chunk(
    problems, *, round_to: int, n_devices: int, cap_gb: float
) -> int:
    """Largest chunk size keeping one device's phi-shaped buffers under
    `cap_gb` for this fleet's (V, A) tier.

    The engine's dominant footprint is the `[B_dev, A, K, V, V]` family
    (state/best/next phi plus the placement sweep's delta — `_PHI_COPIES`
    float32 copies per lane at the round-body peak). `chunk_size` caps B
    globally; this caps the *per-device envelope*, which is what actually
    blows up at V >= 512 (ROADMAP item)."""
    if cap_gb <= 0:
        raise ValueError(f"envelope_cap_gb must be positive, got {cap_gb}")
    v, a = fleet_envelope(problems, round_to=round_to)
    k_stages = fleet_part_envelope(problems) + 1
    per_lane_bytes = _PHI_COPIES * a * k_stages * v * v * 4
    lanes_per_device = max(1, int(cap_gb * 2**30 // per_lane_bytes))
    return lanes_per_device * max(1, n_devices)


def solve_fleet(
    problems,
    *,
    method: str = "ALT",
    m_max: int = 30,
    t_phi: int = 10,
    alpha: float = 0.5,
    tol: float = 1e-3,
    patience: int = 4,
    round_to: int = 1,
    shard: bool = False,
    devices: int | None = None,
    use_pallas: bool = False,
    interpret: bool = True,
    solver: str = "neumann",
    block_apps: int = 1,
    lane_chunk: int | None = None,
    chunk_size: int | None = None,
    envelope_cap_gb: float | None = None,
    trace: bool = True,
    warm_start: State | None = None,
    warm_active=None,
    keep_state: bool = False,
    validate: bool = True,
) -> FleetResult:
    """Solve a heterogeneous fleet of problems as one batched computation.

    problems   : list of `Problem` (arbitrary mixed sizes; padded internally)
    method     : "ALT" | "OneShot" | "CongUnaware" | "CoLocated", matching
                 the sequential solvers in core/alt.py instance-for-instance
    round_to   : round the padded (V, A) envelope up to this multiple so a
                 long-running control plane compiles few distinct shapes
    shard      : run the engine with the instance axis committed over a 1-D
                 fleet mesh of local devices; non-divisible batches are
                 padded up to a device multiple with inert repeats (trimmed
                 on gather). The decision taken is surfaced as
                 `FleetResult.shard` and logged — never silent.
    devices    : cap the fleet mesh to the first N local devices
                 (requires shard=True; asking for more than exist raises)
    solver     : "neumann" (hop-capped propagation, default) | "lu" (dense)
    block_apps : placement sweep schedule (core/placement.py module doc):
                 1 = the paper's sequential per-app scan (default), k > 1 =
                 blocked Jacobi scoring with conflict-checked acceptance in
                 size-k blocks, 0 = one block over all apps. Ignored by
                 CongUnaware (no placement sweep).
    lane_chunk : engine layout over the instance axis (engine_solve):
                 0 = the fused batch — one lockstep while_loop whose round
                 body vmaps over all lanes (the only layout compatible with
                 a committed fleet mesh); k >= 1 = lane-major — each lane's
                 WHOLE solve runs inside `lax.map(..., batch_size=k)`, so
                 its phi-shaped buffers stay cache-resident across rounds
                 and a converged lane stops computing immediately instead
                 of riding lockstep until the slowest lane stalls. Results
                 are bitwise-identical across layouts. None (default) =
                 auto: lane-major when unsharded, fused vmap when a mesh is
                 committed. Asking for a nonzero chunk together with
                 shard=True raises.
    interpret  : with use_pallas=True, run the kernel bodies under the Pallas
                 interpreter (CPU validation). A real TPU/GPU launch passes
                 interpret=False; ignored when use_pallas=False.
    chunk_size : split ensembles larger than this into fixed-B chunks that
                 share one global (V, A) envelope + hop bound, reusing a
                 single compiled program per (V, A, B) signature; the tail
                 chunk is padded with repeats of its first instance (results
                 trimmed). None = one batch. When sharding, the chunk size
                 is rounded up to a device multiple so every chunk keeps the
                 committed layout.
    envelope_cap_gb : bound the per-device footprint of the phi-shaped
                 [B, A, K, V, V] engine buffers by auto-capping the chunk
                 size for this fleet's (V, A) tier (see `envelope_cap_chunk`)
    trace      : carry the engine's on-device round trace (J split, churn,
                 live mask, best round) out as `FleetResult.trace`; False
                 drops the buffers from the compiled loop entirely. Results
                 are bitwise-identical either way.
    warm_start : a stacked `State` over this fleet's envelope — typically
                 `FleetResult.state` from the previous control epoch, after
                 `chaos.repair_fleet` — seeding the engine carry instead of
                 `structured_init` (DESIGN.md section 15). Shape-checked
                 against the stacked envelope (a drifted envelope raises).
                 Single-chunk only: a warm fleet must fit one engine batch.
    warm_active: optional [B] bool mask (requires warm_start); False lanes
                 are frozen from round 0 and return exactly the warm state's
                 evaluation — the "re-solve only the perturbed instances"
                 mechanism. None = all lanes active.
    keep_state : surface the solved stacked `State` as `FleetResult.state`
                 (the warm-start currency for the next epoch). Unsupported
                 for CongUnaware (its baseline never forms an engine state).
    validate   : host-side input validation (`_validate_problems`): reject
                 non-finite rates/capacities, dead src/dst endpoints and
                 empty live-host sets with a named ValueError instead of
                 letting NaN propagate through the fixed point.
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")
    if validate:
        _validate_problems(problems)
    if warm_active is not None and warm_start is None:
        raise ValueError("warm_active requires warm_start")
    if warm_start is not None and method == "CongUnaware":
        raise ValueError(
            "warm_start is meaningless for CongUnaware (a zero-iteration "
            "baseline that never runs the engine)"
        )
    if keep_state and method == "CongUnaware":
        raise ValueError("keep_state is unsupported for CongUnaware")
    solve_kw = dict(
        method=method, m_max=m_max, t_phi=t_phi, alpha=alpha, tol=tol,
        patience=patience, use_pallas=use_pallas, interpret=interpret,
        solver=solver, trace=trace, keep_state=keep_state,
        block_apps=block_apps,
    )
    n = len(problems)
    mesh, n_dev, reason = _plan_mesh(shard, devices)

    if lane_chunk is not None and lane_chunk != 0 and mesh is not None:
        raise ValueError(
            f"lane_chunk={lane_chunk} is incompatible with a committed fleet "
            "mesh: lax.map lane chunks break the instance-axis sharding — "
            "use lane_chunk=0 (or leave it None) when shard=True"
        )
    if lane_chunk is None:
        lane_chunk = 0 if mesh is not None else 1
    solve_kw["lane_chunk"] = lane_chunk

    if envelope_cap_gb is not None:
        cap = envelope_cap_chunk(
            problems, round_to=round_to, n_devices=n_dev,
            cap_gb=envelope_cap_gb,
        )
        if chunk_size is None or cap < chunk_size:
            if cap < n:
                logger.info(
                    "solve_fleet: envelope cap %.3g GB/device limits this "
                    "(V, A) tier to chunks of B=%d (was %s)",
                    envelope_cap_gb, cap, chunk_size,
                )
            chunk_size = cap
    if mesh is not None and chunk_size is not None and chunk_size % n_dev:
        # Round the chunk itself so every chunk (not just the tail) runs at
        # a device multiple and reuses one compiled, committed program.
        chunk_size = -(-chunk_size // n_dev) * n_dev

    warm = None
    if warm_start is not None:
        if chunk_size is not None and n > chunk_size:
            raise ValueError(
                f"warm_start is single-chunk only: fleet of {n} instances "
                f"would split into chunks of {chunk_size} — raise chunk_size/"
                "envelope_cap_gb or re-solve cold"
            )
        warm = (warm_start, warm_active)

    chunk_kw = dict(round_to=round_to, mesh=mesh, solve_kw=solve_kw)
    if chunk_size is None or n <= chunk_size:
        outs = [
            _run_chunk(problems, envelope=None, hop_bound=None, n_parts=None,
                       batch_to=None, warm=warm, **chunk_kw)
        ]
    else:
        # One global envelope + hop bound + partition envelope so every
        # chunk hits the same compiled program.
        envelope = fleet_envelope(problems, round_to=round_to)
        hop_bound = unify_hop_bound(problems)
        part_env = fleet_part_envelope(problems)
        outs = [
            _run_chunk(
                list(problems[i : i + chunk_size]), envelope=envelope,
                hop_bound=hop_bound, n_parts=part_env, batch_to=chunk_size,
                **chunk_kw,
            )
            for i in range(0, n, chunk_size)
        ]

    plan = ShardPlan(
        requested=shard,
        n_devices=n_dev,
        batch=n,
        padded_batch=sum(lanes for (_, _, _, lanes, _) in outs),
        reason=reason,
        output_sharded=mesh is not None
        and all(ok for (_, _, _, _, ok) in outs),
    )

    def chunk_fields(o, i):
        d = dict(
            J=o["J"], J_comm=o["J_comm"], J_comp=o["J_comp"],
            history=o["history"], iters=o["iters"], rounds=o["rounds"],
            hosts=o["hosts"], parts=o["parts"],
            node_mask=i.node_mask, app_mask=i.app_mask,
        )
        if o.get("trace") is not None:
            t = o["trace"]
            d.update(
                trace_J_comm=t.J_comm, trace_J_comp=t.J_comp,
                trace_moves=t.moves, trace_live=t.live,
                trace_best_round=t.best_round,
            )
        return d

    with span("solve_fleet.gather", chunks=len(outs)):
        # ONE device->host sync for every result field across every chunk
        # (device_get on the whole tree): a sync per field per chunk costs
        # more host round-trips than the arrays are worth — the gathered
        # fields are all small [B]- or [B, m_max]-shaped summaries.
        host = jax.device_get(
            [chunk_fields(o, i) for (o, i, _, _, _) in outs]
        )

        def gather(name):
            parts_ = [hc[name][:k] for hc, (_, _, k, _, _) in zip(host, outs)]
            # device_get hands back read-only buffers; the result contract
            # is plain owned numpy (callers mutate hosts in place). The
            # fields are small, so the copy is noise.
            return (
                np.array(parts_[0])
                if len(parts_) == 1
                else np.concatenate(parts_)
            )

        kept_state = None
        if keep_state:
            # Trim pad lanes per chunk, then concatenate; stays on device —
            # this is the next epoch's warm-start input, not a host export.
            states = [
                jax.tree_util.tree_map(lambda x, k=k: x[:k], o["state"])
                for (o, _, k, _, _) in outs
            ]
            kept_state = (
                states[0]
                if len(states) == 1
                else jax.tree_util.tree_map(
                    lambda *xs: jnp.concatenate(xs), *states
                )
            )
        fleet_trace = None
        if all(o.get("trace") is not None for (o, _, _, _, _) in outs):
            fleet_trace = FleetTrace(
                J_comm=gather("trace_J_comm"),
                J_comp=gather("trace_J_comp"),
                moves=gather("trace_moves"),
                live=gather("trace_live"),
                best_round=gather("trace_best_round"),
            )
        result = FleetResult(
            method=method,
            J=gather("J"),
            J_comm=gather("J_comm"),
            J_comp=gather("J_comp"),
            history=gather("history"),
            iters=gather("iters"),
            rounds=max(int(hc["rounds"]) for hc in host),
            hosts=gather("hosts"),
            parts=gather("parts"),
            node_mask=gather("node_mask"),
            app_mask=gather("app_mask"),
            shard=plan,
            m_max=(
                0 if method == "CongUnaware"
                else 1 if method == "OneShot" else m_max
            ),
            trace=fleet_trace,
            state=kept_state,
        )

    obs_registry.counter("fleet.chunks_executed").inc(len(outs))
    obs_registry.gauge("fleet.rounds_executed").set(result.rounds)
    obs_registry.gauge("fleet.m_max").set(result.m_max)
    obs_registry.gauge("fleet.early_exit_saved_rounds").set(
        max(0, result.m_max - result.rounds)
    )
    obs_registry.gauge("fleet.pad_overhead_fraction").set(
        0.0 if plan.padded_batch == 0
        else (plan.padded_batch - plan.batch) / plan.padded_batch
    )
    return result


def solve_sequential(problems, *, method: str = "ALT", **kw) -> list:
    """Reference path: per-instance solving through the same engine at B=1.

    Used by benchmarks/fleet_bench.py for the batched-vs-sequential speedup
    and by tests for the equivalence guarantee. Kwargs are filtered through
    `core.alt.METHOD_KWARGS` — one shared dict for every method, so the
    sequential baselines can never diverge from the fleet's."""
    fn = ALL_METHODS[method]
    return [fn(p, **method_kwargs(method, kw)) for p in problems]
