"""Batched multi-scenario ALT solving over padded problem ensembles.

`solve_fleet` pads a heterogeneous list of `Problem`s to a common (V, A)
envelope (fleet/pad.py), stacks them into a single pytree, and hands the
stack to the shared device-resident round engine (core/engine.py): the whole
ALT pipeline — structured init, placement reassignment, forwarding sweeps,
objective, best-iterate/stall/freeze bookkeeping — runs as ONE jitted
`lax.while_loop` vmapped over the instance axis. There is no fleet-local
copy of the loop body any more; the sequential solvers in core/alt.py run
the exact same engine at B=1, so the two paths share every future fix.

Equivalence contract: for every instance, the returned J matches the
sequential `solve_alt` on the unpadded problem (same m_max / t_phi / alpha /
tol / patience / solver) up to float32 rounding — trivially so, since both
run the same compiled loop. Early stopping is per-instance freeze masking
inside the engine; on top of that, the while_loop predicate ("any live
instance below m_max") exits the whole batch early once every instance has
stalled, instead of burning all `m_max` rounds like the old fixed-length
scan (`FleetResult.rounds` records the trips actually executed).

Scaling hooks: `shard=True` splits the instance axis over local devices;
`chunk_size=B` splits very large ensembles into fixed-B chunks that all pad
to the *global* (V, A) envelope and unified hop bound, so arbitrary fleet
sizes reuse ONE compiled program per (V, A, B) signature instead of
compiling one giant batch (DESIGN.md sections 9-11). Each chunk early-exits
independently.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.alt import ALL_METHODS, linearize, method_kwargs
from ..core.engine import engine_solve
from ..core.flow import objective
from ..core.placement import structured_init
from ..core.structs import Problem
from .pad import PadInfo, fleet_envelope, stack_problems, unify_hop_bound

METHODS = ("ALT", "OneShot", "CongUnaware", "CoLocated")


@dataclasses.dataclass
class FleetResult:
    """Per-instance results of one batched fleet solve.

    J / J_comm / J_comp : [B] final (best-iterate) objective values
    history             : [B, m_max + 1] outer-iteration J trace; entries
                          after an instance froze are NaN
    iters               : [B] outer iterations actually applied per instance
    rounds              : outer while_loop trips actually executed (max over
                          chunks); < m_max whenever every instance froze early
    hosts               : [B, A, 2] chosen partition hosts (padded apps hold
                          meaningless-but-harmless indices)
    node_mask/app_mask  : [B, V] / [B, A] validity masks from padding
    """

    method: str
    J: np.ndarray
    J_comm: np.ndarray
    J_comp: np.ndarray
    history: np.ndarray
    iters: np.ndarray
    rounds: int
    hosts: np.ndarray
    node_mask: np.ndarray
    app_mask: np.ndarray

    @property
    def n_instances(self) -> int:
        return int(self.J.shape[0])

    def per_instance(self) -> list[dict]:
        out = []
        for b in range(self.n_instances):
            hist = self.history[b]
            n_real = int(self.node_mask[b].sum())
            hosts = self.hosts[b][self.app_mask[b] > 0]
            # Padded-envelope indices must never leak to consumers: a host
            # beyond the real-node block would be a solver bug (padded
            # nodes carry a prohibitive marginal compute cost), so flag it
            # and clamp into the valid range either way.
            leaked = int(np.sum(hosts >= n_real))
            hosts = np.minimum(hosts, n_real - 1)
            row = {
                "J": float(self.J[b]),
                "J_comm": float(self.J_comm[b]),
                "J_comp": float(self.J_comp[b]),
                "history": [float(h) for h in hist[~np.isnan(hist)]],
                "iters": int(self.iters[b]),
                "hosts": hosts.tolist(),
            }
            if leaked:
                row["padded_host_leaks"] = leaked
            out.append(row)
        return out

    def summary(self) -> str:
        return (
            f"fleet[{self.method}] B={self.n_instances} "
            f"J: min={self.J.min():.3f} med={np.median(self.J):.3f} "
            f"max={self.J.max():.3f}  iters: {self.iters.min()}-{self.iters.max()}"
            f"  rounds={self.rounds}"
        )


def _solve_one_congunaware(problem: Problem, *, use_pallas: bool, solver: str) -> dict:
    """Zero-iteration baseline: linear-cost init scored under true costs."""
    state = structured_init(linearize(problem), use_pallas=use_pallas)
    J, aux = objective(problem, state, solver=solver)
    return {
        "J": J,
        "J_comm": aux["J_comm"],
        "J_comp": aux["J_comp"],
        "hosts": state.hosts(),
        "history": J[None],
        "iters": jnp.int32(0),
    }


@functools.partial(jax.jit, static_argnames=("use_pallas", "solver"))
def _solve_fleet_congunaware(stacked: Problem, *, use_pallas: bool, solver: str):
    return jax.vmap(
        functools.partial(
            _solve_one_congunaware, use_pallas=use_pallas, solver=solver
        )
    )(stacked)


def _solve_fleet_stacked(
    stacked: Problem,
    *,
    method: str,
    m_max: int,
    t_phi: int,
    alpha: float,
    tol: float,
    patience: int,
    use_pallas: bool,
    solver: str,
) -> dict:
    """Dispatch one stacked batch onto the shared round engine."""
    if method == "CongUnaware":
        out = dict(
            _solve_fleet_congunaware(stacked, use_pallas=use_pallas, solver=solver)
        )
        out["rounds"] = jnp.int32(0)
        return out
    out = dict(
        engine_solve(
            stacked,
            m_max=1 if method == "OneShot" else m_max,
            t_phi=t_phi,
            alpha=alpha,
            tol=tol,
            patience=patience,
            colocate=method == "CoLocated",
            track_best=method != "OneShot",
            use_pallas=use_pallas,
            solver=solver,
        )
    )
    # Drop the full [B, A, K, V, V] State: the fleet result only surfaces
    # hosts, and a chunked solve would otherwise keep every chunk's phi
    # buffers alive until the final gather.
    out.pop("state")
    return out


def _shard_over_devices(stacked: Problem, info: PadInfo, batch: int):
    """Optional hook: lay the instance axis out over all local devices.

    No-op unless there are >= 2 devices and the batch divides evenly; the
    jitted fleet solve then runs SPMD over the instance axis with no code
    changes (batch parallelism has no cross-instance communication).
    """
    devices = jax.devices()
    n_dev = len(devices)
    if n_dev < 2 or batch % n_dev != 0:
        return stacked, info
    from jax.sharding import Mesh, NamedSharding, PartitionSpec

    mesh = Mesh(np.asarray(devices), ("fleet",))
    sharding = NamedSharding(mesh, PartitionSpec("fleet"))
    put = lambda x: jax.device_put(x, sharding)
    return jax.tree_util.tree_map(put, (stacked, info))


def _run_chunk(problems, *, envelope, hop_bound, round_to, shard, solve_kw):
    stacked, info = stack_problems(
        problems, round_to=round_to, envelope=envelope, hop_bound=hop_bound
    )
    if shard:
        stacked, info = _shard_over_devices(stacked, info, len(problems))
    out = _solve_fleet_stacked(stacked, **solve_kw)
    return out, info


def solve_fleet(
    problems,
    *,
    method: str = "ALT",
    m_max: int = 30,
    t_phi: int = 10,
    alpha: float = 0.5,
    tol: float = 1e-3,
    patience: int = 4,
    round_to: int = 1,
    shard: bool = False,
    use_pallas: bool = False,
    solver: str = "neumann",
    chunk_size: int | None = None,
) -> FleetResult:
    """Solve a heterogeneous fleet of problems as one batched computation.

    problems   : list of `Problem` (arbitrary mixed sizes; padded internally)
    method     : "ALT" | "OneShot" | "CongUnaware" | "CoLocated", matching
                 the sequential solvers in core/alt.py instance-for-instance
    round_to   : round the padded (V, A) envelope up to this multiple so a
                 long-running control plane compiles few distinct shapes
    shard      : lay the instance axis out over local devices when possible
    solver     : "neumann" (hop-capped propagation, default) | "lu" (dense)
    chunk_size : split ensembles larger than this into fixed-B chunks that
                 share one global (V, A) envelope + hop bound, reusing a
                 single compiled program per (V, A, B) signature; the tail
                 chunk is padded with repeats of its first instance (results
                 trimmed). None = one batch.
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; expected one of {METHODS}")
    solve_kw = dict(
        method=method, m_max=m_max, t_phi=t_phi, alpha=alpha, tol=tol,
        patience=patience, use_pallas=use_pallas, solver=solver,
    )
    n = len(problems)
    if chunk_size is None or n <= chunk_size:
        out, info = _run_chunk(
            problems, envelope=None, hop_bound=None, round_to=round_to,
            shard=shard, solve_kw=solve_kw,
        )
        outs, infos, keep = [out], [info], [n]
    else:
        # One global envelope + hop bound so every chunk hits the same
        # compiled program.
        envelope = fleet_envelope(problems, round_to=round_to)
        hop_bound = unify_hop_bound(problems)
        outs, infos, keep = [], [], []
        for i in range(0, n, chunk_size):
            chunk = list(problems[i : i + chunk_size])
            real = len(chunk)
            chunk += [chunk[0]] * (chunk_size - real)  # inert tail repeats
            out, info = _run_chunk(
                chunk, envelope=envelope, hop_bound=hop_bound,
                round_to=round_to, shard=shard, solve_kw=solve_kw,
            )
            outs.append(out)
            infos.append(info)
            keep.append(real)

    def gather(getter):
        return np.concatenate(
            [np.asarray(getter(o, i))[:k] for (o, i, k) in zip(outs, infos, keep)]
        )

    return FleetResult(
        method=method,
        J=gather(lambda o, i: o["J"]),
        J_comm=gather(lambda o, i: o["J_comm"]),
        J_comp=gather(lambda o, i: o["J_comp"]),
        history=gather(lambda o, i: o["history"]),
        iters=gather(lambda o, i: o["iters"]),
        rounds=max(int(o["rounds"]) for o in outs),
        hosts=gather(lambda o, i: o["hosts"]),
        node_mask=gather(lambda o, i: i.node_mask),
        app_mask=gather(lambda o, i: i.app_mask),
    )


def solve_sequential(problems, *, method: str = "ALT", **kw) -> list:
    """Reference path: per-instance solving through the same engine at B=1.

    Used by benchmarks/fleet_bench.py for the batched-vs-sequential speedup
    and by tests for the equivalence guarantee. Kwargs are filtered through
    `core.alt.METHOD_KWARGS` — one shared dict for every method, so the
    sequential baselines can never diverge from the fleet's."""
    fn = ALL_METHODS[method]
    return [fn(p, **method_kwargs(method, kw)) for p in problems]
