"""Mask-aware padding of heterogeneous problems to a common (V, A, P) envelope.

The batched fleet solver (fleet/solve.py) vmaps the whole ALT pipeline over
an instance axis, which requires every instance to share one static shape.
Heterogeneous instances are padded up to the fleet envelope so that the
padded coordinates are *provably inert* (DESIGN.md sections 9 and 13):

  padded nodes   - no adjacency (adj = 0), BIG-sentinel link rates (mu), and
                   a vanishing compute rate nu = NU_PAD. Zero incident
                   traffic means D and C contributions are exactly 0, while
                   the *marginal* compute cost C'(0) = 1/NU_PAD is enormous,
                   so neither the structured init nor any placement sweep
                   ever selects a padded host (link distances to padded
                   nodes are >= BIG for the same reason).
  padded apps    - lambda = 0, L = 0, w = 0 with src = dst = node 0. They
                   route zero traffic, add zero load in the sequential
                   placement scan, and contribute zero to J.
  padded stages  - fleets mixing split depths pad the partition axis to a
                   common K envelope: phantom partitions carry w = 0 and
                   L = 0 trailing entries, and `Apps.parts` records each
                   app's real depth. Every stage-generic kernel gates on
                   `parts` (zero forwarding mass, zero traffic injection,
                   frozen placement, identity DP transitions), so a
                   stage-padded instance runs BIT-identically to its
                   unpadded original on the real stages — the section 13
                   extension of the inertness contract, pinned by
                   tests/test_stage_generic.py.

Because every padded quantity enters the objective and the marginals
multiplicatively through zero traffic / zero rates, the solver trajectory on
the real coordinates of a padded instance is identical to solving the
unpadded instance (up to float32 rounding in the dense solves) — that is
the equivalence contract tests/test_fleet.py enforces.

`(I - Phi^T)` stays invertible on the padded system: padded nodes receive
no forwarding mass (no real node ever picks them as next hop), so their
rows can only point *into* the real block, adding no cycles.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core.structs import Apps, BIG, Network, Problem

# Compute rate assigned to padded nodes: small enough that the marginal
# compute cost C'(0) = 1/NU_PAD dominates any congested real marginal, while
# C(0) = 0 keeps the padded contribution to J exactly zero.
NU_PAD = 1e-9


class EmptyFleetError(ValueError):
    """A fleet operation was handed zero solvable instances.

    Raised by `pad_batch_to_multiple` / `stack_problems` when the batch is
    empty — either literally (zero instances) or effectively (every node of
    every instance is dead, so there is nothing inert to repeat the padding
    from). A typed subclass so control planes can catch "nothing to solve"
    distinctly from genuine argument errors; the old behavior was an opaque
    reshape/stack failure deep inside jnp."""


@dataclasses.dataclass(frozen=True)
class PadInfo:
    """Validity masks for one padded instance (or a stacked fleet of them).

    node_mask : [V] float32, 1.0 on real nodes, 0.0 on padding
    app_mask  : [A] float32, 1.0 on real apps, 0.0 on padding
    """

    node_mask: jax.Array
    app_mask: jax.Array

    @property
    def n_real_nodes(self) -> int:
        return int(jnp.sum(self.node_mask))

    @property
    def n_real_apps(self) -> int:
        return int(jnp.sum(self.app_mask))


jax.tree_util.register_dataclass(
    PadInfo, data_fields=["node_mask", "app_mask"], meta_fields=[]
)


def pad_network(net: Network, n_nodes: int) -> Network:
    """Pad a Network to `n_nodes` with disconnected, compute-dead nodes."""
    v = net.n_nodes
    if n_nodes < v:
        raise ValueError(f"cannot pad {v} nodes down to {n_nodes}")
    if n_nodes == v:
        return net
    pad = n_nodes - v
    # Host-side numpy pads: every call site is outside jit (the stack path
    # runs before the engine dispatch), and padding a dozen instances as
    # ~100 tiny XLA programs costs more wall time than the engine round it
    # precedes. Values are identical bit for bit.
    adj = np.pad(np.asarray(net.adj), ((0, pad), (0, pad)))
    mu = np.pad(np.asarray(net.mu), ((0, pad), (0, pad)), constant_values=BIG)
    nu = np.pad(np.asarray(net.nu), (0, pad), constant_values=NU_PAD)
    return Network(adj=adj, mu=mu, nu=nu)


def pad_apps(apps: Apps, n_apps: int, n_parts: int | None = None) -> Apps:
    """Pad an Apps set to `n_apps` with zero-rate, zero-size phantom apps,
    and (optionally) the partition axis to `n_parts` with phantom stages.

    Phantom partitions append L = 0 / w = 0 trailing entries; `parts` keeps
    each real app's split depth, which is what gates every stage-generic
    kernel (module doc). Phantom *apps* get parts = 1 — any valid depth, as
    lambda = 0 already makes the whole app inert."""
    a = apps.n_apps
    p_old = apps.n_parts
    p_new = p_old if n_parts is None else n_parts
    if n_apps < a:
        raise ValueError(f"cannot pad {a} apps down to {n_apps}")
    if p_new < p_old:
        raise ValueError(f"cannot pad {p_old} partitions down to {p_new}")
    if n_apps == a and p_new == p_old:
        return apps
    pad = n_apps - a
    ppad = p_new - p_old
    # Host-side numpy pads, same rationale as pad_network.
    return Apps(
        src=np.pad(np.asarray(apps.src), (0, pad)),
        dst=np.pad(np.asarray(apps.dst), (0, pad)),
        lam=np.pad(np.asarray(apps.lam), (0, pad)),
        L=np.pad(np.asarray(apps.L), ((0, pad), (0, ppad))),
        w=np.pad(np.asarray(apps.w), ((0, pad), (0, ppad))),
        parts=np.pad(np.asarray(apps.parts), (0, pad), constant_values=1),
    )


def pad_problem(
    problem: Problem, n_nodes: int, n_apps: int, n_parts: int | None = None
) -> tuple[Problem, PadInfo]:
    """Pad one problem to the (n_nodes, n_apps[, n_parts]) envelope; returns
    masks.

    Padded nodes are disconnected, so the graph diameter — and with it the
    carried `hop_bound` — is unchanged by padding. Phantom stages carry no
    traffic, so they don't move the bound either."""
    v, a = problem.net.n_nodes, problem.apps.n_apps
    padded = Problem(
        net=pad_network(problem.net, n_nodes),
        apps=pad_apps(problem.apps, n_apps, n_parts),
        cost=problem.cost,
        hop_bound=problem.hop_bound,
    )
    info = PadInfo(
        node_mask=(np.arange(n_nodes) < v).astype(np.float32),
        app_mask=(np.arange(n_apps) < a).astype(np.float32),
    )
    return padded, info


def pad_problem_parts(problem: Problem, n_parts: int) -> Problem:
    """Pad ONLY the partition axis to `n_parts` (phantom stages; module doc).

    The stage-generic inertness contract says this is bitwise-invisible to
    the solver: same J, same real-stage traffic, same placements."""
    return dataclasses.replace(
        problem, apps=pad_apps(problem.apps, problem.apps.n_apps, n_parts)
    )


def fleet_envelope(problems, round_to: int = 1) -> tuple[int, int]:
    """Common (V, A) envelope of a fleet, optionally rounded up for alignment.

    `round_to > 1` (e.g. 8) reduces the number of distinct padded shapes a
    long-running control plane ever compiles for, at the price of a few
    inert rows per instance.
    """

    def up(x: int) -> int:
        return ((x + round_to - 1) // round_to) * round_to

    v = up(max(p.net.n_nodes for p in problems))
    a = up(max(p.apps.n_apps for p in problems))
    return v, a


def fleet_part_envelope(problems) -> int:
    """Common partition-axis envelope: the max structural P over the fleet.

    Instances below it get phantom stages (module doc) — never rounded up
    beyond the max, since each extra stage costs a [A, V, V] phi slab."""
    return max(p.apps.n_parts for p in problems)


def unify_hop_bound(problems) -> int:
    """One batch-wide Neumann hop bound: the max over instances, with the
    nilpotency-index bound V + 1 standing in for any instance that does not
    carry one. `hop_bound` is static metadata (it sizes the solver's hop
    loop), so stacking must agree on a single value — the max is correct
    for every instance because extra hops past an instance's own bound are
    no-ops under the early-exit residual check."""
    return max(
        p.hop_bound if p.hop_bound is not None else p.net.n_nodes + 1
        for p in problems
    )


def pad_batch_to_multiple(problems, multiple: int) -> tuple[list, int]:
    """Extend a batch with inert repeats of its first instance up to the next
    multiple of `multiple`; returns (extended_problems, n_real).

    Repeats are trivially inert: every engine lane runs the identical
    per-instance computation (freeze masking keeps lanes independent —
    DESIGN.md section 11), so a repeated instance converges exactly like its
    original and the result gather simply trims everything past `n_real`.
    This is the pad-and-trim contract `solve_fleet` applies to chunk tails
    and (when sharding) to batches that don't divide the device count,
    packaged for callers that stack batches themselves before handing them
    to the engine (e.g. tests driving `engine_solve` on a committed mesh)."""
    if multiple < 1:
        raise ValueError(f"multiple must be >= 1, got {multiple}")
    problems = list(problems)
    n = len(problems)
    if n == 0:
        raise EmptyFleetError(
            "pad_batch_to_multiple: empty fleet — there is no first instance "
            "to repeat the pad lanes from"
        )
    if all(float(jnp.max(p.net.nu)) <= NU_PAD for p in problems):
        raise EmptyFleetError(
            "pad_batch_to_multiple: every node of every instance is dead "
            f"(nu <= NU_PAD = {NU_PAD:g}); an all-dead fleet has no live "
            "host set to solve over"
        )
    target = -(-n // multiple) * multiple
    return list(problems) + [problems[0]] * (target - n), n


def stack_problems(
    problems, round_to: int = 1, envelope: tuple[int, int] | None = None,
    hop_bound: int | None = None, n_parts: int | None = None,
) -> tuple[Problem, PadInfo]:
    """Pad every instance to the fleet envelope and stack into one pytree.

    Returns (stacked_problem, stacked_info) whose array leaves all carry a
    leading instance axis of length len(problems). Requires every cost
    model to share `kind` (it is static metadata selecting a code path);
    rho_max / w_comm / w_comp may differ per instance. Per-instance
    `hop_bound`s are unified to the batch max (see `unify_hop_bound`);
    heterogeneous split depths are padded to the fleet's partition envelope
    with inert phantom stages, so one compiled program serves a mixed-P
    ensemble (DESIGN.md section 13).

    `envelope` / `hop_bound` / `n_parts` override the computed (V, A)
    envelope, the unified bound, and the partition envelope — the chunked
    fleet path passes the *global* values so every chunk compiles to the
    same program.
    """
    if not problems:
        raise EmptyFleetError("stack_problems: empty fleet")
    kinds = {p.cost.kind for p in problems}
    if len(kinds) > 1:
        raise ValueError(
            f"fleet mixes cost kinds {sorted(kinds)}; CostModel.kind is "
            "static metadata and must be uniform within one batch"
        )
    v, a = envelope if envelope is not None else fleet_envelope(problems, round_to=round_to)
    p_env = n_parts if n_parts is not None else fleet_part_envelope(problems)
    hb = hop_bound if hop_bound is not None else unify_hop_bound(problems)
    problems = [dataclasses.replace(p, hop_bound=hb) for p in problems]
    padded, infos = zip(*(pad_problem(p, v, a, p_env) for p in problems))
    def stack(*xs):
        # Leaves are arrays except the CostModel scalars, which may still be
        # Python floats; asarray unifies both before stacking. The stack runs
        # on host (numpy) with ONE device transfer per stacked leaf — doing
        # it in jnp dispatches a program per leaf per instance, which at
        # B = 12 costs more than the transfer it feeds.
        return jnp.asarray(np.stack([np.asarray(x) for x in xs]))

    stacked_problem = jax.tree_util.tree_map(stack, *padded)
    stacked_info = jax.tree_util.tree_map(stack, *infos)
    return stacked_problem, stacked_info
