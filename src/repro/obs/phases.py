"""Per-phase round profile: where one ALT round actually spends its time.

The engine's round body is a single fused device program (placement sweep ->
T_phi forwarding sweeps -> round_eval), so host spans around `solve_fleet`
can never say which *phase* dominates. This module re-runs the three phases
as separately-jitted vmapped programs over the same stacked fleet and warm
state, timing each one (best-of-N, blocked on the outputs) under the obs
host spans

    round.placement   round.forwarding   round.round_eval

so the numbers land in any configured trace (REPRO_TRACE) next to the
solve-level spans, and `benchmarks/fleet_bench.py` can persist them as the
`phases` section of BENCH_fleet.json.

One honest caveat, stated here because the split drove a design decision
(DESIGN.md section 18): phase times measured as separate dispatches bound
the fused round body from above — XLA fuses across phase boundaries inside
the engine loop — so treat the split as a dominance profile, not an exact
decomposition. It is how we established that the placement sweep is a few
percent of the round and forwarding dominates.
"""
from __future__ import annotations

import time

import jax

from .trace import span

PHASES = ("placement", "forwarding", "round_eval")


def _best_of(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def profile_round_phases(
    problems,
    *,
    t_phi: int,
    alpha: float = 0.5,
    colocate: bool = False,
    use_pallas: bool = False,
    interpret: bool = True,
    solver: str = "neumann",
    block_apps: int = 1,
    round_to: int = 1,
    reps: int = 3,
) -> dict:
    """Time each round phase over a stacked fleet at a warm round-1 state.

    The state driven through the phases is the one round 1 of the engine
    would see (structured init + one evaluation), so the profile reflects
    the real in-loop tensor shapes and placement churn. Returns per-phase
    warm best-of-`reps` milliseconds plus the share of their sum:

        {"batch", "t_phi", "block_apps",
         "placement_ms", "forwarding_ms", "round_eval_ms",
         "placement_share", "forwarding_share", "round_eval_share"}
    """
    # Imported here, not at module top: obs is a leaf package the solver
    # layers import freely, so pulling core/fleet in at import time would
    # close a cycle (fleet -> obs -> fleet) that only resolves by luck of
    # initialization order.
    from repro.core.forwarding import forwarding_update
    from repro.core.marginals import round_eval
    from repro.core.placement import placement_update, structured_init
    from repro.fleet.pad import stack_problems

    stacked, _ = stack_problems(problems, round_to=round_to)

    @jax.jit
    def init(p):
        def one(q):
            s = structured_init(
                q, colocate=colocate, use_pallas=use_pallas,
                interpret=interpret,
            )
            J, aux = round_eval(
                q, s, solver=solver, use_pallas=use_pallas,
                interpret=interpret,
            )
            return s, aux["ctg"]

        return jax.vmap(one)(p)

    state, ctg = jax.block_until_ready(init(stacked))

    place = jax.jit(
        jax.vmap(
            lambda p, s, c: placement_update(
                p, s, c, colocate=colocate, use_pallas=use_pallas,
                interpret=interpret, solver=solver, block_apps=block_apps,
            )
        )
    )
    fwd = jax.jit(
        jax.vmap(
            lambda p, s: forwarding_update(
                p, s, t_phi=t_phi, alpha=alpha, solver=solver,
                use_pallas=use_pallas, interpret=interpret,
            )
        )
    )
    ev = jax.jit(
        jax.vmap(
            lambda p, s: round_eval(
                p, s, solver=solver, use_pallas=use_pallas,
                interpret=interpret,
            )
        )
    )

    placed = jax.block_until_ready(place(stacked, state, ctg))  # compile
    forwarded = jax.block_until_ready(fwd(stacked, placed))
    jax.block_until_ready(ev(stacked, forwarded))

    times = {}
    with span("round.phases", batch=len(problems), block_apps=block_apps):
        with span("round.placement", block_apps=block_apps):
            times["placement"] = _best_of(
                lambda: place(stacked, state, ctg), reps
            )
        with span("round.forwarding", t_phi=t_phi):
            times["forwarding"] = _best_of(
                lambda: fwd(stacked, placed), reps
            )
        with span("round.round_eval"):
            times["round_eval"] = _best_of(
                lambda: ev(stacked, forwarded), reps
            )

    total = sum(times.values())
    out = {
        "batch": len(problems),
        "t_phi": t_phi,
        "block_apps": block_apps,
    }
    for k in PHASES:
        out[f"{k}_ms"] = round(times[k] * 1e3, 3)
        out[f"{k}_share"] = round(times[k] / total, 4)
    return out
