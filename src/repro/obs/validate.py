"""Validate an obs JSONL span trace: schema + span nesting.

CI runs this on the trace the launch-CLI smoke emits:

    REPRO_TRACE=trace/fleet.jsonl python -m repro.launch.fleet ...
    python -m repro.obs.validate trace/fleet.jsonl

Checks (the contract DESIGN.md section 14 documents):
  * every line parses as one JSON object carrying `ts`, `name`, `dur`,
    and `attrs` with the right types (`ts`/`dur` non-negative numbers,
    `name` a non-empty string, `attrs` an object);
  * spans nest properly: every non-root event's `parent` id exists, the
    child's [ts, ts+dur] interval is contained in the parent's (small
    epsilon for clock granularity), and `depth == parent.depth + 1`.
"""
from __future__ import annotations

import argparse
import json
import pathlib

REQUIRED_FIELDS = ("ts", "name", "dur", "attrs")

# Containment slack: perf_counter deltas are exact within a span, but the
# parent's t1 is read a few instructions after the child's, so allow a hair.
_EPS = 1e-6


def validate_events(records: list[dict]) -> list[str]:
    """Return human-readable schema/nesting violations (empty = valid)."""
    errors: list[str] = []
    by_id: dict = {}
    for i, rec in enumerate(records):
        where = f"event {i}"
        if not isinstance(rec, dict):
            errors.append(f"{where}: not a JSON object")
            continue
        missing = [k for k in REQUIRED_FIELDS if k not in rec]
        if missing:
            errors.append(f"{where}: missing required fields {missing}")
            continue
        if not isinstance(rec["name"], str) or not rec["name"]:
            errors.append(f"{where}: name must be a non-empty string")
        for key in ("ts", "dur"):
            v = rec[key]
            if not isinstance(v, (int, float)) or isinstance(v, bool) or v < 0:
                errors.append(f"{where}: {key} must be a non-negative number")
        if not isinstance(rec["attrs"], dict):
            errors.append(f"{where}: attrs must be an object")
        if "id" in rec:
            if rec["id"] in by_id:
                errors.append(f"{where}: duplicate id {rec['id']}")
            by_id[rec["id"]] = rec

    for i, rec in enumerate(records):
        if not isinstance(rec, dict) or "parent" not in rec:
            continue
        parent_id = rec["parent"]
        if parent_id == -1:
            if rec.get("depth", 0) != 0:
                errors.append(f"event {i}: root span with depth != 0")
            continue
        parent = by_id.get(parent_id)
        name = rec.get("name", "?")
        if parent is None:
            errors.append(
                f"event {i} ({name}): parent id {parent_id} not in trace"
            )
            continue
        if rec.get("depth") != parent.get("depth", 0) + 1:
            errors.append(
                f"event {i} ({name}): depth {rec.get('depth')} != "
                f"parent depth {parent.get('depth')} + 1"
            )
        child_t0, child_t1 = rec["ts"], rec["ts"] + rec["dur"]
        par_t0, par_t1 = parent["ts"], parent["ts"] + parent["dur"]
        if child_t0 < par_t0 - _EPS or child_t1 > par_t1 + _EPS:
            errors.append(
                f"event {i} ({name}): interval [{child_t0:.6f}, "
                f"{child_t1:.6f}] not contained in parent "
                f"{parent.get('name', '?')} [{par_t0:.6f}, {par_t1:.6f}]"
            )
    return errors


def validate_lines(lines) -> tuple[list[dict], list[str]]:
    """Parse JSONL lines; returns (parsed_records, errors)."""
    records: list[dict] = []
    errors: list[str] = []
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            records.append(json.loads(line))
        except json.JSONDecodeError as exc:
            errors.append(f"line {i + 1}: invalid JSON ({exc})")
    return records, errors + validate_events(records)


def validate_file(path) -> tuple[int, list[str]]:
    """Returns (n_events, errors) for one JSONL trace file."""
    text = pathlib.Path(path).read_text()
    records, errors = validate_lines(text.splitlines())
    return len(records), errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="path to a JSONL span trace")
    ap.add_argument(
        "--min-events",
        type=int,
        default=1,
        help="fail unless the trace holds at least this many events",
    )
    args = ap.parse_args(argv)
    n_events, errors = validate_file(args.trace)
    if n_events < args.min_events:
        errors.append(
            f"trace has {n_events} events, expected >= {args.min_events}"
        )
    if errors:
        for err in errors:
            print(f"INVALID: {err}")
        return 1
    print(f"{args.trace}: {n_events} events, schema + nesting OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
