"""Span-based host tracing for the solver control plane (obs layer 2).

`with span("solve_fleet.execute", chunk=0):` wraps one host-side phase; the
tracer records (name, ts, dur, attrs, parent) events that serialize to

  * JSONL — one event per line with schema ``{ts, name, dur, attrs}`` plus
    the structural fields ``{id, parent, tid, depth}``, validated by
    `python -m repro.obs.validate` (CI runs it on the launch-CLI smoke
    trace), and
  * Chrome ``trace_event`` JSON (``"ph": "X"`` complete events, microsecond
    timestamps) loadable in Perfetto or chrome://tracing.

Tracing is off by default and costs a single attribute read per span when
disabled — the instrumented hot paths (fleet/solve.py, launch/*.py,
benchmarks/run.py) never pay for it unless asked. Enable programmatically
(`configure(enabled=True, jsonl_path=...)`) or by environment:

  REPRO_TRACE=/path/out.jsonl   enable and write the JSONL there (plus a
                                sibling Chrome file, `.jsonl` replaced by
                                `.trace.json`) at process exit
  REPRO_JAX_TRACE=1             additionally wrap every span in a
                                `jax.profiler.TraceAnnotation`, so host
                                spans line up with XLA activity inside a
                                JAX profiler capture

Spans nest through a thread-local stack, so concurrent threads trace
independently. Events are recorded at span *exit* (a parent's duration is
unknown while its children run), which means children precede their parent
in the stream — consumers join on the explicit `parent` id rather than
stream order; `repro.obs.validate` checks that containment.
"""
from __future__ import annotations

import atexit
import contextlib
import dataclasses
import json
import os
import pathlib
import threading
import time

TRACE_ENV = "REPRO_TRACE"
JAX_TRACE_ENV = "REPRO_JAX_TRACE"


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One closed span. `ts`/`dur` are seconds relative to the tracer epoch.

    id     : unique per tracer, assigned at span entry
    parent : id of the enclosing span on the same thread, -1 for a root
    depth  : nesting depth (0 = root); always parent.depth + 1
    tid    : OS thread ident the span ran on
    """

    id: int
    parent: int
    name: str
    ts: float
    dur: float
    tid: int
    depth: int
    attrs: dict

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    def to_chrome(self) -> dict:
        """Chrome trace_event "complete" event (microsecond clock)."""
        return {
            "name": self.name,
            "cat": "repro",
            "ph": "X",
            "ts": self.ts * 1e6,
            "dur": self.dur * 1e6,
            "pid": os.getpid(),
            "tid": self.tid,
            "args": self.attrs,
        }


class Tracer:
    """Collects `SpanEvent`s; one process-wide instance lives in `TRACER`.

    Instantiable separately for tests — a fresh Tracer shares nothing with
    the global one."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._local = threading.local()
        self._events: list[SpanEvent] = []
        self._epoch = time.perf_counter()
        self._next_id = 0
        self.enabled = False
        self.jsonl_path: str | None = None
        self.chrome_path: str | None = None

    # -- recording ----------------------------------------------------------
    def _stack(self) -> list[int]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        """Trace one host-side phase; a no-op unless the tracer is enabled.

        Keyword attributes must be JSON-serializable (they land in the
        JSONL `attrs` object and the Chrome `args` object verbatim)."""
        if not self.enabled:
            yield
            return
        stack = self._stack()
        with self._lock:
            sid = self._next_id
            self._next_id += 1
        parent = stack[-1] if stack else -1
        depth = len(stack)
        stack.append(sid)
        annotation = None
        if os.environ.get(JAX_TRACE_ENV):
            from jax.profiler import TraceAnnotation

            annotation = TraceAnnotation(name)
            annotation.__enter__()
        t0 = time.perf_counter()
        try:
            yield
        finally:
            t1 = time.perf_counter()
            if annotation is not None:
                annotation.__exit__(None, None, None)
            stack.pop()
            event = SpanEvent(
                id=sid,
                parent=parent,
                name=name,
                ts=t0 - self._epoch,
                dur=t1 - t0,
                tid=threading.get_ident(),
                depth=depth,
                attrs=attrs,
            )
            with self._lock:
                self._events.append(event)

    # -- inspection / lifecycle ---------------------------------------------
    def events(self) -> list[SpanEvent]:
        with self._lock:
            return list(self._events)

    def reset(self) -> None:
        """Drop recorded events (the epoch is kept so ts stays monotone)."""
        with self._lock:
            self._events.clear()

    def configure(
        self,
        enabled: bool = True,
        jsonl_path: str | None = None,
        chrome_path: str | None = None,
    ) -> None:
        self.enabled = enabled
        if jsonl_path is not None:
            self.jsonl_path = str(jsonl_path)
        if chrome_path is not None:
            self.chrome_path = str(chrome_path)

    # -- serialization ------------------------------------------------------
    def write_jsonl(self, path) -> None:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with path.open("w") as fh:
            for ev in self.events():
                fh.write(json.dumps(ev.to_json()) + "\n")

    def write_chrome_trace(self, path) -> None:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        payload = {
            "traceEvents": [ev.to_chrome() for ev in self.events()],
            "displayTimeUnit": "ms",
        }
        path.write_text(json.dumps(payload))

    def flush(self) -> None:
        """Write whatever output paths were configured (no-op otherwise)."""
        if self.jsonl_path:
            self.write_jsonl(self.jsonl_path)
        if self.chrome_path:
            self.write_chrome_trace(self.chrome_path)


# ---------------------------------------------------------------------------
# Process-wide tracer + convenience module API
# ---------------------------------------------------------------------------
TRACER = Tracer()


def span(name: str, **attrs):
    """`with span("solve_fleet.chunk", chunk=i):` on the global tracer."""
    return TRACER.span(name, **attrs)


def configure(
    enabled: bool = True,
    jsonl_path: str | None = None,
    chrome_path: str | None = None,
    flush_at_exit: bool = False,
) -> None:
    TRACER.configure(
        enabled=enabled, jsonl_path=jsonl_path, chrome_path=chrome_path
    )
    if flush_at_exit:
        _register_atexit_flush()


def tracer_enabled() -> bool:
    return TRACER.enabled


def flush() -> None:
    TRACER.flush()


def reset() -> None:
    TRACER.reset()


_ATEXIT_REGISTERED = False


def _register_atexit_flush() -> None:
    global _ATEXIT_REGISTERED
    if not _ATEXIT_REGISTERED:
        atexit.register(TRACER.flush)
        _ATEXIT_REGISTERED = True


def chrome_path_for(jsonl_path) -> str:
    """Sibling Chrome-trace path for a JSONL path (`x.jsonl` -> `x.trace.json`)."""
    p = pathlib.Path(jsonl_path)
    stem = p.name[: -len(".jsonl")] if p.name.endswith(".jsonl") else p.name
    return str(p.with_name(stem + ".trace.json"))


def maybe_configure_from_env() -> bool:
    """Enable the global tracer when REPRO_TRACE names an output path.

    Entry points (launch CLIs, the benchmark harness) call this once at
    startup; the trace is flushed at process exit. Returns whether tracing
    is enabled afterwards (already-configured tracers are left alone)."""
    if TRACER.enabled:
        return True
    path = os.environ.get(TRACE_ENV)
    if not path:
        return False
    configure(
        enabled=True,
        jsonl_path=path,
        chrome_path=chrome_path_for(path),
        flush_at_exit=True,
    )
    return True
