"""Process-local metrics registry (obs layer 3).

Counters, gauges, and histograms keyed by dotted names, snapshotted into
the launch CLIs' JSON output and `benchmarks/run.py --json-out` — so the
committed BENCH files carry convergence telemetry (rounds executed vs
budget, pad overhead, warm/cold compile counts) alongside the timings the
trend lint already tracks.

This is deliberately *not* a client for any metrics backend: it is the
process-local substrate the ROADMAP's online control plane needs (epoch
re-solve latency, placement churn, early-exit savings as numbers in one
dict), and a JSON snapshot is the whole export story. Everything is
thread-safe and cheap enough to live on solver hot paths — a counter inc
is one lock + add.

Conventions:
  * names are dotted lowercase (`fleet.chunks_executed`);
  * counters count events, gauges record the latest value, histograms
    summarize a distribution as {count, mean, min, max, p50, p95};
  * `registry` is the process-wide instance; `MetricsRegistry()` gives
    tests an isolated one;
  * `snapshot()` returns a flat {name: number-or-dict} JSON-ready dict.
"""
from __future__ import annotations

import threading


class Counter:
    """Monotone event count."""

    def __init__(self) -> None:
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self.value += n

    def snapshot(self):
        return self.value


class Gauge:
    """Latest-value metric (e.g. rounds executed by the most recent solve)."""

    def __init__(self) -> None:
        self.value: float | int | None = None

    def set(self, value) -> None:
        self.value = value

    def snapshot(self):
        return self.value


def _percentile(sorted_values: list[float], q: float) -> float:
    """Linear-interpolation percentile of an already-sorted list."""
    n = len(sorted_values)
    if n == 1:
        return sorted_values[0]
    pos = (q / 100.0) * (n - 1)
    lo = int(pos)
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return sorted_values[lo] * (1.0 - frac) + sorted_values[hi] * frac


class Histogram:
    """Distribution summary; observations are retained in memory (the
    intended scale is control-plane events — requests, chunks, epochs —
    not per-token samples)."""

    def __init__(self) -> None:
        self._values: list[float] = []
        self._lock = threading.Lock()

    def observe(self, value) -> None:
        with self._lock:
            self._values.append(float(value))

    @property
    def count(self) -> int:
        return len(self._values)

    def percentile(self, q: float) -> float:
        with self._lock:
            if not self._values:
                raise ValueError("empty histogram has no percentiles")
            return _percentile(sorted(self._values), q)

    def snapshot(self) -> dict:
        with self._lock:
            if not self._values:
                return {"count": 0}
            values = sorted(self._values)
        return {
            "count": len(values),
            "mean": sum(values) / len(values),
            "min": values[0],
            "max": values[-1],
            "p50": _percentile(values, 50.0),
            "p95": _percentile(values, 95.0),
        }


class MetricsRegistry:
    """Get-or-create registry of named metrics.

    Re-registering a name with a different metric type raises — a typo'd
    reuse must fail loudly, not silently fork the series."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, cls):
        with self._lock:
            metric = self._metrics.get(name)
            if metric is None:
                metric = self._metrics[name] = cls()
            elif not isinstance(metric, cls):
                raise TypeError(
                    f"metric {name!r} is a {type(metric).__name__}, "
                    f"not a {cls.__name__}"
                )
            return metric

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> dict:
        """Flat {name: value} dict; histogram values are summary sub-dicts."""
        with self._lock:
            items = sorted(self._metrics.items())
        return {name: metric.snapshot() for name, metric in items}

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


# The process-wide registry every instrumented module shares.
registry = MetricsRegistry()
