"""Host-side view of the engine's on-device round trace (obs layer 1).

`core/engine.py` writes per-round diagnostics into preallocated buffers
inside the jitted while_loop (the `EngineTrace` carry slot); `fleet/solve.py`
gathers and trims them exactly like the J history and wraps the numpy
arrays in the `FleetTrace` below — the object `FleetResult.trace` exposes.

All `[B, m_max + 1]` buffers obey the history contract (DESIGN.md
sections 11 and 14): column m holds round m's value for every instance the
round was applied to, and stays at its NaN (or, for `live`, 0.0) init value
past each instance's freeze point — so the NaN mask doubles as the
per-instance convergence record, and frozen lanes are bitwise-independent
of how long the rest of the batch kept the loop alive.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class FleetTrace:
    """Per-round, per-instance solver diagnostics of one fleet solve.

    J_comm / J_comp : [B, m_max + 1] objective split per applied round
                      (column 0 = structured init), NaN past freeze
    moves           : [B, m_max + 1] placement churn — how many live
                      (app, partition) hosts changed in the round; column 0
                      is 0.0 (the init has no previous placement), NaN past
                      freeze
    live            : [B, m_max + 1] 1.0 iff the round was applied to the
                      instance (`live[b, m] == 1  <=>  m <= iters[b]`);
                      the other buffers' NaN mask in arithmetic form
    best_round      : [B] int32 round index of the returned best iterate
                      (0 = the structured init was never improved on)
    """

    J_comm: np.ndarray
    J_comp: np.ndarray
    moves: np.ndarray
    live: np.ndarray
    best_round: np.ndarray

    @property
    def n_instances(self) -> int:
        return int(self.live.shape[0])

    @property
    def n_rounds(self) -> int:
        """Last round applied to ANY instance (= FleetResult.rounds)."""
        applied = np.flatnonzero(self.live.sum(axis=0) > 0)
        return int(applied[-1]) if applied.size else 0

    def churn_per_instance(self) -> np.ndarray:
        """[B] mean placement moves per applied round (0.0 for instances
        that froze immediately and never applied a round)."""
        moves = self.moves[:, 1:]
        applied = ~np.isnan(moves)
        counts = applied.sum(axis=1)
        total = np.where(applied, moves, 0.0).sum(axis=1)
        return np.where(counts > 0, total / np.maximum(counts, 1), 0.0)

    def mean_churn(self) -> float:
        """Mean placement moves per applied round over the whole fleet."""
        moves = self.moves[:, 1:]
        if not np.any(~np.isnan(moves)):
            return 0.0
        return float(np.nanmean(moves))

    def frozen_count(self) -> np.ndarray:
        """[n_rounds + 1] instances NOT applied at each executed round —
        the paper-facing \"how much of the fleet had converged by round m\"
        curve (column 0 is always 0: the init applies to everyone)."""
        cols = self.n_rounds + 1
        return (self.live[:, :cols] <= 0.0).sum(axis=0).astype(np.int64)

    def to_dict(self) -> dict:
        """Compact JSON-ready summary (what the launch CLI emits)."""
        return {
            "rounds": self.n_rounds,
            "mean_churn_per_round": round(self.mean_churn(), 4),
            "churn_per_instance": [
                round(float(c), 4) for c in self.churn_per_instance()
            ],
            "best_round": self.best_round.astype(int).tolist(),
            "frozen_count_per_round": self.frozen_count().tolist(),
        }
