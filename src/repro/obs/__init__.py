"""Solver observability: round traces, host spans, and a metrics registry.

Three layers (DESIGN.md section 14):

  1. **On-device round traces** — `core/engine.py` writes per-round
     diagnostics (J_comm/J_comp split, placement churn, live mask,
     best-iterate round index) into preallocated NaN-padded buffers inside
     the jitted while_loop, under the same inertness contract as the J
     history; `fleet/solve.py` gathers them into the host-side
     `FleetTrace` riding on `FleetResult.trace`.
  2. **Host spans** — `obs.trace.span("solve_fleet.execute", chunk=i)`
     brackets pad/stack/commit/execute/gather boundaries in the fleet
     solver, the launch CLIs, and the benchmark harness; JSONL + Chrome
     trace_event output, optional `jax.profiler.TraceAnnotation`
     passthrough behind REPRO_JAX_TRACE=1, schema validated by
     `python -m repro.obs.validate`.
  3. **Metrics registry** — `obs.metrics.registry`, process-local
     counters/gauges/histograms (chunks executed, pad overhead, rounds vs
     budget, compile warm/cold, serve latencies) snapshotted into the
     launch CLIs' JSON and `benchmarks/run.py --json-out`.
"""
from .metrics import Counter, Gauge, Histogram, MetricsRegistry, registry  # noqa: F401
from .phases import profile_round_phases  # noqa: F401
from .roundtrace import FleetTrace  # noqa: F401
from .trace import (  # noqa: F401
    TRACER,
    SpanEvent,
    Tracer,
    chrome_path_for,
    configure,
    flush,
    maybe_configure_from_env,
    span,
    tracer_enabled,
)
